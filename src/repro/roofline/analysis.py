"""Three-term roofline analysis from compiled XLA artifacts.

  compute term    = HLO_FLOPs / (chips x peak FLOP/s)
  memory term     = HLO_bytes / (chips x HBM bw)
  collective term = collective_bytes / (chips x link bw)

``compiled.cost_analysis()`` reports *per-partition* FLOPs/bytes after SPMD
partitioning (verified empirically), so no chip division is applied to those.
Collective bytes are parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute op
we sum the result-shape bytes, with an op-specific traffic multiplier
(all-reduce counts 2x for its reduce-scatter + all-gather ring phases).
"""
from __future__ import annotations

import dataclasses
import re


from repro.core.hardware import HardwareProfile, TPU_V5E

__all__ = ["RooflineReport", "collective_bytes", "analyze_compiled"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ring traffic per device relative to result bytes
_MULTIPLIER = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum per-op collective traffic (bytes, multiplier applied) by op kind."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        # async pairs: count -start, skip -done (same shapes)
        if hlo_text[m.end() - 6:m.end() - 1].endswith("done"):
            continue
        out[op] += _shape_bytes(shapes) * _MULTIPLIER[op]
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per device
    hlo_bytes: float              # per device
    coll_bytes: float             # per device
    coll_by_kind: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6ND / 2ND analytic, GLOBAL
    useful_ratio: float           # model_flops / (hlo_flops * chips)
    bytes_per_device: float       # from memory_analysis
    peak_flops: float
    notes: str = ""

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Achievable useful-FLOPs fraction of peak: how close the step is to
        the compute roofline, discounted by non-useful compiled FLOPs."""
        if self.step_time <= 0:
            return 0.0
        useful_per_dev = self.model_flops / self.chips
        return useful_per_dev / self.step_time / self.peak_flops

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["step_time"] = self.step_time
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float,
                     hw: HardwareProfile = TPU_V5E,
                     dtype: str = "bfloat16", notes: str = "") -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    counts = coll.pop("_counts")
    total_coll = float(sum(coll.values()))
    ma = compiled.memory_analysis()
    bytes_per_dev = float(
        getattr(ma, "argument_size_in_bytes", 0)
        + getattr(ma, "output_size_in_bytes", 0)
        + getattr(ma, "temp_size_in_bytes", 0)
        - getattr(ma, "alias_size_in_bytes", 0))
    peak = hw.flops_for(dtype)
    t_comp = flops / peak
    t_mem = byts / hw.beta
    t_coll = total_coll / hw.link_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=total_coll,
        coll_by_kind={**coll, "counts": counts},
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        bytes_per_device=bytes_per_dev, peak_flops=peak, notes=notes,
    )
