"""Analytic per-cell cost model: FLOPs / HBM bytes / collective bytes.

Why this exists: XLA's ``HloCostAnalysis`` visits a ``while`` body ONCE, so
``compiled.cost_analysis()`` under-counts every scanned structure (layer
stack, flash-attention chunks, xent chunks) by its trip count. The dry-run
still records the measured values (and the memory_analysis, which IS correct
per-device), but the roofline terms in EXPERIMENTS.md are computed here from
the model structure + sharding, which we control exactly. The two sources are
cross-validated in tests on a no-scan configuration.

All values are PER DEVICE. Conventions:
  * train FLOPs = fwd * (3 + 1 if remat)  (bwd = 2x fwd, remat replays fwd)
  * ring all-reduce moves 2x the tensor bytes per device; AG/RS/A2A move 1x
  * FSDP: param all-gather in fwd + bwd, gradient reduce-scatter
  * pure DP (pod axis and/or no-fsdp): gradient all-reduce (2x)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeCell

__all__ = ["CellCosts", "analytic_costs"]

BY = {"bfloat16": 2, "float32": 4}


@dataclasses.dataclass
class CellCosts:
    flops: float          # per device
    hbm_bytes: float      # per device
    coll_bytes: float     # per device (multipliers applied)
    detail: dict

    def terms(self, hw, dtype="bfloat16"):
        return (self.flops / hw.flops_for(dtype),
                self.hbm_bytes / hw.beta,
                self.coll_bytes / hw.link_bw)


def _layer_linear_flops(cfg: ModelConfig, T: float) -> float:
    """fwd matmul FLOPs of one layer (global, all tokens)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    f = 0.0
    if cfg.family != "ssm":
        H, Hkv = cfg.num_heads, cfg.num_kv_heads
        f += 2 * T * d * (H * hd + 2 * Hkv * hd)   # qkv
        f += 2 * T * H * hd * d                     # o
    n_mlp_mats = 2 if cfg.mlp_type == "gelu" else 3
    if cfg.family == "moe":
        f += 2 * T * d * cfg.num_experts            # router
        f += n_mlp_mats * 2 * (T * cfg.experts_per_token * cfg.capacity_factor) * d * cfg.d_ff
    elif cfg.d_ff > 0:
        f += n_mlp_mats * 2 * T * d * cfg.d_ff      # gate/up/down
    if cfg.family in ("ssm", "hybrid"):
        Hs, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
        d_in = 2 * Hs * P + 2 * G * N + Hs          # incl. z gate
        f += 2 * T * d * d_in + 2 * T * Hs * P * d  # in/out proj
    return f


def _layer_attn_flops(cfg: ModelConfig, cell: ShapeCell, decode: bool) -> float:
    """fwd attention-score+value FLOPs of one layer (global)."""
    if cfg.family == "ssm":
        return 0.0
    B, S = cell.global_batch, cell.seq_len
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    windows = cfg.layer_windows()
    n_global = sum(1 for w in windows if w == 0)
    n_local = len(windows) - n_global
    w = cfg.sliding_window or S

    def per_layer(keys_per_query):
        q = B * (1 if decode else S)
        return 2 * 2 * q * keys_per_query * H * hd  # QK^T and PV

    if decode:
        kq_g, kq_l = S, min(w, S)
    else:
        kq_g, kq_l = S / 2, min(w, S / 2)  # causal halves the average
    total = n_global * per_layer(kq_g) + n_local * per_layer(kq_l)
    return total / max(len(windows), 1)  # caller multiplies by num_layers


def _ssd_flops(cfg: ModelConfig, cell: ShapeCell, decode: bool) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    B, S = cell.global_batch, cell.seq_len
    Hs, P, N, c = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    T = B * (1 if decode else S)
    if decode:
        return 2 * T * Hs * N * P * 2               # state update + readout
    # intra: scores 2*T*c*N*H + apply 2*T*c*P*H; states/off: 2*2*T*N*P*H
    return T * Hs * (2 * c * N + 2 * c * P + 4 * N * P)


def analytic_costs(cfg: ModelConfig, cell: ShapeCell, mesh_shape: dict,
                   n_params: int, n_active: int,
                   opt_dtype: str = "float32") -> CellCosts:
    chips = int(np.prod(list(mesh_shape.values())))
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    if cfg.parallel_style == "fsdp_only":
        dp, tp = dp * tp, 1  # no TP: model axis joins the batch/ZeRO axes
    opt_by = {"float32": 4, "bfloat16": 2}[opt_dtype]
    by = BY[cfg.dtype]
    decode = cell.kind == "decode"
    B, S = cell.global_batch, cell.seq_len
    T = B * (1 if decode else S)
    Lc = cfg.num_layers
    d, V = cfg.d_model, cfg.vocab_size

    # ---------------- FLOPs ----------------
    lin = Lc * _layer_linear_flops(cfg, T)
    attn = Lc * _layer_attn_flops(cfg, cell, decode)
    ssd = Lc * _ssd_flops(cfg, cell, decode)
    ntok_logits = T if cell.kind == "train" else B
    Vp = -(-V // 256) * 256
    head = 2 * ntok_logits * d * Vp * (cfg.num_codebooks if cfg.frontend == "audio_codebooks" else 1)
    fwd = lin + attn + ssd + head
    if cell.kind != "train":
        mult = 1.0
    elif not cfg.remat:
        mult = 3.0
    elif cfg.remat_policy == "dots":
        mult = 3.15  # matmul outputs saved; only elementwise ops recomputed
    else:
        mult = 4.0
    # vocab is padded to a 256-multiple (models.model.padded_vocab) so the
    # head always shards over the full mesh.
    flops_dev = fwd / chips * mult

    # ---------------- HBM bytes ----------------
    pbytes = n_params * by
    if cell.kind == "train":
        # params: fwd read + bwd read (+ remat replay read) ; grads write+read;
        # opt m,v read+write + param write
        p_traffic = (pbytes * (3 if cfg.remat else 2) + 2 * pbytes
                     + n_params * opt_by * 4 + pbytes)
    else:
        p_traffic = pbytes * (1 if not decode else 1)
    # activations: ~6 hidden-sized tensors r/w per layer + attention score
    # traffic (flash: write+read P per chunk) + ssd chunk states
    act = 0.0
    if cell.kind != "decode":
        act += Lc * 6 * T * d * by * (3 if cell.kind == "train" else 1)
        if cfg.family != "ssm":
            # flash attention writes/reads the (qc, S)-scores per head once
            windows = cfg.layer_windows()
            for w in windows:
                keys = min(w or S, S) / (1 if w else 2)
                act += 2 * B * S * keys * cfg.num_heads * 4 / 1  # f32 scores
    kv = 0.0
    if cfg.family != "ssm" and cell.kind != "train":
        kv_tokens = B * S
        kv = 2 * Lc * kv_tokens * cfg.num_kv_heads * cfg.resolved_head_dim * by
        kv *= 2 if cell.kind == "prefill" else 1  # write on prefill, read on decode
    state = 0.0
    if cfg.family in ("ssm", "hybrid") and cell.kind != "train":
        state = 2 * Lc * B * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * by
    logits_traffic = 2 * ntok_logits * V * 4
    # params live sharded over model x (data if fsdp); each device streams its
    # own shard (replicas read their local copy, so traffic doesn't shrink
    # with replication).
    param_shards = tp * (dp if cfg.fsdp else 1)
    hbm_dev = (p_traffic / param_shards
               + (act + kv + state + logits_traffic) / chips)

    # ---------------- Collectives ----------------
    coll = 0.0
    # TP all-reduces: attn-out + mlp-out (+ssm-out) per layer, fwd (+bwd x2)
    n_ar = 0
    if cfg.family != "ssm":
        n_ar += 1
    if cfg.d_ff > 0 or cfg.family == "moe":
        n_ar += 1
    if cfg.family in ("ssm", "hybrid"):
        n_ar += 1
    # parallel_block: XLA's AllReduceReassociate merges the fwd attn+ffn ARs
    # (measured: 24 -> 22 ops on kimi); the bwd pair does NOT reassociate.
    merge_fwd = 1 if (cfg.parallel_block and n_ar >= 2) else 0
    act_bytes_dev = T * d * by / dp          # tensor local to a TP group member
    # fwd ARs (minus the parallel-block merge) + 2 per AR in bwd for training
    ar_units = (n_ar - merge_fwd) + (2 * n_ar if cell.kind == "train" else 0)
    if tp > 1:
        coll += Lc * ar_units * 2.0 * act_bytes_dev
    # logits are vocab-sharded (embed V over "model") => no (T,V) all-reduce;
    # the logsumexp cross-shard reduction is O(T) and negligible.
    if dp > 1 and cfg.fsdp:
        if cell.kind == "train":
            coll += 3.0 * pbytes / tp   # AG fwd + AG bwd + RS grads
        else:
            coll += 1.0 * pbytes / tp   # AG fwd (fsdp-sharded serving weights)
    elif cell.kind == "train" and dp > 1:
        coll += 2.0 * pbytes / tp       # ring all-reduce of grads
    if cfg.num_experts and tp > 1:
        cap_tokens = T * cfg.experts_per_token * cfg.capacity_factor
        a2a = cap_tokens * d * by / chips
        coll += Lc * 2 * a2a * (3 if cell.kind == "train" else 1)
    coll_dev = coll

    warnings = []
    if cell.kind != "decode" and B % dp != 0 and (B * S) % dp != 0:
        warnings.append(
            f"global_batch {B} (and B*S) not divisible by dp={dp}: activations "
            "replicate and these terms underestimate — wrong style for this cell")
    return CellCosts(
        flops=flops_dev, hbm_bytes=hbm_dev, coll_bytes=coll_dev,
        detail={
            "fwd_flops_global": fwd, "linear": lin, "attention": attn,
            "ssd": ssd, "head": head, "param_bytes": pbytes,
            "act_bytes_global": act, "kv_bytes_global": kv,
            "warnings": warnings,
        },
    )
