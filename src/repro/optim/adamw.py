"""AdamW in pure JAX pytrees, with optional ZeRO-1 state sharding.

The optimizer state mirrors the param tree; under ``zero1=True`` the launch
layer shards ``m``/``v`` over the "data" axis in addition to the param's own
spec (see ``repro.launch.train.opt_state_sharding``) so optimizer memory
scales down with the DP degree — the standard distributed-optimizer trick.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads),
        jnp.zeros((), jnp.float32),
    )
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
