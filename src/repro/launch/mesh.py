"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces a 512-device
host platform while tests/benches must see the real single device.
"""
from __future__ import annotations

import jax

from repro import compat

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a (data, model=1) mesh — smoke/example runs."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"))
