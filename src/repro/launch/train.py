"""Training launcher: ``python -m repro.launch.train --arch granite_3_2b ...``

Runs a real (reduced or full) training job on the available devices with the
fault-tolerant loop, checkpointing, and optional compressed-DP gradients.
On the CPU container this runs the reduced configs; on a TPU slice the same
entrypoint runs the full configs against the production mesh (the per-host
data feeding hook is in repro.data.pipeline).
"""
from __future__ import annotations

import argparse
import logging

import jax

import repro.api as falcon
from repro import compat
from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticLMData
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import sharding as SH
from repro.train import (TrainLoop, TrainLoopConfig, make_train_step, steps)
from jax.sharding import PartitionSpec as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced same-family config (CPU default)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--compressed-dp", action="store_true",
                    help="int8-compressed data-parallel gradient all-reduce")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh else make_local_mesh())
    rules = SH.make_rules(mesh, fsdp=cfg.fsdp)
    fcfg = M.falcon_config_for(cfg, dict(mesh.shape))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, opt_cfg)
    with compat.set_mesh(mesh), falcon.use(fcfg):
        psh = SH.param_sharding(params, mesh, rules)
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, {
            "m": psh, "v": psh, "step": SH.named_sharding(mesh)})

        data = SyntheticLMData(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed,
                       num_codebooks=cfg.num_codebooks
                       if cfg.frontend == "audio_codebooks" else 0),
            mesh=mesh, batch_spec=P(rules.batch))
        if args.compressed_dp:
            step = steps.make_compressed_dp_train_step(
                cfg, opt_cfg, mesh, total_steps=args.steps)
        else:
            step = make_train_step(cfg, opt_cfg, total_steps=args.steps)
        step = jax.jit(step, donate_argnums=(0, 1))

        loop = TrainLoop(
            TrainLoopConfig(total_steps=args.steps,
                            checkpoint_every=args.checkpoint_every,
                            checkpoint_dir=args.checkpoint_dir,
                            handle_sigterm=True),
            step, data, params, opt_state, shardings=None)
        out = loop.run()
    print(f"done: {out['final_step']} steps, "
          f"loss {out['history'][0]['loss']:.4f} -> {out['history'][-1]['loss']:.4f}, "
          f"stragglers={out['stragglers']} restarts={out['restarts']}")


if __name__ == "__main__":
    main()
