import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init). For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract params/opt/cache/input ShapeDtypeStructs with
     NamedShardings (launch/specs.py) — no allocation anywhere,
  3. jits the real train/prefill/decode step and ``.lower().compile()``s it,
  4. prints ``memory_analysis()`` (fits-per-device proof) and
     ``cost_analysis()`` (FLOPs/bytes for the roofline),
  5. writes a JSON record consumed by EXPERIMENTS.md and the perf loop.

Usage:
  python -m repro.launch.dryrun --arch gemma3_27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out-dir artifacts/dryrun]
"""
import argparse
import json
import time
import traceback

import jax

import repro.api as falcon
from repro import compat
from repro.configs import SHAPE_CELLS, get_config, list_archs
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SP
from repro.models import model as M
from repro.optim import AdamWConfig
from repro.roofline.analysis import analyze_compiled
from repro.roofline.analytic import analytic_costs
from repro.core.hardware import TPU_V5E
from repro.train import steps as ST


def build_step_fn(cfg: ModelConfig, cell, mesh, cs: SP.CellSpec,
                  opt_dtype: str = "float32", microbatches: int = 1):
    if cs.kind == "train":
        fn = ST.make_train_step(cfg, AdamWConfig(state_dtype=opt_dtype),
                                microbatches=microbatches)
        donate = (0, 1)
    elif cs.kind == "prefill":
        fn = ST.make_prefill_step(cfg, max_len=cell.seq_len)
        donate = ()
    else:
        fn = ST.make_decode_step(cfg)
        donate = (1,)
    return jax.jit(fn, donate_argnums=donate)


def model_flops_for(cs: SP.CellSpec, cell, cfg) -> float:
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cell.kind == "train":
        return 6.0 * cs.n_active_params * tokens
    return 2.0 * cs.n_active_params * tokens


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str | None,
             falcon_mode: str | None = None, fsdp: int | None = None,
             remat: int | None = None, parallel_style: str | None = None,
             parallel_block: int | None = None, opt_dtype: str | None = None,
             remat_policy: str | None = None, capacity_factor: float | None = None,
             microbatches: int = 1, batch_scale: int = 1,
             falcon_backend: str | None = None,
             tag: str = "", notes: str = "") -> dict:
    import dataclasses

    from repro.parallel import sharding as SHH

    cfg = get_config(arch)
    if falcon_mode is not None:
        cfg = dataclasses.replace(cfg, falcon_mode=falcon_mode,
                                  use_falcon=falcon_mode != "off")
    if fsdp is not None:
        cfg = dataclasses.replace(cfg, fsdp=bool(fsdp))
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=bool(remat))
    if parallel_style is not None:
        cfg = dataclasses.replace(cfg, parallel_style=parallel_style)
    if parallel_block is not None:
        cfg = dataclasses.replace(cfg, parallel_block=bool(parallel_block))
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if capacity_factor is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if falcon_backend is not None:
        cfg = dataclasses.replace(cfg, falcon_backend=falcon_backend)
    SHH.set_parallel_style(cfg.parallel_style)
    cell = SHAPE_CELLS[shape]
    if batch_scale != 1:
        import dataclasses as _dc
        cell = _dc.replace(cell, global_batch=cell.global_batch * batch_scale)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "tag": tag,
                 "opt_dtype": opt_dtype or "float32",
                 "falcon_mode": cfg.falcon_mode if cfg.use_falcon else "off"}
    ok, why = SP.cell_applicable(cfg, cell)
    if not ok:
        rec.update(status="skipped", reason=why)
        _emit(rec, out_dir)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(len(mesh.devices.reshape(-1)))
    t0 = time.time()
    try:
        cs = SP.input_specs(cfg, cell, mesh, opt_dtype=opt_dtype or "float32")
        step = build_step_fn(cfg, cell, mesh, cs, opt_dtype=opt_dtype or "float32",
                             microbatches=microbatches)
        with compat.set_mesh(mesh), \
                falcon.use(M.falcon_config_for(cfg, dict(mesh.shape))):
            lowered = step.lower(*cs.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            print(f"[{arch} x {shape} x {mesh_name}] memory_analysis:", ma)
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            print(f"[{arch} x {shape} x {mesh_name}] cost_analysis: "
                  f"flops={ca.get('flops', 0):.3e} bytes={ca.get('bytes accessed', 0):.3e}")
            rep = analyze_compiled(
                compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                chips=chips, model_flops=model_flops_for(cs, cell, cfg),
                notes=notes)
        # analytic roofline terms (primary: corrects while-body-once counting)
        ac = analytic_costs(cfg, cell, dict(mesh.shape), cs.n_params,
                            cs.n_active_params,
                            opt_dtype=opt_dtype or "float32")
        t_c, t_m, t_l = ac.terms(TPU_V5E, cfg.dtype)
        terms = {"compute": t_c, "memory": t_m, "collective": t_l}
        bott = max(terms, key=terms.get)
        step_time = max(terms.values())
        mf = model_flops_for(cs, cell, cfg)
        rec["analytic"] = {
            "flops_dev": ac.flops, "hbm_bytes_dev": ac.hbm_bytes,
            "coll_bytes_dev": ac.coll_bytes,
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_l,
            "bottleneck": bott, "step_time": step_time,
            "model_flops": mf,
            "useful_ratio": mf / (ac.flops * chips) if ac.flops else 0.0,
            "roofline_fraction": (mf / chips) / step_time / TPU_V5E.flops_for(cfg.dtype)
                                 if step_time > 0 else 0.0,
            "detail": ac.detail,
        }
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   n_params=cs.n_params, n_active_params=cs.n_active_params,
                   argument_bytes=int(ma.argument_size_in_bytes),
                   temp_bytes=int(ma.temp_size_in_bytes),
                   output_bytes=int(ma.output_size_in_bytes),
                   roofline=rep.to_dict())
    except Exception as e:  # noqa: BLE001 - record the failure verbatim
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[{arch} x {shape} x {mesh_name}] FAILED: {e}")
    _emit(rec, out_dir)
    return rec


def _emit(rec: dict, out_dir: str | None):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{rec['tag']}" if rec.get("tag") else (
        f"_{rec['falcon_mode']}" if rec.get("falcon_mode") not in (None, "auto") else "")
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--falcon-mode", default=None,
                    help="override: off|auto|<scheme> (perf experiments)")
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--remat", type=int, default=None)
    ap.add_argument("--parallel-style", default=None, choices=["tp", "fsdp_only"])
    ap.add_argument("--parallel-block", type=int, default=None)
    ap.add_argument("--opt-dtype", default=None)
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--batch-scale", type=int, default=1)
    ap.add_argument("--falcon-backend", default=None)
    ap.add_argument("--tag", default="", help="suffix for the output record")
    ap.add_argument("--notes", default="")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPE_CELLS) if (args.all or not args.shape) else [args.shape]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, args.out_dir,
                               falcon_mode=args.falcon_mode, fsdp=args.fsdp,
                               remat=args.remat,
                               parallel_style=args.parallel_style,
                               parallel_block=args.parallel_block,
                               opt_dtype=args.opt_dtype,
                               remat_policy=args.remat_policy,
                               capacity_factor=args.capacity_factor,
                               microbatches=args.microbatches,
                               batch_scale=args.batch_scale,
                               falcon_backend=args.falcon_backend,
                               tag=args.tag, notes=args.notes)
                if rec["status"] == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
