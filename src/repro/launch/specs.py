"""ShapeDtypeStruct input specs for every (arch x shape-cell) dry-run cell.

Everything here is abstract (no allocation): parameters and optimizer state
come from ``jax.eval_shape`` over the real init functions, inputs are
ShapeDtypeStructs with NamedShardings attached. The dry-run lowers the exact
step functions used by training/serving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import sharding as SH

__all__ = ["cell_applicable", "input_specs", "abstract_state", "CellSpec"]


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return True, ""


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def attach(tree_sds, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_shardings)


@dataclasses.dataclass
class CellSpec:
    kind: str                 # train | prefill | decode
    args: tuple               # positional SDS args for the step fn
    params: object            # params SDS (with shardings)
    opt_state: object | None
    rules: SH.ShardingRules
    n_params: int
    n_active_params: int


def _param_count(params_sds) -> tuple[int, int]:
    """(total, active) param counts; MoE experts count k/E of their size."""
    total = active = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        active += n  # corrected below for experts by caller (needs cfg)
    return total, active


def abstract_state(cfg: ModelConfig, mesh: Mesh, need_opt: bool,
                   seq_shard: bool = False, opt_dtype: str = "float32"):
    rules = SH.make_rules(mesh, fsdp=cfg.fsdp, seq_shard=seq_shard,
                          style=cfg.parallel_style)
    params_sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = SH.param_sharding(params_sds, mesh, rules)
    params = attach(params_sds, params_sh)
    opt = None
    if need_opt:
        opt_sds = jax.eval_shape(
            lambda p: adamw_init(p, AdamWConfig(state_dtype=opt_dtype)), params_sds)
        opt_sh = {
            "m": params_sh, "v": params_sh,
            "step": NamedSharding(mesh, P()),
        }
        opt = attach(opt_sds, opt_sh)
    # param counts (total vs active for MoE)
    total = 0
    expert = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "moe_" in pstr:
            expert += n
    active = total - expert
    if cfg.num_experts:
        active += expert * cfg.experts_per_token // cfg.num_experts
    return params, opt, rules, total, active


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                opt_dtype: str = "float32") -> CellSpec:
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        raise ValueError(f"{cfg.name} x {cell.name} skipped: {why}")
    seq_shard = cell.name == "long_500k"
    params, opt, rules, total, active = abstract_state(
        cfg, mesh, need_opt=(cell.kind == "train"), seq_shard=seq_shard,
        opt_dtype=opt_dtype)
    b_axes = rules.batch
    B, S = cell.global_batch, cell.seq_len
    sizes = dict(rules.axis_sizes)
    nb = int(np.prod([sizes.get(a, 1) for a in b_axes]))
    batch_axis = b_axes if B % nb == 0 else None

    def tok_sds(shape):
        spec = (batch_axis,) + (None,) * (len(shape) - 1)
        return _sds(shape, jnp.int32, NamedSharding(mesh, P(*spec)))

    extra = {}
    if cfg.frontend == "audio_codebooks":
        mk_tokens = lambda s: tok_sds((B, s, cfg.num_codebooks))
    else:
        mk_tokens = lambda s: tok_sds((B, s))

    if cell.kind == "train":
        s_text = S - cfg.num_patches if cfg.frontend == "vision_patches" else S
        batch = {"tokens": mk_tokens(s_text), "labels": mk_tokens(s_text)}
        if cfg.frontend == "vision_patches":
            batch["patch_embeds"] = _sds(
                (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype),
                NamedSharding(mesh, P(batch_axis, None, None)))
        step_sds = _sds((), jnp.int32, NamedSharding(mesh, P()))
        args = (params, opt, batch, step_sds)
        return CellSpec("train", args, params, opt, rules, total, active)

    if cell.kind == "prefill":
        s_text = S - cfg.num_patches if cfg.frontend == "vision_patches" else S
        args = [params, mk_tokens(s_text)]
        if cfg.frontend == "vision_patches":
            args.append(_sds((B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype),
                             NamedSharding(mesh, P(batch_axis, None, None))))
        return CellSpec("prefill", tuple(args), params, None, rules, total, active)

    # decode: one new token against an S-long cache.
    # KV cache layout (L, B, S, Hkv, hd): batch over the DP axes; the model
    # axis goes on KV heads when divisible, else on the SEQUENCE (sequence-
    # parallel KV cache — required when Hkv < model parallelism, and for
    # long_500k where batch=1 offers no parallelism at all).
    cache_sds = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    nmodel = sizes.get("model", 1)
    if cell.name == "long_500k":
        kv_spec = [None, None, ("data", "model"), None, None]
        st_spec = [None, None, "model", None, None]
    elif cfg.num_kv_heads % max(nmodel, 1) == 0 and cfg.num_kv_heads:
        kv_spec = [None, batch_axis, None, "model", None]
        st_spec = [None, batch_axis, "model", None, None]
    else:
        kv_spec = [None, batch_axis, "model", None, None]
        st_spec = [None, batch_axis, "model", None, None]
    cache_sh = {}
    for k in cache_sds:
        dims = cache_sds[k].shape
        spec = kv_spec if k in ("k", "v") else st_spec
        spec = [a if a is None or dims[i] % _axsize(rules, a) == 0 else None
                for i, a in enumerate(spec)]
        cache_sh[k] = NamedSharding(mesh, P(*spec))
    cache = attach(cache_sds, cache_sh)
    tokens = mk_tokens(1)
    index = _sds((), jnp.int32, NamedSharding(mesh, P()))
    return CellSpec("decode", (params, cache, tokens, index), params, None,
                    rules, total, active)


def _axsize(rules: SH.ShardingRules, axis) -> int:
    sizes = dict(rules.axis_sizes)
    axes = axis if isinstance(axis, tuple) else (axis,)
    return int(np.prod([sizes.get(a, 1) for a in axes]))
