"""Serving launcher: batched prefill + decode with the FalconGEMM backend.

``python -m repro.launch.serve --arch granite_3_2b --batch 4 --prompt-len 64
--gen 32`` runs prefill over a token batch and auto-regressive decode. The
FalconGEMM policy is installed once with ``falcon.use`` (context-scoped
config); static weights are lifted to ``PlannedWeight``s — the paper §IV-C
"offline Combine B": for every projection where the Decision Module selects
an LCMA, B̃ is combined once at load time and serving pays only
Combine A + the fused GEMM/Combine-H (``--no-precombine`` opts out). All
planning runs through the persistent plan cache (``--plan-cache``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro import compat
from repro.configs import get_config, smoke_config
from repro.core import plan_cache
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--precombine", action="store_true", default=True,
                    help="lift static weights to PlannedWeights (offline "
                         "Combine B) where the Decision Module picks an LCMA")
    ap.add_argument("--no-precombine", dest="precombine", action="store_false")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="persistent Decision plan cache (JSON, written by "
                         "repro.tools.tune); loaded before tracing and "
                         "flushed back on exit")
    args = ap.parse_args()

    if args.plan_cache:
        cache = plan_cache.configure(path=args.plan_cache)
        print(f"plan cache: {len(cache)} plans loaded from {args.plan_cache}")

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh()
    fcfg = M.falcon_config_for(cfg, dict(mesh.shape))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen

    tok_shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
                 if cfg.frontend == "audio_codebooks"
                 else (args.batch, args.prompt_len))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape), jnp.int32)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    with compat.set_mesh(mesh), falcon.use(fcfg):
        if args.precombine:
            # Offline Combine B against the prefill shape (the M the Decision
            # Module should price); decode re-decides per its own tiny M.
            params, n_planned = falcon.precombine_params(
                params, m_hint=args.batch * args.prompt_len)
            print(f"offline Combine B: {n_planned} weight tensor(s) "
                  f"precombined into PlannedWeights")
        t0 = time.perf_counter()
        if cfg.frontend == "vision_patches":
            pe = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.num_patches, cfg.d_model)), jnp.dtype(cfg.dtype))
            logits, cache = prefill(params, tokens, pe)
            pos0 = args.prompt_len + cfg.num_patches
        else:
            logits, cache = prefill(params, tokens)
            pos0 = args.prompt_len
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out_tokens = []
        t0 = time.perf_counter()
        for i in range(args.gen):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            if cfg.frontend == "audio_codebooks":
                tok = nxt[:, None, :] if nxt.ndim == 2 else jnp.tile(
                    nxt[:, None, None], (1, 1, cfg.num_codebooks))
            else:
                tok = nxt[:, None]
            out_tokens.append(np.asarray(nxt))
            logits, cache = decode(params, cache, tok, pos0 + i)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {t_decode/args.gen*1e3:.2f} ms/token "
          f"({args.batch * args.gen / t_decode:.1f} tok/s)")
    print("sample:", np.stack(out_tokens, 1)[0].reshape(-1)[:16].tolist())
    st = plan_cache.stats()
    print(f"plan cache: {st.hits} hits / {st.misses} misses "
          f"({st.hit_rate:.0%} hit rate, {len(plan_cache.default_cache())} plans)")
    if args.plan_cache:
        plan_cache.flush()


if __name__ == "__main__":
    main()
