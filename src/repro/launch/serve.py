"""Serving launcher: a thin CLI over the serving engines.

Two modes share the FalconGEMM serving stack (context-scoped config, offline
Combine B via ``PlannedWeight``, persistent plan cache):

* ``--continuous`` — the continuous-batching :class:`repro.serve.ServeEngine`:
  ``--requests N`` synthetic requests with ragged prompt lengths are admitted
  through bucketed prefill micro-batches and decoded with per-slot positions;
  the engine pre-plans and pre-compiles the whole bucket grid (``--no-warm``
  opts out) and prints the ``ServeStats`` surface (tokens/s, bucket hit rate,
  plan-cache hit rate, padding waste). See ``docs/serving.md``.

* default — the original one-shot batched prefill + autoregressive decode
  (every row advances in lockstep), kept for benchmarks and smoke tests.

``python -m repro.launch.serve --arch granite_3_2b --continuous --requests 32``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro import compat
from repro.configs import get_config, smoke_config
from repro.core import plan_cache
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.serve import ServeEngine, StepLoop
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--precombine", action="store_true", default=True,
                    help="lift static weights to PlannedWeights (offline "
                         "Combine B) where the Decision Module picks an LCMA")
    ap.add_argument("--no-precombine", dest="precombine", action="store_false")
    ap.add_argument("--quant", action="store_true",
                    help="serve with the int8-quantized decision tier: the "
                         "Decision Module prices quantized execution next to "
                         "fp under the accuracy budget, PlannedWeights carry "
                         "offline-quantized B̃q + scales, and warm() pre-"
                         "plans the quantized buckets (--continuous)")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="persistent Decision plan cache (JSON, written by "
                         "repro.tools.tune); loaded before tracing and "
                         "flushed back on exit")
    # continuous-batching engine
    ap.add_argument("--continuous", action="store_true",
                    help="serve --requests jobs through the continuous-"
                         "batching engine instead of one lockstep batch")
    ap.add_argument("--requests", type=int, default=32,
                    help="number of synthetic requests (--continuous)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="concurrent decode slots (--continuous)")
    ap.add_argument("--min-prompt-len", type=int, default=4,
                    help="ragged prompt lower bound (--continuous)")
    ap.add_argument("--warm", action="store_true", default=True,
                    help="pre-plan + pre-compile the bucket grid before "
                         "serving (--continuous)")
    ap.add_argument("--no-warm", dest="warm", action="store_false")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="shard the engine over a real (data, model) mesh, "
                         "e.g. --mesh 1,8 for 8-way tensor parallelism "
                         "(--continuous); simulate devices on one host with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--speculate", type=int, default=0, metavar="GAMMA",
                    help="speculative decoding: a self-draft proposes GAMMA "
                         "tokens per round, one (batch, GAMMA+1) verify "
                         "forward accepts greedily — token-exact, attention "
                         "families only (--continuous)")
    ap.add_argument("--draft-layers", type=int, default=None, metavar="N",
                    help="slice the draft to the target's first N layers "
                         "(default: all layers = identity draft)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix-KV cache: repeated or extended "
                         "prompts skip prefilling the shared prefix "
                         "(--continuous)")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="S",
                    help="split long prompts into S-token prefill chunks "
                         "(S must be a prefill bucket) interleaved with "
                         "decode (--continuous)")
    ap.add_argument("--stream", action="store_true",
                    help="print the first request's tokens as they are "
                         "emitted (--continuous)")
    args = ap.parse_args()
    args.mesh_shape = _parse_mesh(args.mesh)

    if args.plan_cache:
        cache = plan_cache.configure(path=args.plan_cache)
        print(f"plan cache: {len(cache)} plans loaded from {args.plan_cache}")

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    if args.continuous:
        _run_continuous(cfg, args)
    else:
        _run_oneshot(cfg, args)
    if args.plan_cache:
        plan_cache.flush()


def _parse_mesh(spec: str | None) -> dict:
    """"DATA,MODEL" -> {"data": DATA, "model": MODEL} (empty without --mesh)."""
    if not spec:
        return {}
    parts = [int(x) for x in spec.replace("x", ",").split(",") if x]
    if len(parts) != 2 or min(parts) < 1:
        raise SystemExit(f"--mesh expects DATA,MODEL (e.g. 1,8); got {spec!r}")
    return {"data": parts[0], "model": parts[1]}


def _run_continuous(cfg, args) -> None:
    engine = ServeEngine(
        cfg, max_slots=args.max_slots, max_prompt_len=args.prompt_len,
        max_new_tokens=args.gen, precombine=args.precombine, seed=args.seed,
        mesh_shape=args.mesh_shape, quantize=args.quant,
        speculate=args.speculate, draft_keep_layers=args.draft_layers,
        prefix_cache=args.prefix_cache, prefill_chunk=args.prefill_chunk)
    if engine.mesh is not None:
        print(f"mesh: {dict(engine.mesh.shape)} over "
              f"{len(jax.devices())} visible device(s)")
    print(f"engine: {args.max_slots} slots, cache len {engine.max_len}, "
          f"{engine.n_precombined} weight tensor(s) precombined"
          f"{' (int8-quantized tier on)' if args.quant else ''}, buckets "
          f"seq={list(engine.policy.prefill_seq)} "
          f"prefill_batch={list(engine.policy.prefill_batch)} "
          f"decode_batch={list(engine.policy.decode_batch)}")
    if args.warm:
        w = engine.warm()
        print(f"warmup: {w['plans']} Decision plans, {w['shapes']} step "
              f"shapes compiled in {w['seconds']:.1f}s")
    rng = np.random.default_rng(args.seed)
    lo = min(args.min_prompt_len, args.prompt_len)
    first = None
    for i in range(args.requests):
        plen = int(rng.integers(lo, args.prompt_len + 1))
        req = engine.submit(
            rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=int(rng.integers(1, args.gen + 1)),
            on_token=((lambda r, t: print(f"  rid={r.rid} token {t}"))
                      if args.stream and i == 0 else None))
        first = first or req
    t0 = time.perf_counter()
    done = StepLoop(engine).run_until_idle()
    wall = time.perf_counter() - t0
    s = engine.summary()
    print(f"served {len(done)}/{args.requests} requests in {wall:.2f}s: "
          f"{s['prompt_tokens']} prompt + {s['generated_tokens']} generated "
          f"tokens ({s['tokens_per_s']:.1f} tok/s real, "
          f"{s['decode_tokens_per_s']:.1f} decode tok/s)")
    print(f"steps: {s['prefill_steps']} prefill + {s['decode_steps']} decode "
          f"+ {s['verify_steps']} verify | "
          f"bucket hit rate {s['bucket_hit_rate']:.1%} | "
          f"padding waste {s['padding_waste']:.1%}")
    if args.speculate:
        print(f"speculation: gamma={args.speculate}, acceptance rate "
              f"{s['acceptance_rate']:.1%} "
              f"({s['accepted_tokens']}/{s['drafted_tokens']} drafts kept)")
    if args.prefix_cache and s.get("prefix_cache"):
        p = s["prefix_cache"]
        print(f"prefix cache: {p['hits']} hits / {p['misses']} misses, "
              f"{s['prefix_tokens_reused']} prompt tokens reused, "
              f"{p['entries']} entries ({p['evictions']} evicted)")
    pc = s["plan_cache"]
    print(f"plan cache: {pc['hits']} hits / {pc['misses']} misses "
          f"({pc['hit_rate']:.0%} hit rate, {pc['entries']} plans)")
    if done:
        sample = done[0]
        print(f"sample (rid={sample.rid}): {sample.generated[:16]}")


def _run_oneshot(cfg, args) -> None:
    mesh = make_local_mesh()
    fcfg = M.falcon_config_for(cfg, dict(mesh.shape))
    if args.quant:
        fcfg = dataclasses.replace(fcfg, quantize=True)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen

    tok_shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
                 if cfg.frontend == "audio_codebooks"
                 else (args.batch, args.prompt_len))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape), jnp.int32)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    with compat.set_mesh(mesh), falcon.use(fcfg):
        if args.precombine:
            # Offline Combine B against the prefill shape (the M the Decision
            # Module should price); decode re-decides per its own tiny M.
            params, n_planned = falcon.precombine_params(
                params, m_hint=args.batch * args.prompt_len)
            print(f"offline Combine B: {n_planned} weight tensor(s) "
                  f"precombined into PlannedWeights")
        t0 = time.perf_counter()
        if cfg.frontend == "vision_patches":
            pe = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.num_patches, cfg.d_model)), jnp.dtype(cfg.dtype))
            logits, cache = prefill(params, tokens, pe)
            pos0 = args.prompt_len + cfg.num_patches
        else:
            logits, cache = prefill(params, tokens)
            pos0 = args.prompt_len
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out_tokens = []
        t0 = time.perf_counter()
        for i in range(args.gen):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            if cfg.frontend == "audio_codebooks":
                tok = nxt[:, None, :] if nxt.ndim == 2 else jnp.tile(
                    nxt[:, None, None], (1, 1, cfg.num_codebooks))
            else:
                tok = nxt[:, None]
            out_tokens.append(np.asarray(nxt))
            logits, cache = decode(params, cache, tok, pos0 + i)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {t_decode/args.gen*1e3:.2f} ms/token "
          f"({args.batch * args.gen / t_decode:.1f} tok/s)")
    print("sample:", np.stack(out_tokens, 1)[0].reshape(-1)[:16].tolist())
    st = plan_cache.stats()
    print(f"plan cache: {st.hits} hits / {st.misses} misses "
          f"({st.hit_rate:.0%} hit rate, {len(plan_cache.default_cache())} plans)")


if __name__ == "__main__":
    main()
