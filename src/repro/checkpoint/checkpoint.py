"""Fault-tolerant checkpointing: atomic, resharding-on-load, async, integrity.

Layout:  <dir>/step_<N>/manifest.json + arrays.npz
  * writes go to ``step_<N>.tmp`` then ``os.replace`` => a crash mid-save can
    never corrupt the latest checkpoint (atomic-rename protocol),
  * the manifest stores the flattened tree structure, shapes, dtypes and a
    sha256 of the array payload => bit-rot / truncation is detected at load,
  * arrays are saved as *full logical arrays* (gathered), so a restart may use
    a different mesh/topology: restore() re-shards onto whatever shardings the
    caller provides — this is the elastic-scaling path (shrink/grow pods),
  * ``CheckpointManager`` adds async save (host copy happens synchronously,
    disk write on a background thread), retention, and preemption-safe flush.

bfloat16 leaves are stored as uint16 views (npz has no bf16).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]

_BF16 = "bfloat16"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def _to_np(x):
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), _BF16
    return x, str(x.dtype)


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomic full-logical-array checkpoint. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys, leaves, _ = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, leaf in zip(keys, leaves):
        arr, dt = _to_np(leaf)
        arrays[k] = arr
        dtypes[k] = dt
    buf = io.BytesIO()
    np.savez(buf, **{k.replace("/", "__"): v for k, v in arrays.items()})
    payload = buf.getvalue()
    digest = hashlib.sha256(payload).hexdigest()
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(payload)
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": dtypes,
        "shapes": {k: list(np.shape(a)) for k, a in arrays.items()},
        "sha256": digest,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        # replaying after a restore overwrites the stale future checkpoint;
        # latest_step() ignores manifest-less dirs, so a crash inside this
        # window only loses this one step, never an older checkpoint.
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like``; re-shard onto ``shardings``.

    ``tree_like`` may be arrays or ShapeDtypeStructs (shape donor). The mesh
    used at save time is irrelevant — this is the elastic restart path.
    Returns (tree, step, extra).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.npz"), "rb") as f:
        payload = f.read()
    if verify:
        digest = hashlib.sha256(payload).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} failed integrity check")
    npz = np.load(io.BytesIO(payload))

    keys, leaves, treedef = _flatten(tree_like)
    if keys != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(keys)
        raise ValueError(f"checkpoint/model structure mismatch: {sorted(missing)[:8]}")
    sh_leaves = None
    if shardings is not None:
        _, sh_leaves, _ = _flatten(shardings)
    out = []
    for i, (k, like) in enumerate(zip(keys, leaves)):
        arr = npz[k.replace("/", "__")]
        if manifest["dtypes"][k] == _BF16:
            arr = arr.view(jnp.bfloat16)
        if sh_leaves is not None:
            arr = jax.device_put(arr, sh_leaves[i])
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, manifest.get("extra", {})


class CheckpointManager:
    """Async save + retention + preemption-safe flush."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:  # pragma: no cover
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                raise self._error

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, tree_like, shardings=None):
        return restore_checkpoint(self.directory, tree_like, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", name))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
