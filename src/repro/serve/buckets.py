"""Shape buckets: the fixed grid of step shapes the engine ever launches.

Serving traffic presents ragged, shifting M — prompt lengths and batch
occupancy change every step. Rather than tracing (and re-planning) a fresh
shape per step, every micro-batch is padded up to a bucket from a small
power-of-two grid, so each step runs a shape whose FalconGEMM plan is already
decided, precombined and jit-compiled. The policy fixes:

* **prefill buckets** — (batch, padded sequence) pairs; M = batch x seq,
* **decode buckets**  — padded batch sizes; M = batch (one token per slot).

Padding is pure waste, so buckets grow geometrically: waste is bounded at
<50% of the step (amortized far less) while the number of distinct compiled
shapes stays logarithmic in the range served.
"""
from __future__ import annotations

import dataclasses

__all__ = ["next_pow2", "BucketPolicy"]


def next_pow2(n: int) -> int:
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def _pow2_range(lo: int, hi: int) -> tuple[int, ...]:
    out, v = [], next_pow2(max(lo, 1))
    while v < hi:
        out.append(v)
        v *= 2
    out.append(next_pow2(hi))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The step-shape grid for one engine instance."""

    prefill_seq: tuple[int, ...]        # padded prompt lengths (pow2, sorted)
    prefill_batch: tuple[int, ...]      # prefill micro-batch sizes (pow2, sorted)
    decode_batch: tuple[int, ...]       # decode micro-batch sizes (pow2, sorted)

    @classmethod
    def build(cls, max_prompt_len: int, max_slots: int,
              min_seq: int = 8, max_prefill_batch: int | None = None
              ) -> "BucketPolicy":
        mpb = min(max_prefill_batch or max_slots, max_slots)
        return cls(
            prefill_seq=_pow2_range(min_seq, max_prompt_len),
            prefill_batch=_pow2_range(1, mpb),
            decode_batch=_pow2_range(1, max_slots),
        )

    def __post_init__(self):
        for name in ("prefill_seq", "prefill_batch", "decode_batch"):
            vals = getattr(self, name)
            if not vals or list(vals) != sorted(set(vals)):
                raise ValueError(f"{name} must be non-empty, sorted, unique: {vals}")

    @staticmethod
    def _fit(n: int, grid: tuple[int, ...], what: str) -> int:
        for b in grid:
            if n <= b:
                return b
        raise ValueError(f"{what}={n} exceeds the largest bucket {grid[-1]}")

    def seq_bucket(self, prompt_len: int) -> int:
        """Smallest prefill sequence bucket holding ``prompt_len`` tokens."""
        return self._fit(prompt_len, self.prefill_seq, "prompt_len")

    def prefill_batch_bucket(self, n_requests: int) -> int:
        return self._fit(n_requests, self.prefill_batch, "n_requests")

    def decode_batch_bucket(self, n_active: int) -> int:
        return self._fit(n_active, self.decode_batch, "n_active")

    # -- enumeration (warmup) ----------------------------------------------

    def prefill_shapes(self) -> list[tuple[int, int]]:
        """Every (batch, seq) prefill step shape this policy can launch."""
        return [(b, s) for b in self.prefill_batch for s in self.prefill_seq]

    def bucket_ms(self) -> list[int]:
        """Every activation-row count M a step can present to the Decision
        Module — the grid ``core.engine.warm_buckets`` pre-plans."""
        ms = {b * s for (b, s) in self.prefill_shapes()}
        ms.update(self.decode_batch)
        return sorted(ms)
