"""ServeEngine: continuous batching over bucketed, pre-planned step shapes.

The engine owns a fixed set of KV-cache **slots**. Requests are admitted by
the :class:`~repro.serve.scheduler.Scheduler` into free slots via bucketed
prefill micro-batches (prompts right-padded to a power-of-two sequence
bucket, per-row last-token indices pick the true logits), then advance over
the active slots in decode micro-batches padded to a power-of-two batch
bucket. Every step therefore launches a shape from the closed
:class:`~repro.serve.buckets.BucketPolicy` grid, so after :meth:`warm`:

* the FalconGEMM Decision Module is a pure plan-cache hit per projection
  (``core.engine.warm_buckets`` pre-planned the bucket grid),
* static weights are already lifted to precombined :class:`PlannedWeight`\\ s
  (offline Combine B ran once at load),
* jit never re-traces — each bucket shape's executable exists.

On top of the PR 3 base the engine serves four production decode features,
all riding the same bucket grid (docs/serving.md has the full story):

* **speculative decoding** (``speculate=γ``): a :class:`DraftModel` proposes
  γ tokens, one ``(B, γ+1)`` verify forward scores them, greedy
  accept/rollback emits 1..γ+1 tokens per round — token-exact vs. the
  non-speculative engine by construction (``serve/speculative.py``).
* **prefix KV reuse** (``prefix_cache=True``): finished prefills snapshot
  their slot KV into a radix cache keyed by prompt tokens; a later request
  sharing a prefix prefills only the suffix (``serve/prefix_cache.py``).
* **chunked prefill** (``prefill_chunk=N``): long prompts prefill in
  full-bucket chunks the scheduler interleaves with decode work.
* **token streaming**: ``submit(stream=True)`` / ``on_token=`` deliver
  tokens as ``_emit`` produces them.

Correctness of padding: pad rows/positions never leak. Right-padded prefill
writes pad K/V above each request's true length, but decode validity masks
``kpos < pos + S`` and each write covers its positions before they first
become visible — the same argument covers rejected speculative drafts and
chunk boundaries; pad *rows* of a micro-batch are sliced off before the slot
cache update. The engine output is token-exact vs. per-request eager decode
(``tests/test_serve_engine.py``, ``tests/test_serve_spec.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue as _queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro import compat
from repro.configs.base import ModelConfig
from repro.core import engine as core_engine, plan_cache
from repro.models import model as M
from repro.parallel import sharding as SH
from repro.train.steps import (make_chunk_prefill_step, make_decode_step,
                               make_verify_step)

from .buckets import BucketPolicy, next_pow2
from .prefix_cache import RadixPrefixCache
from .request import Request, RequestQueue
from .scheduler import DecodeWork, PrefillWork, Scheduler
from .speculative import DraftModel, SelfDraft
from .stats import ServeStats

__all__ = ["ServeEngine", "StepLoop"]


class ServeEngine:
    """Continuous-batching serve engine for one model.

    ``submit`` is thread-safe (any frontend thread); ``step``/``run`` are the
    single consumer. All decoder families serve: dense/hybrid KV-cache
    attention is exact under causal masking + decode validity, and SSM/hybrid
    recurrent state is exact because the serve prefill step zeroes dt on
    right-pad positions (see ``make_chunk_prefill_step``). MoE routing is
    approximate under padding (pad rows contend for expert capacity) but
    pad rows are sliced off before the slot cache update. Non-token
    frontends (audio codebooks, vision patches) are rejected — the bucket
    grid assumes one int token stream.

    ``speculate=γ`` turns decode steps into speculative rounds (draft γ,
    verify in one forward, accept greedily — token-exact). Restricted to the
    ``dense``/``moe`` families: recurrent SSM state cannot roll back a
    rejected draft, while attention KV rollback is free (validity masking).
    The draft defaults to the identity :class:`SelfDraft` (every layer kept,
    acceptance ≈ 1) — pass ``draft_keep_layers`` for a truncated self-draft
    or ``draft=`` for any :class:`DraftModel`.

    ``mesh_shape={"data": d, "model": m}`` spanning more than one device
    lifts the engine onto a real mesh: weights shard tensor-parallel by the
    ``parallel.sharding`` rule table (offline Combine B then runs on sharded
    weights), the KV cache stays replicated (decode activations gather back
    each step — "replicated-then-gathered"), and every jitted step runs under
    the mesh context so FalconGEMM's shard-aware plans and ``shard_act``
    constraints see it. Simulate devices on one host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 max_slots: int = 8, max_prompt_len: int = 64,
                 max_new_tokens: int = 32, policy: BucketPolicy | None = None,
                 precombine: bool = True, record_logits: bool = False,
                 seed: int = 0, mesh_shape: dict | None = None,
                 quantize: bool = False, speculate: int = 0,
                 draft: DraftModel | None = None,
                 draft_keep_layers: int | None = None,
                 prefix_cache: bool = False, prefix_entries: int = 32,
                 prefill_chunk: int | None = None,
                 max_consecutive_prefills: int = 2):
        if model_cfg.frontend:
            raise NotImplementedError(
                f"ServeEngine serves token-stream decoders; got "
                f"frontend={model_cfg.frontend!r} (bucketed prefill assumes "
                "one int token stream)")
        self.cfg = model_cfg
        self.gamma = int(speculate)
        if self.gamma < 0:
            raise ValueError(f"speculate must be >= 0, got {speculate}")
        if self.gamma and model_cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"speculate requires a rollback-free cache; family="
                f"{model_cfg.family!r} carries recurrent state that cannot "
                "un-advance past rejected draft tokens")
        self.max_prompt_len = max_prompt_len
        self.prefill_chunk = prefill_chunk
        # with chunking, the bucket grid tops out at the chunk size — longer
        # prompts run as several full-chunk micro-batches
        pol_max_seq = min(max_prompt_len, prefill_chunk) if prefill_chunk \
            else max_prompt_len
        self.policy = policy or BucketPolicy.build(pol_max_seq, max_slots)
        self.max_slots = max_slots
        self.max_new_tokens_cap = max_new_tokens
        # speculation writes up to γ provisional positions past the last
        # committed token, so the slot length budgets for them
        self.max_len = next_pow2(
            max(self.policy.prefill_seq[-1], max_prompt_len)
            + max_new_tokens + self.gamma)
        self.record_logits = record_logits
        self.mesh_shape = dict(mesh_shape or {})
        self.mesh = self._build_mesh(self.mesh_shape)
        self.quantize = bool(quantize)
        self.fcfg = M.falcon_config_for(model_cfg, self.mesh_shape)
        if self.quantize:
            # int8-quantized serving: the Decision Module prices the quant
            # tier alongside fp (plan-cache keys gain the quant token),
            # precombine below bakes B̃q + scales into each PlannedWeight,
            # and warm() pre-plans the quantized buckets.
            self.fcfg = dataclasses.replace(self.fcfg, quantize=True)
        with falcon.use(self.fcfg), self._mesh_ctx():
            self.params = params if params is not None \
                else M.init_params(model_cfg, jax.random.PRNGKey(seed))
            if self.mesh is not None:
                # Tensor-parallel at rest: shard raw weights by the rule table
                # BEFORE precombine, so offline Combine B runs on (and its B̃
                # output inherits) the sharded layout.
                rules = SH.make_rules(self.mesh)
                self.params = jax.device_put(
                    self.params, SH.param_sharding(self.params, self.mesh, rules))
            self.draft: DraftModel | None = draft
            if self.gamma and self.draft is None:
                # built from RAW params: a layer slice of a precombined tree
                # would tear PlannedWeights; the draft precombines its own
                # sliced copy below alongside the target
                self.draft = SelfDraft(model_cfg, self.params,
                                       max_slots=max_slots,
                                       max_len=self.max_len,
                                       keep_layers=draft_keep_layers)
            self.n_precombined = 0
            if precombine:
                # Offline Combine B priced at the largest prefill bucket M;
                # each step re-decides per its actual bucket M (plan-cached).
                m_hint = self.policy.prefill_batch[-1] * self.policy.prefill_seq[-1]
                self.params, self.n_precombined = falcon.precombine_params(
                    self.params, m_hint=m_hint)
                if isinstance(self.draft, SelfDraft):
                    self.draft.params, _ = falcon.precombine_params(
                        self.draft.params, m_hint=m_hint)
        self.queue = RequestQueue()
        self.scheduler = Scheduler(
            self.queue, self.policy, max_slots,
            max_consecutive_prefills=max_consecutive_prefills,
            prefill_chunk=prefill_chunk)
        self.stats = ServeStats()
        self.requests: list[Request] = []
        self.prefix = RadixPrefixCache(max_entries=prefix_entries) \
            if prefix_cache else None
        self.cache = M.init_cache(model_cfg, max_slots, self.max_len)
        if self.mesh is not None:
            # Replicated-then-gathered decode: the KV cache lives replicated on
            # every device; each step's projections run tensor-parallel and the
            # (small) per-step activations gather back before the cache write.
            from jax.sharding import NamedSharding, PartitionSpec as P
            self.cache = jax.device_put(
                self.cache, NamedSharding(self.mesh, P()))
        self.pos = np.zeros(max_slots, np.int32)   # per-slot next write index
        self._prefill_fn = jax.jit(make_chunk_prefill_step(model_cfg))
        self._decode_fn = jax.jit(make_decode_step(model_cfg))
        self._verify_fn = jax.jit(make_verify_step(model_cfg))
        self._compiled: set[tuple] = set()          # step shapes already traced
        self._submit_lock = threading.Lock()

    # -- mesh ----------------------------------------------------------------

    @staticmethod
    def _build_mesh(mesh_shape: dict):
        """A real ("data", "model") mesh when ``mesh_shape`` spans > 1 device."""
        total = 1
        for v in mesh_shape.values():
            total *= int(v)
        if total <= 1:
            return None
        ndev = len(jax.devices())
        if total > ndev:
            raise ValueError(
                f"mesh_shape {mesh_shape} needs {total} devices but only "
                f"{ndev} are visible; simulate with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={total}")
        d = int(mesh_shape.get("data", 1)) * int(mesh_shape.get("pod", 1))
        m = int(mesh_shape.get("model", 1))
        return compat.make_mesh((d, m), ("data", "model"))

    def _mesh_ctx(self):
        return compat.set_mesh(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()

    # -- admission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int | None = None,
               eos_id: int | None = None, stream: bool = False,
               on_token=None) -> Request:
        """Queue one generation request.

        ``stream=True`` attaches a consumer queue — iterate
        ``req.token_stream()`` from any thread while the engine steps.
        ``on_token(req, tok)`` is called synchronously from the step loop for
        every emitted token (keep it cheap — it sits on the decode path).
        """
        req = Request(prompt=prompt,
                      max_new_tokens=max_new_tokens or self.max_new_tokens_cap,
                      eos_id=eos_id)
        if self.prefill_chunk:
            if req.prompt_len > self.max_prompt_len:
                raise ValueError(
                    f"prompt_len={req.prompt_len} exceeds engine "
                    f"max_prompt_len={self.max_prompt_len}")
        else:
            self.policy.seq_bucket(req.prompt_len)  # raises if off-grid
        if req.max_new_tokens > self.max_new_tokens_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} exceeds engine cap "
                f"{self.max_new_tokens_cap} (cache is sized for the cap)")
        if stream:
            req.stream_q = _queue.Queue()
        req.on_token = on_token
        with self._submit_lock:                     # frontend threads race here
            self._lookup_prefix(req)
            self.queue.submit(req)
            self.requests.append(req)
            self.stats.requests_admitted += 1
        return req

    def _lookup_prefix(self, req: Request) -> None:
        """Pin the longest cached prefix of ``prompt[:-1]`` for this request.

        The last prompt token is always excluded so at least one suffix token
        prefills — the request's first logits are always freshly computed,
        and an SSM/hybrid snapshot (state valid only at its exact length) is
        only ever resumed at exactly that length.
        """
        if self.prefix is None:
            return
        n, entry = (0, None) if req.prompt_len < 2 else \
            self.prefix.lookup(req.prompt[:-1], pin=True)
        if entry is not None and self.draft is not None \
                and "draft" not in entry.payload:
            self.prefix.release(entry)              # no draft KV: unusable
            entry = None
        if entry is None:
            self.stats.prefix_misses += 1
            return
        req.prefix_len, req.prefix_entry = n, entry
        self.stats.prefix_hits += 1
        self.stats.prefix_tokens_reused += n

    # -- warmup --------------------------------------------------------------

    def warm(self) -> dict:
        """Pre-plan + pre-compile the whole bucket grid.

        1. ``core.engine.warm_buckets`` runs the Decision Module for every
           contraction the workload registry enumerates at every (batch, seq)
           bucket of the grid — dense projections, grouped MoE expert FFNs,
           attention and SSD scan/decode contractions, plus (under
           ``speculate=γ``) the ``(b, γ+1)`` verify and ``(b, 2)`` draft
           catch-up contexts — so serve-time traces only hit the plan cache,
           including from concurrent engines sharing a warmed cache file.
        2. Each (phase, shape) step function — prefill chunks, decode or
           verify rounds, and the draft's own steps — is traced and compiled
           once on zero inputs, so no live request ever pays a compile.
        """
        t0 = time.perf_counter()
        grid = (list(self.policy.prefill_shapes())
                + [(b, 1) for b in self.policy.decode_batch])
        with falcon.use(self.fcfg), self._mesh_ctx():
            n_plans = core_engine.warm_buckets(
                self.fcfg, self.cfg, grid,
                dtype=str(self.cfg.dtype), mesh_shape=self.mesh_shape,
                kv_len=self.max_len, spec_gamma=self.gamma or None)
            for (b, s) in self.policy.prefill_shapes():
                rows_b = self._broadcast_rows(self.cache, b)
                jax.block_until_ready(self._prefill_fn(
                    self.params, rows_b, jnp.zeros((b, s), jnp.int32),
                    jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32)))
                self._compiled.add(("prefill", b, s))
            for b in self.policy.decode_batch:
                rows_b = self._broadcast_rows(self.cache, b)
                if self.gamma:
                    jax.block_until_ready(self._verify_fn(
                        self.params, rows_b,
                        jnp.zeros((b, self.gamma + 1), jnp.int32),
                        jnp.zeros((b,), jnp.int32)))
                    self._compiled.add(("spec", b))
                else:
                    jax.block_until_ready(self._decode_fn(
                        self.params, rows_b, jnp.zeros((b, 1), jnp.int32),
                        jnp.zeros((b,), jnp.int32)))
                    self._compiled.add(("decode", b))
            if self.draft is not None:
                self.draft.warm(self.policy, self.gamma)
        self.stats.warm_plans = n_plans
        self.stats.warmed_shapes = len(self._compiled)
        self.stats.t_warm = time.perf_counter() - t0
        return {"plans": n_plans, "shapes": len(self._compiled),
                "seconds": self.stats.t_warm}

    @staticmethod
    def _broadcast_rows(cache, b: int):
        return jax.tree.map(
            lambda c: jnp.broadcast_to(
                c[:, :1], (c.shape[0], b) + c.shape[2:]), cache)

    # -- step loop -----------------------------------------------------------

    def step(self) -> bool:
        """Run one scheduler-selected micro-batch. False when idle."""
        work = self.scheduler.next_work()
        if work is None:
            return False
        if isinstance(work, PrefillWork):
            self._run_prefill(work)
        elif self.gamma:
            self._run_spec_decode(work)
        else:
            self._run_decode(work)
        return True

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until idle (or ``max_steps``); returns finished requests."""
        steps = 0
        while max_steps is None or steps < max_steps:
            if not self.step():
                break
            steps += 1
        return [r for r in self.requests if r.done]

    # -- execution -----------------------------------------------------------

    def _note_shape(self, key: tuple) -> None:
        if key in self._compiled:
            self.stats.bucket_hits += 1
        else:
            self.stats.bucket_misses += 1
            self._compiled.add(key)

    def _run_prefill(self, work: PrefillWork) -> None:
        B, S = work.batch_pad, work.seq_pad
        self._note_shape(("prefill", B, S))
        k = len(work.requests)
        # first chunk of a prefix hit: copy the reused KV/state into the slot
        # before this chunk's rows are gathered
        for i, r in enumerate(work.requests):
            if r.prefix_entry is not None and work.starts[i] == r.prefix_len:
                self._load_prefix(work.slots[i], r)
        toks = np.zeros((B, S), np.int32)
        last = np.zeros((B,), np.int32)
        start = np.zeros((B,), np.int32)
        for i, r in enumerate(work.requests):
            n = work.lengths[i]
            toks[i, :n] = r.prompt[work.starts[i]:work.starts[i] + n]
            last[i] = n - 1
            start[i] = work.starts[i]
        t0 = time.perf_counter()
        with falcon.use(self.fcfg), self._mesh_ctx():
            idx = jnp.asarray(list(work.slots) + [work.slots[-1]] * (B - k))
            rows = jax.tree.map(lambda c: c[:, idx], self.cache)
            logits, new_rows = self._prefill_fn(
                self.params, rows, jnp.asarray(toks), jnp.asarray(start),
                jnp.asarray(last))
            jax.block_until_ready(logits)
            slots = jnp.asarray(work.slots)
            # pad rows i >= k are sliced off; pad positions inside a row are
            # overwritten by decode before the validity mask admits them
            self.cache = jax.tree.map(
                lambda c, nc: c.at[:, slots].set(nc[:, :k].astype(c.dtype)),
                self.cache, new_rows)
            if self.draft is not None:
                self.draft.prefill_chunk(toks, start, last, work.slots, k)
        step_logits = np.asarray(logits[:, -1])
        now = time.perf_counter()
        self.stats.t_prefill += now - t0
        self.stats.prefill_steps += 1
        self.stats.prompt_tokens += work.real_tokens
        self.stats.prefill_padded_tokens += work.padded_tokens
        for i, r in enumerate(work.requests):
            r.prefilled = work.starts[i] + work.lengths[i]
            if not work.final[i]:
                continue                    # chunk done; more prompt to go
            if self.prefix is not None and r.prompt_len > 1:
                self._insert_prefix(r, work.slots[i])
            self.pos[work.slots[i]] = r.prompt_len
            r.first_token_t = now
            self.stats.generated_tokens += 1
            self._emit(r, int(np.argmax(step_logits[i])), step_logits[i])

    # -- prefix cache --------------------------------------------------------

    def _load_prefix(self, slot: int, req: Request) -> None:
        """Copy a pinned prefix snapshot into ``slot``; release the pin."""
        entry = req.prefix_entry
        n = len(entry.tokens)
        payload = entry.payload
        new = {}
        for name, c in self.cache.items():
            v = jnp.asarray(payload[name]).astype(c.dtype)
            new[name] = (c.at[:, slot].set(v) if name == "state"
                         else c.at[:, slot, :n].set(v))
        self.cache = new
        if self.draft is not None:
            self.draft.load(slot, payload["draft"], n)
        self.prefix.release(entry)
        req.prefix_entry = None

    def _insert_prefix(self, req: Request, slot: int) -> None:
        """Snapshot the freshly prefilled prompt KV under its token key.

        Attention K/V slices to any length, so the entry is keyed at
        ``prompt[:-1]`` — the longest key :meth:`_lookup_prefix` can ever
        match (it always leaves one suffix token to prefill), which makes an
        identical resubmission a full hit. A recurrent ``state`` snapshot is
        only valid at its exact length, so state-bearing caches keep the
        whole prompt as key and serve only prompts that extend this one.
        """
        n = req.prompt_len if "state" in self.cache else req.prompt_len - 1
        if n < 1:
            return
        payload = {}
        for name, c in self.cache.items():
            payload[name] = np.asarray(c[:, slot] if name == "state"
                                       else c[:, slot, :n])
        if self.draft is not None:
            payload["draft"] = self.draft.snapshot(slot, n)
        self.prefix.insert(tuple(req.prompt[:n]), payload)

    # -- decode --------------------------------------------------------------

    def _run_decode(self, work: DecodeWork) -> None:
        k = len(work.slots)
        b = work.batch_pad
        self._note_shape(("decode", b))
        idx = jnp.asarray(list(work.slots) + [work.slots[-1]] * (b - k))
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i, r in enumerate(work.requests):
            toks[i, 0] = r.generated[-1]
            pos[i] = self.pos[work.slots[i]]
        t0 = time.perf_counter()
        with falcon.use(self.fcfg), self._mesh_ctx():
            rows = jax.tree.map(lambda c: c[:, idx], self.cache)
            logits, new_rows = self._decode_fn(
                self.params, rows, jnp.asarray(toks), jnp.asarray(pos))
            jax.block_until_ready(logits)
        slots = jnp.asarray(work.slots)
        self.cache = jax.tree.map(
            lambda c, nc: c.at[:, slots].set(nc[:, :k]), self.cache, new_rows)
        step_logits = np.asarray(logits[:, -1])
        self.stats.t_decode += time.perf_counter() - t0
        self.stats.decode_steps += 1
        self.stats.generated_tokens += work.real_tokens
        self.stats.decode_real_rows += work.real_tokens
        self.stats.decode_emitted_tokens += work.real_tokens
        self.stats.decode_padded_tokens += work.padded_tokens
        for i, r in enumerate(work.requests):
            self.pos[work.slots[i]] += 1
            self._emit(r, int(np.argmax(step_logits[i])), step_logits[i])

    def _run_spec_decode(self, work: DecodeWork) -> None:
        """One speculative round: draft γ, verify in one forward, accept.

        Per row: feed ``[t_last, d_1..d_γ]`` at the slot position, take the
        verify argmaxes ``t'_0..t'_γ``, accept drafts while ``d_j ==
        t'_{j-1}``, emit ``t'_0..t'_{n_acc}`` (always ≥ 1 — the bonus token
        means a round never stalls). Rejected draft K/V stays in the cache
        above the new position and is overwritten before validity ever
        admits it, so rollback costs nothing.
        """
        k = len(work.slots)
        b = work.batch_pad
        g = self.gamma
        self._note_shape(("spec", b))
        idx = jnp.asarray(list(work.slots) + [work.slots[-1]] * (b - k))
        last2 = np.zeros((b, 2), np.int32)
        pos = np.zeros((b,), np.int32)
        for i, r in enumerate(work.requests):
            last2[i] = r.tokens[-2:]
            pos[i] = self.pos[work.slots[i]]
        last2[k:] = last2[k - 1]            # pad rows mirror the last real row
        pos[k:] = pos[k - 1]
        t0 = time.perf_counter()
        with falcon.use(self.fcfg), self._mesh_ctx():
            drafts = self.draft.propose(idx, last2, pos, g, k)   # (b, γ)
            verify = np.concatenate([last2[:, 1:], drafts], axis=1)
            rows = jax.tree.map(lambda c: c[:, idx], self.cache)
            logits, new_rows = self._verify_fn(
                self.params, rows, jnp.asarray(verify), jnp.asarray(pos))
            jax.block_until_ready(logits)
            slots = jnp.asarray(work.slots)
            self.cache = jax.tree.map(
                lambda c, nc: c.at[:, slots].set(nc[:, :k]),
                self.cache, new_rows)
        logits_np = np.asarray(logits)                           # (b, γ+1, V)
        greedy = np.argmax(logits_np, axis=-1)
        self.stats.t_decode += time.perf_counter() - t0
        self.stats.verify_steps += 1
        self.stats.drafted_tokens += g * k
        self.stats.decode_real_rows += k * (g + 1)
        self.stats.decode_padded_tokens += b * (g + 1)
        for i, r in enumerate(work.requests):
            n_acc = 0
            while n_acc < g and int(drafts[i, n_acc]) == int(greedy[i, n_acc]):
                n_acc += 1
            self.stats.accepted_tokens += n_acc
            emitted = 0
            for j in range(n_acc + 1):
                emitted += 1
                self._emit(r, int(greedy[i, j]), logits_np[i, j])
                if r.done:
                    break                   # budget/eos cut mid-acceptance
            self.pos[work.slots[i]] += emitted
            self.stats.generated_tokens += emitted
            self.stats.decode_emitted_tokens += emitted

    def _emit(self, req: Request, tok: int, logits_row=None) -> None:
        """Deliver one generated token; retire the request when finished."""
        req.generated.append(tok)
        if self.record_logits and logits_row is not None:
            req.logits.append(np.asarray(logits_row).copy())
        if req.on_token is not None:
            req.on_token(req, tok)
        if req.stream_q is not None:
            req.stream_q.put(tok)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.generated) >= req.max_new_tokens:
            req.state = "done"
            req.finish_t = time.perf_counter()
            self.scheduler.release(req)
            self.stats.requests_finished += 1
            if req.stream_q is not None:
                req.stream_q.put(None)      # end-of-stream sentinel

    # -- observability -------------------------------------------------------

    def summary(self) -> dict:
        """ServeStats + the process plan cache, one coherent snapshot."""
        d = self.stats.as_dict()
        d["plan_cache"] = plan_cache.stats().as_dict()
        d["plan_cache"]["entries"] = len(plan_cache.default_cache())
        d["precombined_weights"] = self.n_precombined
        d["quantize"] = self.quantize
        d["max_len"] = self.max_len
        d["max_slots"] = self.max_slots
        d["speculate"] = self.gamma
        d["prefix_cache"] = None if self.prefix is None else self.prefix.stats()
        d["prefill_chunk"] = self.prefill_chunk
        d["mesh"] = self.mesh_shape or None
        d["n_devices"] = (1 if self.mesh is None
                          else int(np.prod(list(dict(self.mesh.shape).values()))))
        return d


class StepLoop:
    """Drives a :class:`ServeEngine` until its queue and slots drain.

    A thin synchronous loop for CLI/batch use; a real deployment would run
    this on a dedicated thread while frontend threads ``submit``.
    """

    def __init__(self, engine: ServeEngine, max_steps: int | None = None):
        self.engine = engine
        self.max_steps = max_steps

    def run_until_idle(self, poll_s: float = 0.0) -> list[Request]:
        """Drain the engine; ``max_steps`` bounds total steps across both the
        initial drain and the polling phase (a watchdog for wedged work)."""
        steps = 0
        while self.max_steps is None or steps < self.max_steps:
            if self.engine.step():
                steps += 1
            elif poll_s and not self.engine.scheduler.idle:
                time.sleep(poll_s)
            else:
                break
        return [r for r in self.engine.requests if r.done]
