"""ServeEngine: continuous batching over bucketed, pre-planned step shapes.

The engine owns a fixed set of KV-cache **slots**. Requests are admitted by
the :class:`~repro.serve.scheduler.Scheduler` into free slots via bucketed
prefill micro-batches (prompts right-padded to a power-of-two sequence
bucket, per-row last-token indices pick the true logits), then advance one
token per decode micro-batch over the active slots, padded to a power-of-two
batch bucket. Every step therefore launches a shape from the closed
:class:`~repro.serve.buckets.BucketPolicy` grid, so after :meth:`warm`:

* the FalconGEMM Decision Module is a pure plan-cache hit per projection
  (``core.engine.warm_buckets`` pre-planned the bucket grid),
* static weights are already lifted to precombined :class:`PlannedWeight`\\ s
  (offline Combine B ran once at load),
* jit never re-traces — each bucket shape's executable exists.

Correctness of padding: pad rows/positions never leak. Right-padded prefill
writes pad K/V above each request's true length, but decode validity masks
``kpos < pos`` and each per-slot decode write overwrites position ``pos``
before it first becomes visible; pad *rows* of a micro-batch are sliced off
before the slot cache update. The engine output is allclose to per-request
eager decode (``tests/test_serve_engine.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro import compat
from repro.configs.base import ModelConfig
from repro.core import engine as core_engine, plan_cache
from repro.models import model as M
from repro.parallel import sharding as SH
from repro.train.steps import make_decode_step, make_serve_prefill_step

from .buckets import BucketPolicy, next_pow2
from .request import Request, RequestQueue
from .scheduler import DecodeWork, PrefillWork, Scheduler
from .stats import ServeStats

__all__ = ["ServeEngine", "StepLoop"]


class ServeEngine:
    """Continuous-batching serve engine for one model.

    ``submit`` is thread-safe (any frontend thread); ``step``/``run`` are the
    single consumer. All decoder families serve: dense/hybrid KV-cache
    attention is exact under causal masking + decode validity, and SSM/hybrid
    recurrent state is exact because the serve prefill step zeroes dt on
    right-pad positions (see ``make_serve_prefill_step``). MoE routing is
    approximate under padding (pad rows contend for expert capacity) but
    pad rows are sliced off before the slot cache update. Non-token
    frontends (audio codebooks, vision patches) are rejected — the bucket
    grid assumes one int token stream.

    ``mesh_shape={"data": d, "model": m}`` spanning more than one device
    lifts the engine onto a real mesh: weights shard tensor-parallel by the
    ``parallel.sharding`` rule table (offline Combine B then runs on sharded
    weights), the KV cache stays replicated (decode activations gather back
    each step — "replicated-then-gathered"), and every jitted step runs under
    the mesh context so FalconGEMM's shard-aware plans and ``shard_act``
    constraints see it. Simulate devices on one host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    def __init__(self, model_cfg: ModelConfig, params=None, *,
                 max_slots: int = 8, max_prompt_len: int = 64,
                 max_new_tokens: int = 32, policy: BucketPolicy | None = None,
                 precombine: bool = True, record_logits: bool = False,
                 seed: int = 0, mesh_shape: dict | None = None,
                 quantize: bool = False):
        if model_cfg.frontend:
            raise NotImplementedError(
                f"ServeEngine serves token-stream decoders; got "
                f"frontend={model_cfg.frontend!r} (bucketed prefill assumes "
                "one int token stream)")
        self.cfg = model_cfg
        self.policy = policy or BucketPolicy.build(max_prompt_len, max_slots)
        self.max_slots = max_slots
        self.max_new_tokens_cap = max_new_tokens
        self.max_len = next_pow2(self.policy.prefill_seq[-1] + max_new_tokens)
        self.record_logits = record_logits
        self.mesh_shape = dict(mesh_shape or {})
        self.mesh = self._build_mesh(self.mesh_shape)
        self.quantize = bool(quantize)
        self.fcfg = M.falcon_config_for(model_cfg, self.mesh_shape)
        if self.quantize:
            # int8-quantized serving: the Decision Module prices the quant
            # tier alongside fp (plan-cache keys gain the quant token),
            # precombine below bakes B̃q + scales into each PlannedWeight,
            # and warm() pre-plans the quantized buckets.
            self.fcfg = dataclasses.replace(self.fcfg, quantize=True)
        with falcon.use(self.fcfg), self._mesh_ctx():
            self.params = params if params is not None \
                else M.init_params(model_cfg, jax.random.PRNGKey(seed))
            if self.mesh is not None:
                # Tensor-parallel at rest: shard raw weights by the rule table
                # BEFORE precombine, so offline Combine B runs on (and its B̃
                # output inherits) the sharded layout.
                rules = SH.make_rules(self.mesh)
                self.params = jax.device_put(
                    self.params, SH.param_sharding(self.params, self.mesh, rules))
            self.n_precombined = 0
            if precombine:
                # Offline Combine B priced at the largest prefill bucket M;
                # each step re-decides per its actual bucket M (plan-cached).
                m_hint = self.policy.prefill_batch[-1] * self.policy.prefill_seq[-1]
                self.params, self.n_precombined = falcon.precombine_params(
                    self.params, m_hint=m_hint)
        self.queue = RequestQueue()
        self.scheduler = Scheduler(self.queue, self.policy, max_slots)
        self.stats = ServeStats()
        self.requests: list[Request] = []
        self.cache = M.init_cache(model_cfg, max_slots, self.max_len)
        if self.mesh is not None:
            # Replicated-then-gathered decode: the KV cache lives replicated on
            # every device; each step's projections run tensor-parallel and the
            # (small) per-step activations gather back before the cache write.
            from jax.sharding import NamedSharding, PartitionSpec as P
            self.cache = jax.device_put(
                self.cache, NamedSharding(self.mesh, P()))
        self.pos = np.zeros(max_slots, np.int32)   # per-slot next write index
        self._prefill_fn = jax.jit(make_serve_prefill_step(model_cfg, self.max_len))
        self._decode_fn = jax.jit(make_decode_step(model_cfg))
        self._compiled: set[tuple] = set()          # step shapes already traced
        self._submit_lock = threading.Lock()

    # -- mesh ----------------------------------------------------------------

    @staticmethod
    def _build_mesh(mesh_shape: dict):
        """A real ("data", "model") mesh when ``mesh_shape`` spans > 1 device."""
        total = 1
        for v in mesh_shape.values():
            total *= int(v)
        if total <= 1:
            return None
        ndev = len(jax.devices())
        if total > ndev:
            raise ValueError(
                f"mesh_shape {mesh_shape} needs {total} devices but only "
                f"{ndev} are visible; simulate with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={total}")
        d = int(mesh_shape.get("data", 1)) * int(mesh_shape.get("pod", 1))
        m = int(mesh_shape.get("model", 1))
        return compat.make_mesh((d, m), ("data", "model"))

    def _mesh_ctx(self):
        return compat.set_mesh(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()

    # -- admission ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int | None = None,
               eos_id: int | None = None) -> Request:
        req = Request(prompt=prompt,
                      max_new_tokens=max_new_tokens or self.max_new_tokens_cap,
                      eos_id=eos_id)
        self.policy.seq_bucket(req.prompt_len)      # raises if off-grid
        if req.max_new_tokens > self.max_new_tokens_cap:
            raise ValueError(
                f"max_new_tokens={req.max_new_tokens} exceeds engine cap "
                f"{self.max_new_tokens_cap} (cache is sized for the cap)")
        with self._submit_lock:                     # frontend threads race here
            self.queue.submit(req)
            self.requests.append(req)
            self.stats.requests_admitted += 1
        return req

    # -- warmup --------------------------------------------------------------

    def warm(self) -> dict:
        """Pre-plan + pre-compile the whole bucket grid.

        1. ``core.engine.warm_buckets`` runs the Decision Module for every
           contraction the workload registry enumerates at every (batch, seq)
           bucket of the grid — dense projections, grouped MoE expert FFNs,
           attention and SSD scan/decode contractions — so serve-time traces
           only hit the plan cache, including from concurrent engines sharing
           a warmed cache file.
        2. Each (phase, shape) step function is traced and compiled once on
           zero inputs, so no live request ever pays a compile.
        """
        t0 = time.perf_counter()
        grid = (list(self.policy.prefill_shapes())
                + [(b, 1) for b in self.policy.decode_batch])
        with falcon.use(self.fcfg), self._mesh_ctx():
            n_plans = core_engine.warm_buckets(
                self.fcfg, self.cfg, grid,
                dtype=str(self.cfg.dtype), mesh_shape=self.mesh_shape,
                kv_len=self.max_len)
            for (b, s) in self.policy.prefill_shapes():
                jax.block_until_ready(self._prefill_fn(
                    self.params, jnp.zeros((b, s), jnp.int32),
                    jnp.zeros((b,), jnp.int32)))
                self._compiled.add(("prefill", b, s))
            for b in self.policy.decode_batch:
                rows_b = jax.tree.map(
                    lambda c: jnp.broadcast_to(
                        c[:, :1], (c.shape[0], b) + c.shape[2:]), self.cache)
                jax.block_until_ready(self._decode_fn(
                    self.params, rows_b, jnp.zeros((b, 1), jnp.int32),
                    jnp.zeros((b,), jnp.int32)))
                self._compiled.add(("decode", b))
        self.stats.warm_plans = n_plans
        self.stats.warmed_shapes = len(self._compiled)
        self.stats.t_warm = time.perf_counter() - t0
        return {"plans": n_plans, "shapes": len(self._compiled),
                "seconds": self.stats.t_warm}

    # -- step loop -----------------------------------------------------------

    def step(self) -> bool:
        """Run one scheduler-selected micro-batch. False when idle."""
        work = self.scheduler.next_work()
        if work is None:
            return False
        if isinstance(work, PrefillWork):
            self._run_prefill(work)
        else:
            self._run_decode(work)
        return True

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until idle (or ``max_steps``); returns finished requests."""
        steps = 0
        while max_steps is None or steps < max_steps:
            if not self.step():
                break
            steps += 1
        return [r for r in self.requests if r.done]

    # -- execution -----------------------------------------------------------

    def _note_shape(self, key: tuple) -> None:
        if key in self._compiled:
            self.stats.bucket_hits += 1
        else:
            self.stats.bucket_misses += 1
            self._compiled.add(key)

    def _run_prefill(self, work: PrefillWork) -> None:
        B, S = work.batch_pad, work.seq_pad
        self._note_shape(("prefill", B, S))
        toks = np.zeros((B, S), np.int32)
        last = np.zeros((B,), np.int32)
        for i, r in enumerate(work.requests):
            toks[i, :r.prompt_len] = r.prompt
            last[i] = r.prompt_len - 1
        t0 = time.perf_counter()
        with falcon.use(self.fcfg), self._mesh_ctx():
            logits, new_cache = self._prefill_fn(
                self.params, jnp.asarray(toks), jnp.asarray(last))
            jax.block_until_ready(logits)
        k = len(work.requests)
        slots = jnp.asarray(work.slots)
        # pad rows i >= k are sliced off; pad positions inside a row are
        # overwritten by decode before the validity mask admits them
        self.cache = jax.tree.map(
            lambda c, nc: c.at[:, slots].set(nc[:, :k].astype(c.dtype)),
            self.cache, new_cache)
        step_logits = np.asarray(logits[:, -1])
        now = time.perf_counter()
        self.stats.t_prefill += now - t0
        self.stats.prefill_steps += 1
        self.stats.prompt_tokens += work.real_tokens
        self.stats.prefill_padded_tokens += work.padded_tokens
        self.stats.generated_tokens += len(work.requests)  # first token each
        for i, r in enumerate(work.requests):
            self.pos[work.slots[i]] = r.prompt_len
            r.first_token_t = now
            self._emit(r, step_logits[i])

    def _run_decode(self, work: DecodeWork) -> None:
        k = len(work.slots)
        b = work.batch_pad
        self._note_shape(("decode", b))
        idx = jnp.asarray(list(work.slots) + [work.slots[-1]] * (b - k))
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for i, r in enumerate(work.requests):
            toks[i, 0] = r.generated[-1]
            pos[i] = self.pos[work.slots[i]]
        t0 = time.perf_counter()
        with falcon.use(self.fcfg), self._mesh_ctx():
            rows = jax.tree.map(lambda c: c[:, idx], self.cache)
            logits, new_rows = self._decode_fn(
                self.params, rows, jnp.asarray(toks), jnp.asarray(pos))
            jax.block_until_ready(logits)
        slots = jnp.asarray(work.slots)
        self.cache = jax.tree.map(
            lambda c, nc: c.at[:, slots].set(nc[:, :k]), self.cache, new_rows)
        step_logits = np.asarray(logits[:, -1])
        self.stats.t_decode += time.perf_counter() - t0
        self.stats.decode_steps += 1
        self.stats.generated_tokens += work.real_tokens
        self.stats.decode_real_rows += work.real_tokens
        self.stats.decode_padded_tokens += work.padded_tokens
        for i, r in enumerate(work.requests):
            self.pos[work.slots[i]] += 1
            self._emit(r, step_logits[i])

    def _emit(self, req: Request, logits_row: np.ndarray) -> None:
        """Append the greedy next token; retire the request when finished."""
        tok = int(np.argmax(logits_row))
        req.generated.append(tok)
        if self.record_logits:
            req.logits.append(logits_row.copy())
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.generated) >= req.max_new_tokens:
            req.state = "done"
            req.finish_t = time.perf_counter()
            self.scheduler.release(req)
            self.stats.requests_finished += 1

    # -- observability -------------------------------------------------------

    def summary(self) -> dict:
        """ServeStats + the process plan cache, one coherent snapshot."""
        d = self.stats.as_dict()
        d["plan_cache"] = plan_cache.stats().as_dict()
        d["plan_cache"]["entries"] = len(plan_cache.default_cache())
        d["precombined_weights"] = self.n_precombined
        d["quantize"] = self.quantize
        d["max_len"] = self.max_len
        d["max_slots"] = self.max_slots
        d["mesh"] = self.mesh_shape or None
        d["n_devices"] = (1 if self.mesh is None
                          else int(np.prod(list(dict(self.mesh.shape).values()))))
        return d


class StepLoop:
    """Drives a :class:`ServeEngine` until its queue and slots drain.

    A thin synchronous loop for CLI/batch use; a real deployment would run
    this on a dedicated thread while frontend threads ``submit``.
    """

    def __init__(self, engine: ServeEngine, max_steps: int | None = None):
        self.engine = engine
        self.max_steps = max_steps

    def run_until_idle(self, poll_s: float = 0.0) -> list[Request]:
        """Drain the engine; ``max_steps`` bounds total steps across both the
        initial drain and the polling phase (a watchdog for wedged work)."""
        steps = 0
        while self.max_steps is None or steps < self.max_steps:
            if self.engine.step():
                steps += 1
            elif poll_s and not self.engine.scheduler.idle:
                time.sleep(poll_s)
            else:
                break
        return [r for r in self.engine.requests if r.done]
