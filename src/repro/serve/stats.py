"""ServeStats: the engine's observable surface.

Counters are plain ints/floats updated by the step loop (single consumer
thread); derived rates are properties so a dashboard or test reads one
coherent snapshot via :meth:`ServeStats.as_dict`.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ServeStats"]


@dataclasses.dataclass
class ServeStats:
    # step counts. A verify step is one speculative round: γ+1 rows per
    # active request through one forward instead of one row per decode step.
    prefill_steps: int = 0
    decode_steps: int = 0
    verify_steps: int = 0
    # token accounting. Rows: what the hardware ran — prompt_tokens and
    # decode_real_rows are useful rows, *_padded_tokens the launched bucket
    # area (their gap is padding waste). generated_tokens counts every token
    # emitted to a caller (each request's first comes from its prefill step).
    # Under speculation a verify step launches (γ+1) rows per real request
    # (decode_real_rows) but emits only the accepted ones
    # (decode_emitted_tokens) — padding waste is judged on rows launched,
    # decode throughput on tokens emitted.
    prompt_tokens: int = 0
    generated_tokens: int = 0
    decode_real_rows: int = 0
    decode_emitted_tokens: int = 0
    prefill_padded_tokens: int = 0
    decode_padded_tokens: int = 0
    # speculative decoding: γ proposals per request per round; accepted is
    # how many survived verify (the bonus token is not counted as drafted)
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # prefix cache: hits/misses counted per submitted request, reused tokens
    # skip prefill entirely
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_tokens_reused: int = 0
    # bucket reuse: a hit runs a step shape that is already compiled (warmed
    # or previously seen); a miss pays a fresh trace + compile mid-serve
    bucket_hits: int = 0
    bucket_misses: int = 0
    # warmup provenance
    warmed_shapes: int = 0
    warm_plans: int = 0
    t_warm: float = 0.0
    # phase wall-clock (step dispatch + device time, excludes warmup)
    t_prefill: float = 0.0
    t_decode: float = 0.0
    # request lifecycle
    requests_admitted: int = 0
    requests_finished: int = 0

    @property
    def steps(self) -> int:
        return self.prefill_steps + self.decode_steps + self.verify_steps

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens that survived verification."""
        return self.accepted_tokens / self.drafted_tokens \
            if self.drafted_tokens else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        total = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / total if total else 0.0

    @property
    def bucket_hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / total if total else 0.0

    @property
    def real_tokens(self) -> int:
        """Tokens that reached a caller: prompts consumed + tokens emitted."""
        return self.prompt_tokens + self.generated_tokens

    @property
    def real_rows(self) -> int:
        return self.prompt_tokens + self.decode_real_rows

    @property
    def padded_tokens(self) -> int:
        return self.prefill_padded_tokens + self.decode_padded_tokens

    @property
    def padding_waste(self) -> float:
        """Fraction of launched token-rows that were padding."""
        return 1.0 - self.real_rows / self.padded_tokens \
            if self.padded_tokens else 0.0

    @property
    def decode_tokens_per_s(self) -> float:
        """Tokens emitted by decode/verify steps per second of decode time
        (each request's first token comes from prefill and is excluded).
        Uses emitted tokens, not launched rows — under speculation a verify
        row that gets rejected is work done, not a token served."""
        return self.decode_emitted_tokens / self.t_decode \
            if self.t_decode else 0.0

    @property
    def tokens_per_s(self) -> float:
        """Real tokens processed per second of engine step time."""
        t = self.t_prefill + self.t_decode
        return self.real_tokens / t if t else 0.0

    def as_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d.update(
            steps=self.steps,
            bucket_hit_rate=round(self.bucket_hit_rate, 4),
            padding_waste=round(self.padding_waste, 4),
            tokens_per_s=round(self.tokens_per_s, 2),
            decode_tokens_per_s=round(self.decode_tokens_per_s, 2),
            acceptance_rate=round(self.acceptance_rate, 4),
            prefix_hit_rate=round(self.prefix_hit_rate, 4),
        )
        return d
