"""ServeStats: the engine's observable surface.

Counters are plain ints/floats updated by the step loop (single consumer
thread); derived rates are properties so a dashboard or test reads one
coherent snapshot via :meth:`ServeStats.as_dict`.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ServeStats"]


@dataclasses.dataclass
class ServeStats:
    # step counts
    prefill_steps: int = 0
    decode_steps: int = 0
    # token accounting. Rows: what the hardware ran — prompt_tokens and
    # decode_real_rows are useful rows, *_padded_tokens the launched bucket
    # area (their gap is padding waste). generated_tokens counts every token
    # emitted to a caller (each request's first comes from its prefill step).
    prompt_tokens: int = 0
    generated_tokens: int = 0
    decode_real_rows: int = 0
    prefill_padded_tokens: int = 0
    decode_padded_tokens: int = 0
    # bucket reuse: a hit runs a step shape that is already compiled (warmed
    # or previously seen); a miss pays a fresh trace + compile mid-serve
    bucket_hits: int = 0
    bucket_misses: int = 0
    # warmup provenance
    warmed_shapes: int = 0
    warm_plans: int = 0
    t_warm: float = 0.0
    # phase wall-clock (step dispatch + device time, excludes warmup)
    t_prefill: float = 0.0
    t_decode: float = 0.0
    # request lifecycle
    requests_admitted: int = 0
    requests_finished: int = 0

    @property
    def steps(self) -> int:
        return self.prefill_steps + self.decode_steps

    @property
    def bucket_hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / total if total else 0.0

    @property
    def real_tokens(self) -> int:
        """Tokens that reached a caller: prompts consumed + tokens emitted."""
        return self.prompt_tokens + self.generated_tokens

    @property
    def real_rows(self) -> int:
        return self.prompt_tokens + self.decode_real_rows

    @property
    def padded_tokens(self) -> int:
        return self.prefill_padded_tokens + self.decode_padded_tokens

    @property
    def padding_waste(self) -> float:
        """Fraction of launched token-rows that were padding."""
        return 1.0 - self.real_rows / self.padded_tokens \
            if self.padded_tokens else 0.0

    @property
    def decode_tokens_per_s(self) -> float:
        """Tokens emitted by decode steps per second of decode time (each
        request's first token comes from prefill and is excluded here)."""
        return self.decode_real_rows / self.t_decode if self.t_decode else 0.0

    @property
    def tokens_per_s(self) -> float:
        """Real tokens processed per second of engine step time."""
        t = self.t_prefill + self.t_decode
        return self.real_tokens / t if t else 0.0

    def as_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        d.update(
            steps=self.steps,
            bucket_hit_rate=round(self.bucket_hit_rate, 4),
            padding_waste=round(self.padding_waste, 4),
            tokens_per_s=round(self.tokens_per_s, 2),
            decode_tokens_per_s=round(self.decode_tokens_per_s, 2),
        )
        return d
