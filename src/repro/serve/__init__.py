"""Continuous-batching serve engine with bucketed plan reuse.

``ServeEngine`` admits requests into a fixed slot set through power-of-two
shape buckets so every step runs a pre-planned, pre-compiled FalconGEMM
shape; see ``docs/serving.md``.
"""
from .buckets import BucketPolicy, next_pow2
from .engine import ServeEngine, StepLoop
from .prefix_cache import PrefixEntry, RadixPrefixCache
from .request import Request, RequestQueue
from .scheduler import DecodeWork, PrefillWork, Scheduler
from .speculative import DraftModel, SelfDraft
from .stats import ServeStats

__all__ = ["BucketPolicy", "next_pow2", "ServeEngine", "StepLoop", "Request",
           "RequestQueue", "DecodeWork", "PrefillWork", "Scheduler",
           "ServeStats", "DraftModel", "SelfDraft", "RadixPrefixCache",
           "PrefixEntry"]
