"""Continuous-batching scheduler: admission into slots, micro-batch formation.

The scheduler owns slot accounting and decides what the next step runs:

* **prefill-leaning, decode-fair** — whenever waiting requests (or unfinished
  prefill chunks) and capacity exist, the next step is a prefill micro-batch:
  keeping slots full is what decode throughput amortizes over. But prefill no
  longer starves decode: after ``max_consecutive_prefills`` prefill batches in
  a row, one decode batch runs if any slot is decode-ready — under a sustained
  arrival stream every in-flight request's inter-token gap is bounded by the
  cap instead of the queue depth (regression-tested in
  ``tests/test_serve_spec.py``).
* **chunked prefill** — with ``prefill_chunk`` set, prompts longer than one
  bucket prefill in fixed full-bucket chunks across multiple micro-batches,
  each interleaved with decode work by the same fairness cap, so one long
  prompt stops inflating decode p99. Intermediate chunks are exactly the
  chunk bucket (no internal padding — the cache-validity exactness argument
  needs contiguously written positions); only the final chunk right-pads.
* otherwise, a decode micro-batch over every decode-ready slot, padded up to
  the decode batch bucket.

The scheduler never launches an off-grid shape: both work items carry their
padded (bucket) dimensions, so the engine's jit cache and the plan cache key
on a closed set of shapes.
"""
from __future__ import annotations

import dataclasses
import threading

from .buckets import BucketPolicy
from .request import Request, RequestQueue

__all__ = ["PrefillWork", "DecodeWork", "Scheduler"]


@dataclasses.dataclass(frozen=True)
class PrefillWork:
    requests: tuple[Request, ...]
    slots: tuple[int, ...]          # one slot per request
    batch_pad: int                  # bucketed batch (>= len(requests))
    seq_pad: int                    # bucketed chunk length
    # per-row chunk geometry; defaults (derived in __post_init__) describe a
    # whole-prompt single-chunk prefill, the pre-chunking behavior
    starts: tuple[int, ...] = ()    # cache offset this chunk resumes at
    lengths: tuple[int, ...] = ()   # real tokens this chunk
    final: tuple[bool, ...] = ()    # does this chunk finish the prompt?

    def __post_init__(self):
        if not self.starts:
            object.__setattr__(self, "starts", (0,) * len(self.requests))
        if not self.lengths:
            object.__setattr__(
                self, "lengths", tuple(r.prompt_len for r in self.requests))
        if not self.final:
            object.__setattr__(self, "final", (True,) * len(self.requests))

    @property
    def real_tokens(self) -> int:
        return sum(self.lengths)

    @property
    def padded_tokens(self) -> int:
        return self.batch_pad * self.seq_pad


@dataclasses.dataclass(frozen=True)
class DecodeWork:
    requests: tuple[Request, ...]
    slots: tuple[int, ...]          # the active slots, |slots| == |requests|
    batch_pad: int                  # bucketed batch (>= len(slots))

    @property
    def real_tokens(self) -> int:
        return len(self.slots)

    @property
    def padded_tokens(self) -> int:
        return self.batch_pad


class Scheduler:
    """Admits requests into a fixed slot set and forms bucketed micro-batches."""

    def __init__(self, queue: RequestQueue, policy: BucketPolicy,
                 max_slots: int, *, max_consecutive_prefills: int = 2,
                 prefill_chunk: int | None = None):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if prefill_chunk is not None and prefill_chunk not in policy.prefill_seq:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must be a prefill bucket "
                f"from {policy.prefill_seq} (intermediate chunks must be "
                "exactly full buckets)")
        self.queue = queue
        self.policy = policy
        self.max_slots = max_slots
        self.max_consecutive_prefills = max_consecutive_prefills
        self.prefill_chunk = prefill_chunk
        self._free = list(range(max_slots))[::-1]   # pop() -> lowest slot
        self._active: dict[int, Request] = {}
        self._prefill_run = 0                       # consecutive prefill batches
        self._lock = threading.Lock()

    # -- state -------------------------------------------------------------

    @property
    def n_active(self) -> int:
        with self._lock:
            return len(self._active)

    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    def active_items(self) -> list[tuple[int, Request]]:
        with self._lock:
            return sorted(self._active.items())

    @property
    def idle(self) -> bool:
        return self.n_active == 0 and len(self.queue) == 0

    # -- step selection ----------------------------------------------------

    def next_work(self) -> PrefillWork | DecodeWork | None:
        """The next micro-batch to run, or None when idle.

        Decode-fairness cap: prefill still leads (slots should fill fast),
        but after ``max_consecutive_prefills`` prefill batches in a row a
        pending decode batch runs first — a continuous arrival stream can no
        longer starve in-flight decodes indefinitely.
        """
        decode = self._form_decode()
        if (decode is not None and self.max_consecutive_prefills
                and self._prefill_run >= self.max_consecutive_prefills):
            self._prefill_run = 0
            return decode
        work = self._form_prefill()
        if work is not None:
            self._prefill_run += 1
            return work
        self._prefill_run = 0
        return decode

    def _chunk_plan(self, r: Request) -> tuple[int, int, int, bool]:
        """(start, length, seq_pad, final) of ``r``'s next prefill chunk.

        A waiting request starts at its prefix-cache hit length; a running
        one resumes where the last chunk stopped. Chunks longer than the cap
        are cut to exactly the cap bucket (full, no pad); the final chunk
        pads to its own sequence bucket.
        """
        start = r.prefilled if r.state == "running" else r.prefix_len
        rem = r.prompt_len - start
        cap = self.prefill_chunk or self.policy.prefill_seq[-1]
        if rem > cap:
            return start, cap, cap, False
        return start, rem, self.policy.seq_bucket(rem), True

    def _form_prefill(self) -> PrefillWork | None:
        # 1) continuation chunks: partially-prefilled slots come first (they
        #    already hold a slot; finishing them is what unblocks decode)
        with self._lock:
            conts = [(s, r) for s, r in sorted(self._active.items())
                     if r.prefilled < r.prompt_len]
        if conts:
            return self._pack_chunks([r for _, r in conts],
                                     [s for s, _ in conts])
        # 2) fresh admissions from the queue head into free slots
        with self._lock:
            n_free = len(self._free)
        if n_free == 0:
            return None
        limit = min(n_free, self.policy.prefill_batch[-1])
        head = self.queue.peek(limit)
        if not head:
            return None
        # group the FIFO head while requests share the head's chunk bucket; a
        # longer prompt behind a short head waits for the next micro-batch
        # rather than inflating this one's bucket for everyone
        seq_pad = self._chunk_plan(head[0])[2]
        picked: list[Request] = []
        for r in head:
            if self._chunk_plan(r)[2] != seq_pad:
                break
            picked.append(r)
        self.queue.pop(picked)
        with self._lock:
            slots = [self._free.pop() for _ in picked]
            for s, r in zip(slots, picked):
                r.state, r.slot = "running", s
                r.prefilled = r.prefix_len
                self._active[s] = r
        return self._pack_chunks(picked, slots)

    def _pack_chunks(self, reqs: list[Request],
                     slots: list[int]) -> PrefillWork:
        """One PrefillWork from rows that share the first row's chunk bucket."""
        seq_pad = self._chunk_plan(reqs[0])[2]
        limit = self.policy.prefill_batch[-1]
        rows = []
        for r, s in zip(reqs, slots):
            plan = self._chunk_plan(r)
            if plan[2] != seq_pad:
                continue            # different bucket: next micro-batch's turn
            rows.append((r, s, plan))
            # advance at formation time: the engine runs this work before the
            # next next_work() call, and decode-readiness / the next chunk's
            # start are scheduler state, not engine state
            r.prefilled = plan[0] + plan[1]
            if len(rows) == limit:
                break
        reqs_t = tuple(r for r, _, _ in rows)
        return PrefillWork(
            requests=reqs_t,
            slots=tuple(s for _, s, _ in rows),
            batch_pad=self.policy.prefill_batch_bucket(len(rows)),
            seq_pad=seq_pad,
            starts=tuple(p[0] for _, _, p in rows),
            lengths=tuple(p[1] for _, _, p in rows),
            final=tuple(p[3] for _, _, p in rows))

    def _form_decode(self) -> DecodeWork | None:
        with self._lock:
            items = [(s, r) for s, r in sorted(self._active.items())
                     if r.prefilled >= r.prompt_len]
        if not items:
            return None
        slots = tuple(s for s, _ in items)
        reqs = tuple(r for _, r in items)
        return DecodeWork(requests=reqs, slots=slots,
                          batch_pad=self.policy.decode_batch_bucket(len(slots)))

    # -- completion --------------------------------------------------------

    def release(self, req: Request) -> None:
        """Return a finished request's slot to the free pool."""
        with self._lock:
            s = req.slot
            if self._active.get(s) is not req:
                raise ValueError(f"request {req.rid} does not own slot {s}")
            del self._active[s]
            self._free.append(s)
            req.slot = -1
