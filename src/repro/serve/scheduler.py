"""Continuous-batching scheduler: admission into slots, micro-batch formation.

The scheduler owns slot accounting and decides what the next step runs:

* **prefill-priority** — whenever waiting requests and free slots exist, the
  next step is a prefill micro-batch (keeps slots full, which is what decode
  throughput amortizes over). Requests are taken FIFO from the queue head and
  grouped while they share the head request's sequence bucket, capped by free
  slots and the largest prefill batch bucket.
* otherwise, a decode micro-batch over every active slot, padded up to the
  decode batch bucket.

The scheduler never launches an off-grid shape: both work items carry their
padded (bucket) dimensions, so the engine's jit cache and the plan cache key
on a closed set of shapes.
"""
from __future__ import annotations

import dataclasses
import threading

from .buckets import BucketPolicy
from .request import Request, RequestQueue

__all__ = ["PrefillWork", "DecodeWork", "Scheduler"]


@dataclasses.dataclass(frozen=True)
class PrefillWork:
    requests: tuple[Request, ...]
    slots: tuple[int, ...]          # one free slot per request, pre-assigned
    batch_pad: int                  # bucketed batch (>= len(requests))
    seq_pad: int                    # bucketed prompt length

    @property
    def real_tokens(self) -> int:
        return sum(r.prompt_len for r in self.requests)

    @property
    def padded_tokens(self) -> int:
        return self.batch_pad * self.seq_pad


@dataclasses.dataclass(frozen=True)
class DecodeWork:
    requests: tuple[Request, ...]
    slots: tuple[int, ...]          # the active slots, |slots| == |requests|
    batch_pad: int                  # bucketed batch (>= len(slots))

    @property
    def real_tokens(self) -> int:
        return len(self.slots)

    @property
    def padded_tokens(self) -> int:
        return self.batch_pad


class Scheduler:
    """Admits requests into a fixed slot set and forms bucketed micro-batches."""

    def __init__(self, queue: RequestQueue, policy: BucketPolicy,
                 max_slots: int):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.queue = queue
        self.policy = policy
        self.max_slots = max_slots
        self._free = list(range(max_slots))[::-1]   # pop() -> lowest slot
        self._active: dict[int, Request] = {}
        self._lock = threading.Lock()

    # -- state -------------------------------------------------------------

    @property
    def n_active(self) -> int:
        with self._lock:
            return len(self._active)

    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    def active_items(self) -> list[tuple[int, Request]]:
        with self._lock:
            return sorted(self._active.items())

    @property
    def idle(self) -> bool:
        return self.n_active == 0 and len(self.queue) == 0

    # -- step selection ----------------------------------------------------

    def next_work(self) -> PrefillWork | DecodeWork | None:
        """The next micro-batch to run, or None when idle."""
        work = self._form_prefill()
        if work is not None:
            return work
        return self._form_decode()

    def _form_prefill(self) -> PrefillWork | None:
        with self._lock:
            n_free = len(self._free)
        if n_free == 0:
            return None
        limit = min(n_free, self.policy.prefill_batch[-1])
        head = self.queue.peek(limit)
        if not head:
            return None
        # group the FIFO head while requests share its sequence bucket; a
        # longer prompt behind a short head waits for the next micro-batch
        # rather than inflating this one's bucket for everyone
        seq_pad = self.policy.seq_bucket(head[0].prompt_len)
        picked: list[Request] = []
        for r in head:
            if self.policy.seq_bucket(r.prompt_len) != seq_pad:
                break
            picked.append(r)
        self.queue.pop(picked)
        with self._lock:
            slots = tuple(self._free.pop() for _ in picked)
            for s, r in zip(slots, picked):
                r.state, r.slot = "running", s
                self._active[s] = r
        return PrefillWork(
            requests=tuple(picked), slots=slots,
            batch_pad=self.policy.prefill_batch_bucket(len(picked)),
            seq_pad=seq_pad)

    def _form_decode(self) -> DecodeWork | None:
        with self._lock:
            items = sorted(self._active.items())
        if not items:
            return None
        slots = tuple(s for s, _ in items)
        reqs = tuple(r for _, r in items)
        return DecodeWork(requests=reqs, slots=slots,
                          batch_pad=self.policy.decode_batch_bucket(len(slots)))

    # -- completion --------------------------------------------------------

    def release(self, req: Request) -> None:
        """Return a finished request's slot to the free pool."""
        with self._lock:
            s = req.slot
            if self._active.get(s) is not req:
                raise ValueError(f"request {req.rid} does not own slot {s}")
            del self._active[s]
            self._free.append(s)
            req.slot = -1
