"""Serving requests and the thread-safe admission queue.

A :class:`Request` is one generation job: a prompt, a token budget, and the
mutable per-request state the engine fills in (generated tokens, slot, phase
timestamps). The :class:`RequestQueue` is the front door — callers submit
from any thread; the scheduler drains FIFO batches from the step loop.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue as _queue
import threading
import time
from collections import deque
from typing import Any, Callable, Iterator, Sequence

__all__ = ["Request", "RequestQueue"]

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    """One generation request plus its serving-time state."""

    prompt: Sequence[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    # -- engine-owned state ------------------------------------------------
    state: str = "waiting"              # waiting | running | done
    generated: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1                      # engine slot while running
    submit_t: float = dataclasses.field(default_factory=time.perf_counter)
    first_token_t: float | None = None  # time-to-first-token source
    finish_t: float | None = None
    logits: list = dataclasses.field(default_factory=list)  # engine record mode
    # chunked prefill / prefix reuse progress
    prefilled: int = 0                  # prompt tokens already in slot KV
    prefix_len: int = 0                 # of which: reused from the prefix cache
    prefix_entry: Any = None            # pinned PrefixEntry until loaded
    # streaming: per-token callback and/or a consumer-side iterator queue
    on_token: Callable[["Request", int], None] | None = None
    stream_q: _queue.Queue | None = None

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        if not self.prompt:
            raise ValueError("Request needs a non-empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def tokens(self) -> list[int]:
        """Prompt + generation, the full sequence so far."""
        return list(self.prompt) + self.generated

    def token_stream(self, timeout: float | None = None) -> Iterator[int]:
        """Yield generated tokens as the engine emits them.

        Only for requests submitted with ``stream=True``; the engine pushes
        each token into ``stream_q`` from ``_emit`` and a ``None`` sentinel
        on completion. Safe to consume from any thread while the engine's
        step loop runs elsewhere.
        """
        if self.stream_q is None:
            raise ValueError(
                f"request {self.rid} was not submitted with stream=True")
        while True:
            tok = self.stream_q.get(timeout=timeout)
            if tok is None:
                return
            yield tok


class RequestQueue:
    """Thread-safe FIFO of waiting requests.

    ``submit`` may be called from any thread (a frontend handler); ``peek`` /
    ``pop`` are the scheduler's side and preserve arrival order — bucket
    grouping never reorders across the queue head, it only limits how far a
    micro-batch extends.
    """

    def __init__(self):
        self._q: deque[Request] = deque()
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def submit(self, req: Request) -> Request:
        if req.state != "waiting":
            raise ValueError(f"request {req.rid} already {req.state}")
        with self._nonempty:
            self._q.append(req)
            self._nonempty.notify()
        return req

    def peek(self, n: int) -> list[Request]:
        """The first ``n`` waiting requests (no removal)."""
        with self._lock:
            return list(itertools.islice(self._q, n))

    def pop(self, requests: list[Request]) -> None:
        """Remove specific requests (the subset a micro-batch admitted)."""
        with self._lock:
            picked = set(id(r) for r in requests)
            self._q = deque(r for r in self._q if id(r) not in picked)

    def wait_nonempty(self, timeout: float | None = None) -> bool:
        with self._nonempty:
            if self._q:
                return True
            self._nonempty.wait(timeout)
            return bool(self._q)
