"""Speculative decoding: a draft proposes γ tokens, one verify forward scores
them, greedy accept/rollback keeps output token-exact.

Why this is cheap here: FalconGEMM serving already amortizes Decision-Module
plans over a closed pow2 bucket grid, so draft steps and the ``(batch, γ+1)``
verify forward are *just more buckets* — ``warm_buckets(spec_gamma=γ)``
pre-plans them (the only new registry symbol is ``logit_tokens = B·(γ+1)``,
since the lm head scores every verify row), and a layer-sliced self-draft
shares the target's per-layer contraction shapes, so speculation adds zero
plan-cache keys beyond the two extra bucket contexts.

Greedy accept rule (:meth:`~repro.serve.engine.ServeEngine` verify round):
feed ``[t_last, d_1..d_γ]`` through one cached forward, take per-row argmax
``t'_0..t'_γ``, accept the longest prefix with ``d_j == t'_{j-1}``, emit
``t'_0..t'_{n_acc}`` (the bonus token makes every round emit ≥ 1). By
induction each emitted token equals what sequential greedy decode would have
produced, so exactness never depends on draft quality — acceptance rate only
sets the speedup. Rollback is free for attention KV: decode validity admits
``kpos < pos + S`` and every position is rewritten before it first becomes
visible, so rejected draft K/V is simply never observed. Recurrent SSM state
cannot roll back, which is why the engine gates speculation to the
``dense``/``moe`` families.

The draft keeps its own slot KV consistent with a fixed-shape *catch-up*
trick: every round starts with one ``(B, 2)`` forward feeding
``[t_prev, t_last]`` at ``pos-1`` — re-writing an already-cached position is
idempotent (same prefix ⇒ same K/V) — which repairs the draft cache for any
acceptance count of the previous round, including full acceptance, with
uniform warmed shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.train.steps import make_chunk_prefill_step, make_decode_step

__all__ = ["DraftModel", "SelfDraft"]


@runtime_checkable
class DraftModel(Protocol):
    """What the engine needs from a draft: propose tokens, mirror prefill.

    A draft owns its own per-slot cache and stays position-synchronized with
    the target through these four calls; any model with the same tokenizer
    can implement it (a distilled small model, an n-gram cache, ...).
    :class:`SelfDraft` is the registry-derived reference implementation.
    """

    def prefill_chunk(self, tokens, start, last, slots, k) -> None:
        """Mirror one (padded) prefill chunk into the draft's slot cache."""
        ...

    def propose(self, slot_idx, last2, pos, gamma: int, k: int) -> np.ndarray:
        """γ greedy draft tokens per row -> (B, γ) int array."""
        ...

    def snapshot(self, slot: int, length: int) -> Any:
        """Opaque per-slot prefix payload for the radix prefix cache."""
        ...

    def load(self, slot: int, payload: Any, length: int) -> None:
        """Restore a :meth:`snapshot` payload into ``slot``."""
        ...

    def warm(self, policy, gamma: int, params_like=None) -> int:
        """Compile every step shape; returns the number of shapes."""
        ...


class SelfDraft:
    """Layer-sliced self-draft: the target's first ``keep_layers`` layers.

    Built from the *raw* (pre-precombine) target params: the scan-stacked
    ``params["layers"]`` tree is sliced ``[:keep]`` and the embedding, final
    norm and lm head are shared, under ``dataclasses.replace(cfg,
    num_layers=keep)`` (layer windows are index-periodic, so the slice keeps
    each kept layer's own window). Same d_model/heads/ffn ⇒ identical
    per-layer contraction shapes ⇒ the draft hits the same warmed plan-cache
    keys as the target.

    ``keep_layers=None`` keeps every layer — the *identity draft*, whose
    proposals match the target's greedy choice (acceptance ≈ 1.0). That is
    the default for smoke/bench runs on randomly initialized weights, where
    a truncated stack predicts noise; real deployments pick
    ``keep_layers < num_layers`` to trade acceptance for draft speed.
    """

    def __init__(self, model_cfg, params, *, max_slots: int, max_len: int,
                 keep_layers: int | None = None):
        keep = int(keep_layers or model_cfg.num_layers)
        if not 1 <= keep <= model_cfg.num_layers:
            raise ValueError(
                f"keep_layers={keep} out of range 1..{model_cfg.num_layers}")
        self.cfg = dataclasses.replace(model_cfg, num_layers=keep)
        self.keep_layers = keep
        if keep == model_cfg.num_layers:
            self.params = params            # identity draft shares the tree
        else:
            self.params = dict(params)
            self.params["layers"] = jax.tree.map(
                lambda p: p[:keep], params["layers"])
        self.max_len = max_len
        self.cache = M.init_cache(self.cfg, max_slots, max_len)
        self._chunk_fn = jax.jit(make_chunk_prefill_step(self.cfg))
        self._decode_fn = jax.jit(make_decode_step(self.cfg))

    # -- prefill mirror ------------------------------------------------------

    def prefill_chunk(self, tokens, start, last, slots, k) -> None:
        B = tokens.shape[0]
        idx = jnp.asarray(list(slots) + [slots[-1]] * (B - k))
        rows = jax.tree.map(lambda c: c[:, idx], self.cache)
        logits, new_rows = self._chunk_fn(
            self.params, rows, jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(last))
        jax.block_until_ready(logits)
        sl = jnp.asarray(list(slots))
        self.cache = jax.tree.map(
            lambda c, nc: c.at[:, sl].set(nc[:, :k].astype(c.dtype)),
            self.cache, new_rows)

    # -- drafting ------------------------------------------------------------

    def propose(self, slot_idx, last2, pos, gamma: int, k: int) -> np.ndarray:
        """Catch-up ``(B, 2)`` forward, then γ-1 single-token greedy steps."""
        idx = jnp.asarray(slot_idx)
        pos = jnp.asarray(pos)
        rows = jax.tree.map(lambda c: c[:, idx], self.cache)
        # catch-up: re-feed [t_prev, t_last] at pos-1; rewriting the cached
        # position pos-1 is idempotent, and this repairs the draft KV after
        # any acceptance count of the previous round with one fixed shape
        logits, rows = self._decode_fn(
            self.params, rows, jnp.asarray(last2), pos - 1)
        out = [np.argmax(np.asarray(logits[:, -1]), axis=-1)]
        p = pos + 1
        for _ in range(gamma - 1):
            logits, rows = self._decode_fn(
                self.params, rows, jnp.asarray(out[-1][:, None], jnp.int32), p)
            out.append(np.argmax(np.asarray(logits[:, -1]), axis=-1))
            p = p + 1
        real = idx[:k]
        self.cache = jax.tree.map(
            lambda c, nc: c.at[:, real].set(nc[:, :k].astype(c.dtype)),
            self.cache, rows)
        return np.stack(out, axis=1).astype(np.int32)

    # -- prefix-cache payloads ----------------------------------------------

    def snapshot(self, slot: int, length: int) -> Any:
        out = {}
        for name, c in self.cache.items():
            out[name] = np.asarray(c[:, slot] if name == "state"
                                   else c[:, slot, :length])
        return out

    def load(self, slot: int, payload: Any, length: int) -> None:
        new = {}
        for name, c in self.cache.items():
            v = jnp.asarray(payload[name]).astype(c.dtype)
            new[name] = (c.at[:, slot].set(v) if name == "state"
                         else c.at[:, slot, :length].set(v))
        self.cache = new

    # -- warmup --------------------------------------------------------------

    def warm(self, policy, gamma: int, params_like=None) -> int:
        """Compile the draft's chunk-prefill, catch-up and single-token
        shapes on zeros so no live round pays a trace."""
        n = 0
        for (b, s) in policy.prefill_shapes():
            rows = jax.tree.map(
                lambda c: jnp.broadcast_to(
                    c[:, :1], (c.shape[0], b) + c.shape[2:]), self.cache)
            jax.block_until_ready(self._chunk_fn(
                self.params, rows, jnp.zeros((b, s), jnp.int32),
                jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32)))
            n += 1
        for b in policy.decode_batch:
            rows = jax.tree.map(
                lambda c: jnp.broadcast_to(
                    c[:, :1], (c.shape[0], b) + c.shape[2:]), self.cache)
            jax.block_until_ready(self._decode_fn(
                self.params, rows, jnp.zeros((b, 2), jnp.int32),
                jnp.zeros((b,), jnp.int32)))
            if gamma > 1:
                jax.block_until_ready(self._decode_fn(
                    self.params, rows, jnp.zeros((b, 1), jnp.int32),
                    jnp.zeros((b,), jnp.int32)))
                n += 1
            n += 1
        return n
