"""Radix prefix cache: shared prompt prefixes skip re-prefill.

Serving traffic repeats prompt prefixes constantly — system prompts, few-shot
preambles, multi-turn histories. The engine snapshots each finished prefill's
slot KV (and recurrent state) keyed by the prompt tokens; a later request
whose prompt extends a cached prefix copies the snapshot into its slot and
prefills only the suffix. The index is a compressed radix trie over token
sequences, so ``lookup`` returns the *longest* cached prefix in one walk and
shared prefixes share trie nodes.

Entries are evicted LRU under a fixed capacity, except entries **pinned** by
an in-flight request (looked up at submit, released once the snapshot is
copied into the slot): a pinned entry is never evicted, so the payload a
scheduled request depends on cannot vanish between admission and prefill
(property-tested in ``tests/test_serve_spec.py``).

Payloads are opaque to the cache. The engine stores per-family snapshots:
attention K/V rows sliced to the prefix length, SSM/hybrid recurrent state
(valid only at exactly the inserted length — which is why the engine looks
up ``prompt[:-1]``, guaranteeing at least one suffix token to prefill so the
last-token logits are always recomputed), and the draft model's KV when
speculation is on.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any

__all__ = ["PrefixEntry", "RadixPrefixCache"]


class PrefixEntry:
    """One cached prefix: its token key, an opaque payload, and a pin count."""

    __slots__ = ("tokens", "payload", "pins", "tick")

    def __init__(self, tokens: tuple[int, ...], payload: Any):
        self.tokens = tokens
        self.payload = payload
        self.pins = 0
        self.tick = 0

    def __len__(self) -> int:
        return len(self.tokens)

    def __repr__(self) -> str:
        return (f"PrefixEntry(len={len(self.tokens)}, pins={self.pins}, "
                f"tick={self.tick})")


class _Node:
    """Radix trie node; the incoming edge holds a run of tokens."""

    __slots__ = ("edge", "children", "entry")

    def __init__(self, edge: tuple[int, ...] = ()):
        self.edge = edge
        self.children: dict[int, _Node] = {}
        self.entry: PrefixEntry | None = None


class RadixPrefixCache:
    """Compressed-trie prefix cache with LRU eviction and pinning.

    Thread-safe: ``lookup`` runs on submit (frontend threads), ``insert`` /
    ``release`` on the engine's step thread.
    """

    def __init__(self, max_entries: int = 32):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._root = _Node()
        self._entries: dict[tuple[int, ...], PrefixEntry] = {}
        self._clock = itertools.count(1)
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- queries -------------------------------------------------------------

    def lookup(self, tokens, pin: bool = False
               ) -> tuple[int, PrefixEntry | None]:
        """Longest cached prefix of ``tokens`` -> (length, entry).

        ``pin=True`` bumps the entry's pin count — the caller owns a
        reference that blocks eviction until :meth:`release`. Returns
        ``(0, None)`` on a miss.
        """
        toks = tuple(int(t) for t in tokens)
        with self._lock:
            best: PrefixEntry | None = None
            node, i = self._root, 0
            while i < len(toks):
                child = node.children.get(toks[i])
                if child is None:
                    break
                edge = child.edge
                if toks[i:i + len(edge)] != edge:
                    break           # partial edge match: no entry down here
                i += len(edge)
                node = child
                if node.entry is not None:
                    best = node.entry
            if best is None:
                self.misses += 1
                return 0, None
            self.hits += 1
            best.tick = next(self._clock)
            if pin:
                best.pins += 1
            return len(best.tokens), best

    def release(self, entry: PrefixEntry) -> None:
        """Drop one pin (the request copied the snapshot into its slot)."""
        with self._lock:
            if entry.pins > 0:
                entry.pins -= 1

    # -- updates -------------------------------------------------------------

    def insert(self, tokens, payload: Any) -> PrefixEntry:
        """Cache ``payload`` under ``tokens``; refreshes an existing entry."""
        toks = tuple(int(t) for t in tokens)
        if not toks:
            raise ValueError("cannot cache an empty prefix")
        with self._lock:
            existing = self._entries.get(toks)
            if existing is not None:
                existing.payload = payload
                existing.tick = next(self._clock)
                return existing
            entry = PrefixEntry(toks, payload)
            entry.tick = next(self._clock)
            self._insert_node(toks, entry)
            self._entries[toks] = entry
            while len(self._entries) > self.max_entries:
                if not self._evict_one():
                    break           # everything pinned: tolerate overflow
            return entry

    def _insert_node(self, toks: tuple[int, ...], entry: PrefixEntry) -> None:
        node, i = self._root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None:
                leaf = _Node(toks[i:])
                leaf.entry = entry
                node.children[toks[i]] = leaf
                return
            edge = child.edge
            common = 0
            while (common < len(edge) and i + common < len(toks)
                   and edge[common] == toks[i + common]):
                common += 1
            if common == len(edge):
                node, i = child, i + common
                continue
            # split the edge at the divergence point
            mid = _Node(edge[:common])
            child.edge = edge[common:]
            mid.children[child.edge[0]] = child
            node.children[toks[i]] = mid
            node, i = mid, i + common
        node.entry = entry

    def _evict_one(self) -> bool:
        victims = [e for e in self._entries.values() if e.pins == 0]
        if not victims:
            return False
        victim = min(victims, key=lambda e: e.tick)
        self._remove(victim.tokens)
        self.evictions += 1
        return True

    def _remove(self, toks: tuple[int, ...]) -> None:
        self._entries.pop(toks, None)
        path: list[tuple[_Node, _Node]] = []      # (parent, child) walked
        node, i = self._root, 0
        while i < len(toks):
            child = node.children.get(toks[i])
            if child is None or toks[i:i + len(child.edge)] != child.edge:
                return
            path.append((node, child))
            i += len(child.edge)
            node = child
        node.entry = None
        # prune entry-less leaf chains so the trie doesn't grow unboundedly
        for parent, child in reversed(path):
            if child.entry is None and not child.children:
                del parent.children[child.edge[0]]
            elif child.entry is None and len(child.children) == 1:
                # merge a pass-through node into its only child
                (only,) = child.children.values()
                only.edge = child.edge + only.edge
                parent.children[child.edge[0]] = only
            else:
                break

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "pinned": sum(1 for e in self._entries.values() if e.pins)}
