"""Mesh-axis conventions and parameter/activation sharding rules.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod. The "pod" axis is pure data parallelism across pods (only the
gradient all-reduce crosses the inter-pod links), "data" is DP/FSDP inside a
pod, "model" is tensor/expert parallelism.

Parameters are sharded by *path-pattern rules* (T5X/MaxText style): a table of
regexes over the flattened param path decides each leaf's PartitionSpec.
``fsdp=True`` additionally shards the non-model dimension of large matrices
over "data" (ZeRO-3 style parameter sharding); ``seq_shard=True`` turns on
sequence/context parallelism for long-context cells (KV cache and activation
sequence dims over "data").
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

__all__ = ["ShardingRules", "batch_axes", "param_sharding", "activation_specs",
           "named_sharding", "make_rules", "layouts_for_mesh"]


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Compiled rule table: list of (path_regex, ndim -> PartitionSpec)."""

    rules: tuple[tuple[str, tuple], ...]
    batch: tuple[str, ...]
    axis_sizes: tuple[tuple[str, int], ...] = ()
    seq_shard: bool = False

    def _fits(self, dim: int, axis) -> bool:
        sizes = dict(self.axis_sizes)
        axes = axis if isinstance(axis, tuple) else (axis,)
        total = int(np.prod([sizes.get(a, 1) for a in axes]))
        return dim % total == 0

    def spec_for(self, path: str, shape: tuple[int, ...]) -> P:
        for pat, spec in self.rules:
            if re.search(pat, path):
                # rules are written for the param's trailing dims; stacked
                # per-layer params carry a leading L dim which is unsharded.
                if len(spec) < len(shape):
                    spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
                elif len(spec) > len(shape):
                    spec = tuple(spec)[-len(shape):]
                # drop axes that do not divide the dim (e.g. 5 KV heads vs a
                # 16-way model axis, vocab 49155 vs 16-way data axis).
                spec = tuple(
                    a if a is not None and self._fits(shape[i], a) else None
                    for i, a in enumerate(spec)
                )
                return P(*spec)
        return P()  # replicate by default (norm scales, biases, ...)


def make_rules(mesh: Mesh, fsdp: bool = False, seq_shard: bool = False,
               style: str = "tp") -> ShardingRules:
    if style == "fsdp_only":
        # no tensor parallelism: batch over every axis, params ZeRO-3-sharded
        # over (data x model) on their first (largest) dim.
        b = batch_axes(mesh) + ("model",)
        fs2 = ("data", "model")
        table = [
            (r"embed|lm_head|w_q|w_qkv|w_k|w_v|w_o|mlp_|moe_|router|ssm_in|ssm_out|frontend",
             (fs2, None)),
        ]
        axis_sizes = tuple((n, int(mesh.shape[n])) for n in mesh.axis_names)
        return ShardingRules(tuple(table), b, axis_sizes, seq_shard)
    b = batch_axes(mesh)
    fs = "data" if fsdp else None
    # NOTE: order matters — first match wins.
    table = [
        # embeddings / tied lm head: vocab over model (=> logits shard over
        # vocab, no (T,V) all-reduce), embed dim unsharded
        (r"embed", ("model", fs)),
        (r"lm_head", (fs, "model")),
        # attention projections
        (r"\bw_q\b|w_qkv|w_kv|\bw_k\b|\bw_v\b", (fs, "model")),
        (r"\bw_o\b", ("model", fs)),
        # MoE: experts over model; per-expert matrices over fsdp/None
        (r"moe_(gate|up)", ("model", fs, None)),
        (r"moe_down", ("model", None, fs)),
        (r"router", (fs, "model")),
        # dense MLP
        (r"mlp_(gate|up)", (fs, "model")),
        (r"mlp_down", ("model", fs)),
        # mamba/SSD: inner channels over model
        (r"ssm_in", (fs, "model")),
        (r"ssm_out", ("model", fs)),
        (r"ssm_(A|D|dt_bias)", ("model",)),
        (r"conv_w", (None, "model")),
        # patch/frame stub frontends
        (r"frontend", (fs, "model")),
    ]
    axis_sizes = tuple((name, int(mesh.shape[name])) for name in mesh.axis_names)
    return ShardingRules(tuple(table), b, axis_sizes, seq_shard)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def param_sharding(params_shape: Any, mesh: Mesh, rules: ShardingRules):
    """Pytree of NamedShardings matching a pytree of arrays/ShapeDtypeStructs."""
    flat, tree = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [
        NamedSharding(mesh, rules.spec_for(_path_str(path), leaf.shape))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(tree, specs)


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


import contextvars

_STYLE_CTX = contextvars.ContextVar("repro_parallel_style", default="tp")

# sentinel resolved by shard_act according to the active parallel style
BATCH = "BATCH"


def set_parallel_style(style: str):
    """"tp" (default) or "fsdp_only". Returns a token for ContextVar.reset."""
    assert style in ("tp", "fsdp_only"), style
    return _STYLE_CTX.set(style)


def get_parallel_style() -> str:
    return _STYLE_CTX.get()


def resolve_batch_axes() -> tuple[str, ...]:
    if _STYLE_CTX.get() == "fsdp_only":
        return ("pod", "data", "model")
    return ("pod", "data")


def layouts_for_mesh(mesh: Mesh | None = None, style: str | None = None):
    """Candidate shard layouts for pricing a dense contraction on ``mesh``.

    Returns ``(n_devices, layouts)`` for the shard-aware Decision Module
    (``falcon_gemm.plan_sharded``). The rule table is the parallel style's:

      * ``"tp"``        — weights shard over the "model" axis; candidates are
        replicated / column-parallel (all-gather C) / row-parallel
        (all-reduce C), with D = model-axis size;
      * ``"fsdp_only"`` — activations shard over every batch axis; candidates
        are replicated (gather A and B) vs batch-sharded with a weight
        all-gather, with D = the product of batch-axis sizes.

    Without a mesh (or with a trivial axis) this degenerates to
    ``(1, (replicated,))`` — the local model.
    """
    from repro.core import decision as dec

    if mesh is None:
        mesh = compat.get_abstract_mesh()
    if mesh is None:
        return 1, (dec.layout_by_name("replicated"),)
    style = style or get_parallel_style()
    sizes = dict(mesh.shape)
    if style == "fsdp_only":
        axes = tuple(a for a in resolve_batch_axes() if a in sizes)
        d = int(np.prod([sizes[a] for a in axes])) if axes else 1
        layouts = dec.fsdp_layouts()
    else:
        d = int(sizes.get("model", 1))
        layouts = dec.default_layouts()
    if d <= 1:
        return 1, (dec.layout_by_name("replicated"),)
    return d, layouts


def shard_act(x, *spec):
    """Constrain an activation's sharding, tolerantly.

    Usable from model code that may run with or without a mesh context:
    axes not present in the active mesh are dropped, axes that don't divide
    the corresponding dim are dropped (e.g. hymba's 25 heads on a 16-way
    model axis), and without any mesh this is the identity. The BATCH
    sentinel resolves per the active parallel style; under "fsdp_only" the
    model axis belongs to batch, so non-batch "model" references are dropped.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(mesh.shape)
    style = _STYLE_CTX.get()

    def filt(a, dim):
        if a is None:
            return None
        if a == BATCH:
            a = resolve_batch_axes()
        elif style == "fsdp_only":
            return None  # "model"/other TP refs are batch-owned in this style
        axes = a if isinstance(a, tuple) else (a,)
        axes = tuple(ax for ax in axes if ax in names)
        if not axes:
            return None
        total = int(np.prod([sizes[ax] for ax in axes]))
        if dim % total != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    spec = tuple(spec) + (None,) * (x.ndim - len(spec))
    fspec = P(*[filt(a, d) for a, d in zip(spec, x.shape)])
    return jax.lax.with_sharding_constraint(x, fspec)


def activation_specs(rules: ShardingRules) -> dict[str, P]:
    """Canonical activation PartitionSpecs used via with_sharding_constraint."""
    if rules.seq_shard:
        # context parallelism: batch is tiny (e.g. 1); shard sequence over
        # "data" instead, keeping only the pod axis (if any) on batch.
        b = tuple(a for a in rules.batch if a == "pod")
        seq = "data"
    else:
        b, seq = rules.batch, None
    return {
        "tokens": P(b, seq),
        "hidden": P(b, seq, None),               # (B, S, d)
        "heads": P(b, seq, "model", None),       # (B, S, H, hd)
        "kv_cache": P(b, seq, "model", None),    # (B, S_max, Hkv, hd)
        "ffn": P(b, seq, "model"),               # (B, S, d_ff)
        "logits": P(b, seq, "model"),            # (B, S, V)
        "ssm_state": P(b, "model", None, None),  # (B, H, N, P)
        "moe_buf": P("model", b, None),          # (E, C, d)
    }
