"""Compressed data-parallel gradient all-reduce (distributed-optimization trick).

int8 quantization with a shared per-leaf scale: each DP shard quantizes its
local gradient to int8 against the global max (one scalar all-reduce), the
int8 payload is summed in int32, and the mean is dequantized. 4x (bf16) / 8x
(f32) less DP all-reduce traffic for <1e-2 relative error on LM gradients.

Used inside ``shard_map`` over the DP axes (see ``repro.train.steps``'s
``make_compressed_dp_train_step``). Error feedback (residual accumulation) is
available for accuracy-critical runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

__all__ = ["compressed_psum_mean", "psum_mean"]


def psum_mean(tree, axis_names):
    n = 1
    for a in axis_names:
        n *= compat.axis_size(a)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_names) / n, tree)


def _q_one(g, axis_names, bits: int):
    levels = float(2 ** (bits - 1) - 1)
    g32 = g.astype(jnp.float32)
    scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_names)
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(g32 / scale * levels), -levels, levels).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    n = 1
    for a in axis_names:
        n *= compat.axis_size(a)
    return (total.astype(jnp.float32) * (scale / levels) / n).astype(g.dtype)


def compressed_psum_mean(tree, axis_names, bits: int = 8):
    """Mean-all-reduce every leaf of ``tree`` with int``bits`` compression."""
    return jax.tree.map(lambda g: _q_one(g, axis_names, bits), tree)
