"""The FalconGEMM public API — ``import repro.api as falcon``.

One import gives the whole dispatch surface:

    import repro.api as falcon

    with falcon.use(falcon.FalconConfig(hardware="tpu_v5e")):
        y = falcon.dense(x, w)                       # context config
        s = falcon.einsum("bqhd,bkhd->bhqk", q, k)   # hits the Decision Module
        c = falcon.dot_general(a, b, dimension_numbers)

    pw = falcon.plan_weight(w, m_hint=batch * prompt_len)   # offline Combine B
    y = falcon.dense(x, pw)                                 # serving fast path

    falcon.register_backend("mine", my_apply_fn)            # pluggable exec
    falcon.dense(x, w, cfg=falcon.FalconConfig(backend="mine"))

Compatibility forms (``falcon_matmul(a, b, cfg)`` / ``falcon_dense(x, w,
cfg)`` with an explicit config) keep working; see ``docs/api.md`` for the
old-to-new migration table.
"""
from __future__ import annotations

from repro.core.backends import (Backend, available_backends, get_backend,
                                 register_backend, unregister_backend)
from repro.core.decision import backward_shapes
from repro.core.engine import (FalconEngine, PlannedWeight, active_config,
                               current_config, dense, dot_general, einsum,
                               grouped_expert_shapes, grouped_matmul, matmul,
                               plan_weight, precombine_params,
                               projection_shapes, refresh_planned_params, use,
                               warm_buckets)
from repro.core.falcon_gemm import (FalconConfig, falcon_dense, falcon_matmul,
                                    grouped_matmul_with_precombined,
                                    matmul_with_precombined, plan,
                                    plan_batched, plan_sharded,
                                    plan_training,
                                    precombine_weights)
from repro.core.workloads import (Contraction, ContractionSpec,
                                  contraction_set, dense_projection_shapes,
                                  grouped_moe_shapes, resolve_contractions)

__all__ = [
    # context-scoped config
    "use", "current_config", "active_config", "FalconConfig", "FalconEngine",
    # dispatch entry points
    "dense", "matmul", "dot_general", "einsum", "plan", "plan_sharded",
    # grouped batched dispatch (group-parallel execution)
    "grouped_matmul", "plan_batched", "grouped_expert_shapes",
    "grouped_matmul_with_precombined",
    # planned training (custom-VJP backward)
    "plan_training", "backward_shapes", "refresh_planned_params",
    # precombined weights (offline Combine B)
    "PlannedWeight", "plan_weight", "precombine_params",
    "precombine_weights", "matmul_with_precombined",
    # workload registry (config -> contraction set -> warm plan)
    "ContractionSpec", "Contraction", "contraction_set",
    "resolve_contractions", "dense_projection_shapes", "grouped_moe_shapes",
    # bucket pre-planning (continuous-batching serve path)
    "warm_buckets", "projection_shapes",
    # backend registry
    "Backend", "register_backend", "unregister_backend", "get_backend",
    "available_backends",
    # compatibility forms
    "falcon_matmul", "falcon_dense",
]
