"""LCMA: Lower-Complexity Matrix Multiplication Algorithm abstraction.

An LCMA is the tuple ``L = <m, k, n, R, U, V, W>`` (paper §II-A):

  * ``(m, k, n)``  — grid dimensions partitioning (M, K, N),
  * ``R``          — rank: number of submatrix multiplications (R < m*k*n),
  * ``U in S^{R x m x k}``, ``V in S^{R x k x n}``, ``W in S^{R x m x n}``
    — coefficient tensors, S = {-1, 0, 1} for every scheme in this library.

Correctness is the bilinear identity

    sum_r U[r,i,l] * V[r,l',j] * W[r,i',j'] == d(i,i') d(j,j') d(l,l')

which ``validate()`` checks exhaustively (it is exactly "this decomposition
expresses the <m,k,n> matrix-multiplication tensor with rank R").
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property, lru_cache

import numpy as np

__all__ = ["LCMA", "validate", "apply_reference", "matmul_tensor"]


def _check_coefficients(name: str, which: str, arr) -> np.ndarray:
    """Validate a coefficient tensor at construction (= registry) time.

    Every execution path (codegen, Pallas kernels) bakes coefficients in as
    small integers; a float listing that silently truncated under the old
    ``astype(int8)`` computed wrong results without any error. Non-integer
    values and magnitudes outside the int8 range are rejected here, so a bad
    scheme fails at registration, not at matmul time.
    """
    a = np.asarray(arr)
    if a.dtype.kind == "f":
        if not np.all(a == np.round(a)):
            raise ValueError(
                f"LCMA {name}: {which} has non-integer coefficients "
                f"(worst offender: {a.flat[np.argmax(np.abs(a - np.round(a)))]!r}); "
                f"only integer coefficient tensors are supported")
        a = np.round(a)
    elif a.dtype.kind not in "iub":
        raise ValueError(
            f"LCMA {name}: {which} has unsupported coefficient dtype {a.dtype}")
    if np.any(np.abs(a.astype(np.int64)) > 127):
        raise ValueError(
            f"LCMA {name}: {which} coefficient magnitude "
            f"{int(np.max(np.abs(a.astype(np.int64))))} exceeds the supported "
            f"int8 range")
    return a.astype(np.int8)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash => usable as a jit static arg
class LCMA:
    """A bilinear matrix-multiplication scheme ``<m,k,n,R,U,V,W>``."""

    name: str
    m: int
    k: int
    n: int
    R: int
    U: np.ndarray  # (R, m, k) int8
    V: np.ndarray  # (R, k, n) int8
    W: np.ndarray  # (R, m, n) int8

    def __post_init__(self):
        U = np.ascontiguousarray(_check_coefficients(self.name, "U", self.U))
        V = np.ascontiguousarray(_check_coefficients(self.name, "V", self.V))
        W = np.ascontiguousarray(_check_coefficients(self.name, "W", self.W))
        object.__setattr__(self, "U", U)
        object.__setattr__(self, "V", V)
        object.__setattr__(self, "W", W)
        if U.shape != (self.R, self.m, self.k):
            raise ValueError(f"{self.name}: U shape {U.shape} != {(self.R, self.m, self.k)}")
        if V.shape != (self.R, self.k, self.n):
            raise ValueError(f"{self.name}: V shape {V.shape} != {(self.R, self.k, self.n)}")
        if W.shape != (self.R, self.m, self.n):
            raise ValueError(f"{self.name}: W shape {W.shape} != {(self.R, self.m, self.n)}")
        U.setflags(write=False)
        V.setflags(write=False)
        W.setflags(write=False)

    # ---- structural properties used by the Decision Module (Table II) ----
    @property
    def grid(self) -> tuple[int, int, int]:
        return (self.m, self.k, self.n)

    @cached_property
    def nnz_u(self) -> int:
        return int(np.count_nonzero(self.U))

    @cached_property
    def nnz_v(self) -> int:
        return int(np.count_nonzero(self.V))

    @cached_property
    def nnz_w(self) -> int:
        return int(np.count_nonzero(self.W))

    @property
    def mult_saving(self) -> float:
        """1 - R/(m*k*n): fraction of submatrix multiplications saved."""
        return 1.0 - self.R / (self.m * self.k * self.n)

    @property
    def key(self) -> str:
        return f"<{self.m},{self.k},{self.n}>;R={self.R}"

    @cached_property
    def fingerprint(self) -> str:
        """Content hash of the scheme *definition* (grid, rank, U/V/W).

        Two schemes with the same name but different coefficients get
        different fingerprints — the plan cache persists this next to the
        scheme name so `falcon-check` can prove a cached decision still
        refers to the definition that priced it.
        """
        h = hashlib.sha1()
        h.update(f"<{self.m},{self.k},{self.n}>;R={self.R};".encode())
        for t in (self.U, self.V, self.W):
            h.update(t.tobytes())
        return h.hexdigest()[:12]

    @cached_property
    def stability(self):
        """Static error-growth profile (``repro.analysis.stability``).

        Lazily computed and cached on the (frozen, long-lived) scheme object;
        the Decision Module reads it to reject candidates whose error bound
        exceeds a call site's accuracy budget without touching the analyzer
        package at import time.
        """
        from repro.analysis.stability import analyze
        return analyze(self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"LCMA({self.name}, {self.key}, |U|={self.nnz_u}, |V|={self.nnz_v}, |W|={self.nnz_w})"

    def is_valid(self) -> bool:
        return validate(self)


@lru_cache(maxsize=64)
def matmul_tensor(m: int, k: int, n: int) -> np.ndarray:
    """The <m,k,n> matrix-multiplication tensor ``d(i,i') d(j,j') d(l,l')``.

    Axes ``(i, l, l', j, i', j')``, int64. The shared ground truth for
    ``validate``, the discovery ALS target and the exact Brent verifier
    (``repro.analysis.brent``). Cached and marked read-only — callers that
    need a float/writable copy must copy.
    """
    expect = np.zeros((m, k, k, n, m, n), dtype=np.int64)
    for i in range(m):
        for a in range(k):
            for j in range(n):
                expect[i, a, a, j, i, j] = 1
    expect.setflags(write=False)
    return expect


def validate(l: LCMA, atol: float | None = None) -> bool:
    """Exhaustively check the bilinear identity for scheme ``l``.

    T[i,l, l',j, i',j'] = sum_r U[r,i,l] V[r,l',j] W[r,i',j'] must equal the
    <m,k,n> matmul tensor  d(i,i') d(j,j') d(l,l').

    The default (``atol=None``) is the EXACT integer path: ``LCMA``'s
    constructor guarantees int8 coefficients, so the identity is decided in
    int64 arithmetic with no tolerance — a pass is a certificate, not a
    float comparison (|T| <= R * 127**3 cannot overflow int64). Passing an
    explicit ``atol`` selects the float64 path, kept for validating
    *prospective* non-integer decompositions (e.g. un-rounded ALS iterates)
    before they are projected onto an integer scheme.
    """
    expect = matmul_tensor(l.m, l.k, l.n)
    if atol is None:
        U = l.U.astype(np.int64)
        V = l.V.astype(np.int64)
        W = l.W.astype(np.int64)
        T = np.einsum("ria,rbj,rcd->iabjcd", U, V, W)
        return bool(np.array_equal(T, expect))
    U = np.asarray(l.U, dtype=np.float64)
    V = np.asarray(l.V, dtype=np.float64)
    W = np.asarray(l.W, dtype=np.float64)
    T = np.einsum("ria,rbj,rcd->iabjcd", U, V, W)
    return bool(np.all(np.abs(T - expect) <= atol))


def apply_reference(l: LCMA, A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Reference (numpy, staged Algorithm 1) application of an LCMA.

    Requires M % m == 0, K % k == 0, N % n == 0 (the framework pads before
    reaching this point). Used as the ground-truth oracle in tests.
    """
    M, K = A.shape
    K2, N = B.shape
    if K != K2:
        raise ValueError(f"apply_reference({l.name}): A {A.shape} and "
                         f"B {B.shape} disagree on the contraction dimension")
    if M % l.m or K % l.k or N % l.n:
        # a bare assert here vanished under ``python -O``, letting misaligned
        # operands reshape into garbage instead of raising
        raise ValueError(
            f"apply_reference({l.name}): shape (M={M}, K={K}, N={N}) is not "
            f"divisible by the scheme grid <{l.m},{l.k},{l.n}> — pad first")
    Ms, Ks, Ns = M // l.m, K // l.k, N // l.n
    # Partition into submatrices.
    Ap = A.reshape(l.m, Ms, l.k, Ks).transpose(0, 2, 1, 3)  # (m,k,Ms,Ks)
    Bp = B.reshape(l.k, Ks, l.n, Ns).transpose(0, 2, 1, 3)  # (k,n,Ks,Ns)
    # Stage 1/2: combine (einsum over the small coefficient tensors).
    At = np.einsum("rik,ikxy->rxy", l.U.astype(A.dtype), Ap)
    Bt = np.einsum("rkn,knyz->ryz", l.V.astype(B.dtype), Bp)
    # Stage 3: R batched multiplications.
    H = np.einsum("rxy,ryz->rxz", At, Bt)
    # Stage 4: combine H.
    Cp = np.einsum("rin,rxz->inxz", l.W.astype(A.dtype), H)
    return Cp.transpose(0, 2, 1, 3).reshape(M, N)
