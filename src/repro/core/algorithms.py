"""LCMA scheme library: classical algorithms + validated constructions.

The paper draws its candidate set from AlphaTensor's published coefficients;
those exact tensors are not available offline, so this library populates
``S_LCMA`` with classical schemes (Strassen, Strassen-Winograd, Laderman) and
*constructed* schemes obtained by closure operations that provably preserve
correctness:

  * ``tensor_product``  <m1,k1,n1>;R1 x <m2,k2,n2>;R2 -> <m1m2,k1k2,n1n2>;R1R2
  * ``concat_m/k/n``    block-concatenation along one grid dimension
  * ``cyclic`` / ``transpose_dual``  symmetries of the matmul tensor

Every scheme — hand-written or constructed — is machine-verified against the
matmul tensor identity at library-build time (``lcma.validate``); an invalid
scheme is a hard error. Ranks match published optima where known (e.g.
<2,2,3>;11 equals the Hopcroft-Kerr rank).
"""
from __future__ import annotations

import itertools
from functools import lru_cache

import numpy as np

from .lcma import LCMA, validate

__all__ = [
    "standard", "strassen", "strassen_winograd", "laderman",
    "tensor_product", "concat_m", "concat_k", "concat_n",
    "cyclic", "transpose_dual", "library", "get", "candidates", "register",
    "unregister",
]


# --------------------------------------------------------------------------
# Elementary schemes
# --------------------------------------------------------------------------

def standard(m: int, k: int, n: int) -> LCMA:
    """The trivial rank-mkn algorithm (used as a composition building block)."""
    R = m * k * n
    U = np.zeros((R, m, k), np.int8)
    V = np.zeros((R, k, n), np.int8)
    W = np.zeros((R, m, n), np.int8)
    r = 0
    for i in range(m):
        for l in range(k):
            for j in range(n):
                U[r, i, l] = 1
                V[r, l, j] = 1
                W[r, i, j] = 1
                r += 1
    return LCMA(f"standard-{m}{k}{n}", m, k, n, R, U, V, W)


def _from_terms(name, m, k, n, terms, cexprs) -> LCMA:
    """Build an LCMA from symbolic product terms.

    ``terms``: list of (a_lin, b_lin) where a_lin maps (i,l)->coeff and
    b_lin maps (l,j)->coeff.   ``cexprs``: maps (i,j) -> {r: coeff}.
    Out-of-range indices raise ``ValueError`` naming the offending term —
    a transcribed listing with a bad index must fail loudly, not wrap
    around via negative indexing into the wrong coefficient slot.
    """
    R = len(terms)
    if R < 1:
        raise ValueError(f"_from_terms({name}): empty term list")
    U = np.zeros((R, m, k), np.int8)
    V = np.zeros((R, k, n), np.int8)
    W = np.zeros((R, m, n), np.int8)
    for r, (al, bl) in enumerate(terms):
        for (i, l), c in al.items():
            if not (0 <= i < m and 0 <= l < k):
                raise ValueError(f"_from_terms({name}): term {r} indexes "
                                 f"A[{i},{l}] outside the {m}x{k} grid")
            U[r, i, l] = c
        for (l, j), c in bl.items():
            if not (0 <= l < k and 0 <= j < n):
                raise ValueError(f"_from_terms({name}): term {r} indexes "
                                 f"B[{l},{j}] outside the {k}x{n} grid")
            V[r, l, j] = c
    for (i, j), combo in cexprs.items():
        if not (0 <= i < m and 0 <= j < n):
            raise ValueError(f"_from_terms({name}): output C[{i},{j}] outside "
                             f"the {m}x{n} grid")
        for r, c in combo.items():
            if not (0 <= r < R):
                raise ValueError(f"_from_terms({name}): C[{i},{j}] references "
                                 f"product term {r} outside 0..{R - 1}")
            W[r, i, j] = c
    return LCMA(name, m, k, n, R, U, V, W)


def strassen() -> LCMA:
    """Strassen's <2,2,2>;7 (paper Fig. 1)."""
    t = [
        ({(0, 0): 1, (1, 1): 1}, {(0, 0): 1, (1, 1): 1}),      # M1=(A11+A22)(B11+B22)
        ({(1, 0): 1, (1, 1): 1}, {(0, 0): 1}),                 # M2=(A21+A22)B11
        ({(0, 0): 1}, {(0, 1): 1, (1, 1): -1}),                # M3=A11(B12-B22)
        ({(1, 1): 1}, {(1, 0): 1, (0, 0): -1}),                # M4=A22(B21-B11)
        ({(0, 0): 1, (0, 1): 1}, {(1, 1): 1}),                 # M5=(A11+A12)B22
        ({(1, 0): 1, (0, 0): -1}, {(0, 0): 1, (0, 1): 1}),     # M6=(A21-A11)(B11+B12)
        ({(0, 1): 1, (1, 1): -1}, {(1, 0): 1, (1, 1): 1}),     # M7=(A12-A22)(B21+B22)
    ]
    c = {
        (0, 0): {0: 1, 3: 1, 4: -1, 6: 1},
        (0, 1): {2: 1, 4: 1},
        (1, 0): {1: 1, 3: 1},
        (1, 1): {0: 1, 1: -1, 2: 1, 5: 1},
    }
    return _from_terms("strassen", 2, 2, 2, t, c)


def strassen_winograd() -> LCMA:
    """Winograd's variant of <2,2,2>;7 — 15 additions instead of 18.

    Lower ||U||_0+||V||_0+||W||_0 => cheaper Combine stages in the Decision
    Module's Table-II accounting.
    """
    t = [
        ({(0, 0): 1}, {(0, 0): 1}),                                   # P1=A11 B11
        ({(0, 1): 1}, {(1, 0): 1}),                                   # P2=A12 B21
        ({(0, 0): 1, (0, 1): 1, (1, 0): -1, (1, 1): -1}, {(1, 1): 1}),  # P3=S4 B22
        ({(1, 1): 1}, {(0, 0): 1, (0, 1): -1, (1, 0): -1, (1, 1): 1}),  # P4=A22 T4
        ({(1, 0): 1, (1, 1): 1}, {(0, 1): 1, (0, 0): -1}),            # P5=S1 T1
        ({(1, 0): 1, (1, 1): 1, (0, 0): -1}, {(0, 0): 1, (0, 1): -1, (1, 1): 1}),  # P6=S2 T2
        ({(0, 0): 1, (1, 0): -1}, {(1, 1): 1, (0, 1): -1}),           # P7=S3 T3
    ]
    c = {
        (0, 0): {0: 1, 1: 1},
        (0, 1): {0: 1, 5: 1, 4: 1, 2: 1},
        (1, 0): {0: 1, 5: 1, 6: 1, 3: -1},
        (1, 1): {0: 1, 5: 1, 6: 1, 4: 1},
    }
    return _from_terms("strassen-winograd", 2, 2, 2, t, c)


# Rank-23 <3,3,3> ternary scheme of the Laderman family. Recovered offline by
# a rounding-homotopy ALS decomposition of the <3,3,3> matmul tensor (seeded
# from Laderman 1976) and machine-verified against the tensor identity; the
# exact published coefficient listing was unavailable offline. Encoding:
# row-major base-3 digits, digit = coeff + 1.
_LADERMAN_U = (
    "000221122211011111111121111011221111111221111211111111011111221011111211"
    "111111221222100001111111121110111122112111110112111111111111122110122111"
    "112110111111122111121111111111112111111211111111111211111111112"
)
_LADERMAN_V = (
    "111121111101121111021200012201121111021111111211111111210112111112110111"
    "012111111111112111210022201111121201111121101111111211111111021111112210"
    "111112110111111012111211111111111121112111111121111111111111112"
)
_LADERMAN_W = (
    "101111111111221111111211111121221111121121111222221212112111212111111212"
    "112111112112111111111111011121111221111111221222212221121111121112212111"
    "111212111112112111211111111111121111111112111111111121111111112"
)


def _decode(s: str, shape) -> np.ndarray:
    return (np.frombuffer(s.encode(), dtype=np.uint8) - ord("1")).astype(np.int8).reshape(shape)


def laderman() -> LCMA:
    """Rank-23 <3,3,3> scheme (Laderman family). Machine-verified at build."""
    return LCMA(
        "laderman", 3, 3, 3, 23,
        _decode(_LADERMAN_U, (23, 3, 3)),
        _decode(_LADERMAN_V, (23, 3, 3)),
        _decode(_LADERMAN_W, (23, 3, 3)),
    )


# --------------------------------------------------------------------------
# Closure operations (correctness-preserving constructions)
# --------------------------------------------------------------------------

def tensor_product(l1: LCMA, l2: LCMA, name: str | None = None) -> LCMA:
    """Kronecker composition: recursive application of l2 inside l1."""
    m, k, n = l1.m * l2.m, l1.k * l2.k, l1.n * l2.n
    R = l1.R * l2.R

    def kron(X1, X2, d1, d2, e1, e2):
        # (R1,d1,e1) x (R2,d2,e2) -> (R1*R2, d1*d2, e1*e2)
        out = np.einsum("rde,sfg->rsdfeg", X1.astype(np.int16), X2.astype(np.int16))
        return out.reshape(R, d1 * d2, e1 * e2).astype(np.int8)

    U = kron(l1.U, l2.U, l1.m, l2.m, l1.k, l2.k)
    V = kron(l1.V, l2.V, l1.k, l2.k, l1.n, l2.n)
    W = kron(l1.W, l2.W, l1.m, l2.m, l1.n, l2.n)
    return LCMA(name or f"({l1.name})x({l2.name})", m, k, n, R, U, V, W)


def _require_matching(op: str, l1: LCMA, l2: LCMA, dims1, dims2, what: str):
    # bare asserts here disappeared under ``python -O``, letting incompatible
    # grids concatenate into a silently-wrong scheme
    if dims1 != dims2:
        raise ValueError(
            f"{op}: incompatible grids — {l1.name} <{l1.m},{l1.k},{l1.n}> vs "
            f"{l2.name} <{l2.m},{l2.k},{l2.n}> (need matching {what})")


def concat_n(l1: LCMA, l2: LCMA, name: str | None = None) -> LCMA:
    """C = [A B1 | A B2]: <m,k,n1+n2>; R1+R2."""
    _require_matching("concat_n", l1, l2, (l1.m, l1.k), (l2.m, l2.k), "(m, k)")
    m, k = l1.m, l1.k
    n = l1.n + l2.n
    R = l1.R + l2.R
    U = np.concatenate([l1.U, l2.U], axis=0)
    V = np.zeros((R, k, n), np.int8)
    V[: l1.R, :, : l1.n] = l1.V
    V[l1.R :, :, l1.n :] = l2.V
    W = np.zeros((R, m, n), np.int8)
    W[: l1.R, :, : l1.n] = l1.W
    W[l1.R :, :, l1.n :] = l2.W
    return LCMA(name or f"[{l1.name}|{l2.name}]n", m, k, n, R, U, V, W)


def concat_m(l1: LCMA, l2: LCMA, name: str | None = None) -> LCMA:
    """Row-stacked C: <m1+m2,k,n>; R1+R2."""
    _require_matching("concat_m", l1, l2, (l1.k, l1.n), (l2.k, l2.n), "(k, n)")
    k, n = l1.k, l1.n
    m = l1.m + l2.m
    R = l1.R + l2.R
    U = np.zeros((R, m, k), np.int8)
    U[: l1.R, : l1.m, :] = l1.U
    U[l1.R :, l1.m :, :] = l2.U
    V = np.concatenate([l1.V, l2.V], axis=0)
    W = np.zeros((R, m, n), np.int8)
    W[: l1.R, : l1.m, :] = l1.W
    W[l1.R :, l1.m :, :] = l2.W
    return LCMA(name or f"[{l1.name};{l2.name}]m", m, k, n, R, U, V, W)


def concat_k(l1: LCMA, l2: LCMA, name: str | None = None) -> LCMA:
    """C = A1 B1 + A2 B2 (K split): <m,k1+k2,n>; R1+R2."""
    _require_matching("concat_k", l1, l2, (l1.m, l1.n), (l2.m, l2.n), "(m, n)")
    m, n = l1.m, l1.n
    k = l1.k + l2.k
    R = l1.R + l2.R
    U = np.zeros((R, m, k), np.int8)
    U[: l1.R, :, : l1.k] = l1.U
    U[l1.R :, :, l1.k :] = l2.U
    V = np.zeros((R, k, n), np.int8)
    V[: l1.R, : l1.k, :] = l1.V
    V[l1.R :, l1.k :, :] = l2.V
    W = np.concatenate([l1.W, l2.W], axis=0)
    return LCMA(name or f"[{l1.name}+{l2.name}]k", m, k, n, R, U, V, W)


def transpose_dual(l: LCMA, name: str | None = None) -> LCMA:
    """From C = A B derive the <n,k,m> scheme via C^T = B^T A^T."""
    U = np.ascontiguousarray(np.transpose(l.V, (0, 2, 1)))
    V = np.ascontiguousarray(np.transpose(l.U, (0, 2, 1)))
    W = np.ascontiguousarray(np.transpose(l.W, (0, 2, 1)))
    out = LCMA(name or f"{l.name}^T", l.n, l.k, l.m, l.R, U, V, W)
    if not validate(out):
        raise ValueError(f"transpose_dual({l.name}) failed validation")
    return out


def cyclic(l: LCMA, name: str | None = None) -> LCMA:
    """Cyclic symmetry of the matmul tensor: <m,k,n>;R -> <k,n,m>;R.

    The correct index/transpose convention is found automatically by trying
    the small set of candidate permutations and validating (validation for
    grids <= 6 is microseconds, so this is both robust and cheap).
    """
    cands = []
    for (X, Y, Z) in itertools.permutations([l.U, l.V, l.W]):
        for tx in (False, True):
            for ty in (False, True):
                for tz in (False, True):
                    cands.append((X, Y, Z, tx, ty, tz))
    for X, Y, Z, tx, ty, tz in cands:
        U = np.transpose(X, (0, 2, 1)) if tx else X
        V = np.transpose(Y, (0, 2, 1)) if ty else Y
        W = np.transpose(Z, (0, 2, 1)) if tz else Z
        m2, k2 = U.shape[1], U.shape[2]
        if V.shape[1] != k2 or W.shape[1] != m2 or V.shape[2] != W.shape[2]:
            continue
        n2 = V.shape[2]
        if (m2, k2, n2) == (l.m, l.k, l.n) and not (tx or ty or tz):
            continue  # identity
        if (m2, k2, n2) != (l.k, l.n, l.m):
            continue
        cand = LCMA(name or f"cyc({l.name})", m2, k2, n2, l.R,
                    np.ascontiguousarray(U), np.ascontiguousarray(V),
                    np.ascontiguousarray(W))
        if validate(cand):
            return cand
    raise ValueError(f"no cyclic rotation of {l.name} found")


# --------------------------------------------------------------------------
# Library / registry
# --------------------------------------------------------------------------

@lru_cache(maxsize=1)
def library() -> dict[str, LCMA]:
    """All validated schemes, keyed by name. Hard-fails on invalid schemes."""
    out: dict[str, LCMA] = {}

    def add(l: LCMA, check: bool = True):
        if check and not validate(l):
            raise AssertionError(f"LCMA {l.name} {l.key} failed the tensor identity")
        out[l.name] = l
        return l

    s = add(strassen())
    sw = add(strassen_winograd())
    lad = add(laderman())

    # Rectangular borders via block concatenation (rank-optimal where known).
    s223 = add(concat_n(s, standard(2, 2, 1), "s223"))        # <2,2,3>;11 (Hopcroft-Kerr rank)
    add(cyclic(s223, "s232"))                                  # <2,3,2>;11
    add(cyclic(cyclic(s223), "s322"))                          # <3,2,2>;11
    s224 = add(tensor_product(s, standard(1, 1, 2), "s224"))   # <2,2,4>;14
    add(tensor_product(s, standard(1, 2, 1), "s242"))          # <2,4,2>;14
    add(tensor_product(s, standard(2, 1, 1), "s422"))          # <4,2,2>;14
    add(concat_n(s224, standard(2, 2, 1), "s225"))             # <2,2,5>;18
    add(concat_k(s223, standard(2, 1, 3), "s233"))             # <2,3,3>;17
    add(tensor_product(s, standard(1, 2, 2), "s244"))          # <2,4,4>;28
    add(tensor_product(s, standard(2, 2, 1), "s442"))          # <4,4,2>;28
    add(tensor_product(s, standard(2, 1, 2), "s424"))          # <4,2,4>;28

    # Two-level Strassen <4,4,4>;49 (paper §II-A) and Winograd-flavored twin.
    s444 = add(tensor_product(s, s, "s444"))
    add(tensor_product(sw, sw, "sw444"))
    # Laderman-based blowups.
    add(tensor_product(lad, standard(1, 1, 2), "lad336"))      # <3,3,6>;46
    s334 = add(concat_n(lad, standard(3, 3, 1), "lad334"))     # <3,3,4>;32
    add(concat_n(s334, standard(3, 3, 1), "lad335"))           # <3,3,5>;41
    # m,k,n in [2,5] coverage toward <5,5,5>.
    s445 = add(concat_n(s444, standard(4, 4, 1), "s445"))      # <4,4,5>;65
    s455 = add(concat_k(s445, standard(4, 1, 5), "s455"))      # <4,5,5>;85
    add(concat_m(s455, standard(1, 5, 5), "s555"))             # <5,5,5>;110
    add(tensor_product(s, s223, "s446"))                       # <4,4,6>;77
    return out


def get(name: str) -> LCMA:
    return library()[name]


def register(l: LCMA, overwrite: bool = False) -> LCMA:
    """Add a user scheme to the library (resolvable via ``FalconConfig.mode``
    / ``candidates``).

    Registration revalidates the tensor identity even though ``LCMA``'s
    constructor already vetted the coefficient *domain* (integer, int8
    range): an externally sourced listing (AlphaTensor standard-arithmetic,
    Smirnov ⟨3,3,6⟩) with |c| > 1 coefficients must prove it actually
    multiplies matrices before the dispatcher may pick it. The check is the
    exact Brent-equation verifier (``repro.analysis.brent``): a rejection
    names the violated equations, not just "failed".
    """
    from repro.analysis.brent import verify_or_raise
    verify_or_raise(l, context=f"register({l.name!r})")
    lib = library()
    if l.name in lib and not overwrite:
        raise ValueError(f"LCMA {l.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    lib[l.name] = l
    return l


def unregister(name: str) -> None:
    """Remove a user scheme (tests / plugin teardown). Unknown names no-op."""
    library().pop(name, None)


def candidates(max_grid: int = 5, min_saving: float = 0.0) -> list[LCMA]:
    """The Decision Module's candidate set S_LCMA (paper: m,k,n in [2,5])."""
    out = [
        l for l in library().values()
        if max(l.grid) <= max_grid and l.mult_saving > min_saving
    ]
    return sorted(out, key=lambda l: -l.mult_saving)
