"""Workload registry: one config -> contraction set -> warm-plan spine.

Every planned contraction a :class:`~repro.configs.base.ModelConfig` will
issue — dense projections, grouped MoE expert blocks, attention einsums, SSD
chunked-scan and decode contractions — is enumerated **once** here, as
symbolic :class:`ContractionSpec` entries, and resolved to concrete plan
shapes by :func:`resolve_contractions`. This is the ONE source consumed by

  * ``core.engine.warm_buckets`` / ``ServeEngine.warm`` (plan-cache warmup),
  * ``repro.tools.tune`` cache warming (``warm_shapes``),
  * ``falcon-check --workload`` (static lint of an arch's contraction set),
  * the benchmark suite (paper §IV-B projection grids), and
  * the registry-coverage tests (``tests/test_config_matrix.py`` proves a
    fwd+bwd trace creates no plan-cache key outside the registry).

Per-layer heterogeneous stacks (hymba/nemotron-style) are expressed through
``ContractionSpec.layers``: ``()`` means "every layer of this block type";
a tuple of indices pins a spec to specific layers. The hybrid family emits
attention *and* SSD specs — each layer's block types contribute their own
registry entries.

The paper's three LLM serving workloads (§IV-B, DeepSeek-R1 / Qwen3.5 /
HunyuanVideo projections) live here too, as registry entries addressable by
name, so the tune CLI and benchmarks derive identical shape grids from
``contraction_set("deepseek_r1")`` and cannot drift.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "ContractionSpec", "Contraction", "contraction_set",
    "resolve_contractions", "dense_projection_shapes", "grouped_moe_shapes",
    "paper_workloads", "paper_projection_shapes", "warm_shapes",
    "shape_token", "moe_capacity", "WARM_TOKENS", "WARM_SQUARE",
]

# Paper §IV-B LLM projection (K, N) pairs. Data only — addressed through
# contraction_set(<name>) / paper_projection_shapes(<name>).
_PAPER_PROJECTIONS = {
    "deepseek_r1": [(7168, 18432), (18432, 7168), (7168, 2048), (2048, 7168),
                    (7168, 4096), (4096, 7168), (1536, 7168), (7168, 1536),
                    (7168, 9216), (9216, 7168), (7168, 7168)],
    "qwen3_5": [(5120, 25600), (25600, 5120), (5120, 5120), (5120, 640),
                (640, 5120), (5120, 13824), (13824, 5120)],
    "hunyuan_video": [(3072, 12288), (12288, 3072), (3072, 3072),
                      (3072, 9216), (9216, 3072), (3072, 6144)],
}

# Tokens-per-trace (batch x seq) grid and square operator sizes used to
# pre-warm the plan cache for serving.
WARM_TOKENS = [128, 512, 2048, 8192]
WARM_SQUARE = [512, 1024, 2048, 4096, 8192, 16384]

# The flash-attention query chunk (models.layers.flash_attention): no-cache
# attention over sequences longer than this runs in 512-query chunks.
_FLASH_Q_CHUNK = 512
# lm-head cross-entropy chunk cap (models.model._chunked_xent).
_XENT_CHUNK = 512


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    """One planned contraction class, with symbolic M/K/N/group dims.

    Dims are ints (from the config) or symbol strings resolved per call
    context by :func:`resolve_contractions`:

    ``tokens``          batch * (padded) sequence — dense projection rows
    ``logit_tokens``    lm-head rows (xent chunk in training, B at decode)
    ``attn_q``/``attn_kv``/``head_dim``   attention einsum dims
    ``capacity``        per-expert MoE capacity C (moe_capacity)
    ``ssd_chunk``/``ssd_state``/``ssd_head_dim``   SSD scan dims
    ``one``             literal 1 (SSD decode readout rows)

    ``group`` symbols: ``experts`` (E, mesh-scaled), ``attn_groups`` (B*H),
    ``ssd_groups`` (B*n_chunks*H), ``ssd_decode_groups`` (B*H).
    """
    kind: str              # dense | grouped_moe | attention | ssd_scan |
    #                        ssd_decode | cross_attn (vocabulary reserved)
    role: str              # e.g. "attn.w_q", "moe.down", "ssd.scores"
    m: int | str
    k: int | str
    n: int | str
    group: int | str = 1          # 1 => plain 2-D contraction
    shared_b: bool = False
    # B operand is a static model weight => precombinable offline
    # (falcon.precombine_params) and eligible for the int8 quant tier.
    weight_static: bool = True
    # () => every layer with this block type; tuple => specific layer indices
    # (per-layer heterogeneity, hymba/nemotron-style).
    layers: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class Contraction:
    """A concrete resolved contraction — what the plan cache is keyed on."""
    kind: str
    role: str
    m: int
    k: int
    n: int
    group: int = 1
    shared_b: bool = False
    weight_static: bool = True

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.m, self.k, self.n)

    def key_shape(self) -> str:
        """The shape token as it appears inside a plan-cache key."""
        if self.group == 1:
            return f"{self.m}x{self.k}x{self.n}"
        return f"g{self.group}x{self.m}x{self.k}x{self.n}|sb={int(self.shared_b)}"


def moe_capacity(tokens: int, top_k: int, num_experts: int,
                 capacity_factor: float, shard_round: bool = False) -> int:
    """Per-expert token capacity ``C = max(ceil(T·k/E·cf), 8)``.

    THE one definition of MoE capacity — shared by ``models.moe.moe_apply``,
    the layer stack (``models.model``, which passes ``shard_round=True`` to
    round capacities above 256 up to a 256 multiple for shardability), and
    the registry resolver here (warm-bucket pre-planning). The grouped
    plan-cache keys embed C, so these sites must agree bit-for-bit; sharing
    the formula is what enforces it.
    """
    c = max(math.ceil(tokens * top_k / num_experts * capacity_factor), 8)
    if shard_round and c > 256:
        c = -(-c // 256) * 256
    return c


def _resolve_arch(arch):
    """str -> ModelConfig via the configs registry; pass configs through."""
    if isinstance(arch, str):
        from repro.configs import registry
        return registry.get_config(arch)
    return arch


def _mesh_factors(mesh_shape) -> tuple[int, int]:
    """-> (data shards, model shards). Accepts the engine's axis-name dict
    (``{"data": .., "model": .., "pod": ..}``) or a plain (data, model)
    tuple; None => single device."""
    if not mesh_shape:
        return 1, 1
    if isinstance(mesh_shape, dict):
        nd = int(mesh_shape.get("data", 1)) * int(mesh_shape.get("pod", 1) or 1)
        return max(nd, 1), int(mesh_shape.get("model", 1)) or 1
    nd = int(mesh_shape[0]) or 1
    nm = int(mesh_shape[1]) if len(mesh_shape) > 1 else 1
    return max(nd, 1), max(nm, 1)


def paper_workloads() -> list[str]:
    """The paper's §IV-B LLM workload names (addressable by contraction_set)."""
    return list(_PAPER_PROJECTIONS)


def paper_projection_shapes(workload: str) -> list[tuple[int, int]]:
    """(K, N) projection pairs of one paper workload, via the registry."""
    return [(s.k, s.n) for s in contraction_set(workload)]


def _paper_specs(workload: str) -> list[ContractionSpec]:
    return [ContractionSpec("dense", f"{workload}.proj{i}", "tokens", k, n)
            for i, (k, n) in enumerate(_PAPER_PROJECTIONS[workload])]


def _model_specs(cfg) -> list[ContractionSpec]:
    """Forward contraction specs for one ModelConfig (duck-typed).

    Duck-typed on :class:`~repro.configs.base.ModelConfig` fields (getattr
    with defaults) so the core layer stays import-free of the config zoo —
    block presence follows what ``models.model._layer_body`` actually
    dispatches: MoE replaces the dense MLP, the pure-SSM family has neither
    attention nor an MLP.
    """
    d = int(cfg.d_model)
    fam = getattr(cfg, "family", None)
    E = int(getattr(cfg, "num_experts", 0))
    is_moe = bool(E) and fam in (None, "moe")
    specs: list[ContractionSpec] = []

    # --- attention block (every family except pure SSM) ---
    heads = int(getattr(cfg, "num_heads", 0))
    if heads and fam != "ssm":
        hd = int(cfg.resolved_head_dim if hasattr(cfg, "resolved_head_dim")
                 else (getattr(cfg, "head_dim", 0) or d // heads))
        kv = int(getattr(cfg, "num_kv_heads", heads) or heads)
        specs += [
            ContractionSpec("dense", "attn.w_q", "tokens", d, heads * hd),
            ContractionSpec("dense", "attn.w_k", "tokens", d, kv * hd),
            ContractionSpec("dense", "attn.w_v", "tokens", d, kv * hd),
            ContractionSpec("dense", "attn.w_o", "tokens", heads * hd, d),
            # QK^T and AV einsums: grouped over B*H heads (GQA K/V are
            # repeated up to H before the einsum), activation x activation.
            ContractionSpec("attention", "attn.qk", "attn_q", "head_dim",
                            "attn_kv", group="attn_groups",
                            weight_static=False),
            ContractionSpec("attention", "attn.av", "attn_q", "attn_kv",
                            "head_dim", group="attn_groups",
                            weight_static=False),
        ]

    # --- dense MLP (not for moe: experts replace it; never for pure SSM) ---
    ff = int(getattr(cfg, "d_ff", 0))
    if ff and fam != "ssm" and not is_moe:
        if getattr(cfg, "mlp_type", "swiglu") == "swiglu":
            specs.append(ContractionSpec("dense", "mlp.gate", "tokens", d, ff))
        specs += [
            ContractionSpec("dense", "mlp.up", "tokens", d, ff),
            ContractionSpec("dense", "mlp.down", "tokens", ff, d),
        ]

    # --- grouped MoE expert FFN (router is a plain f32 matmul, not planned) ---
    if is_moe:
        specs += [
            ContractionSpec("grouped_moe", "moe.gate", "capacity", d, ff,
                            group="experts"),
            ContractionSpec("grouped_moe", "moe.up", "capacity", d, ff,
                            group="experts"),
            ContractionSpec("grouped_moe", "moe.down", "capacity", ff, d,
                            group="experts"),
        ]

    # --- SSD (mamba2-style state-space duality) block ---
    sh = int(getattr(cfg, "ssm_heads", 0))
    if sh and fam in (None, "ssm", "hybrid"):
        P = getattr(cfg, "ssm_head_dim", 64)
        G = getattr(cfg, "ssm_groups", 1)
        Ns = getattr(cfg, "ssm_state", 0)
        d_inner = sh * P
        d_in_proj = 2 * d_inner + 2 * G * Ns + sh
        specs += [
            ContractionSpec("dense", "ssm.in_proj", "tokens", d, d_in_proj),
            ContractionSpec("dense", "ssm.out_proj", "tokens", d_inner, d),
            # chunked-scan contractions (models.ssd.ssd_scan), grouped over
            # B * n_chunks * H; decay factors are folded into the operands
            # elementwise so each einsum is one 2-operand grouped GEMM.
            ContractionSpec("ssd_scan", "ssd.scores", "ssd_chunk",
                            "ssd_state", "ssd_chunk", group="ssd_groups",
                            weight_static=False),
            ContractionSpec("ssd_scan", "ssd.y_diag", "ssd_chunk",
                            "ssd_chunk", "ssd_head_dim", group="ssd_groups",
                            weight_static=False),
            ContractionSpec("ssd_scan", "ssd.states", "ssd_state",
                            "ssd_chunk", "ssd_head_dim", group="ssd_groups",
                            weight_static=False),
            ContractionSpec("ssd_scan", "ssd.y_off", "ssd_chunk",
                            "ssd_state", "ssd_head_dim", group="ssd_groups",
                            weight_static=False),
            # single-token recurrence (models.ssd.ssd_decode_step)
            ContractionSpec("ssd_decode", "ssd.state_update", "ssd_state",
                            "one", "ssd_head_dim", group="ssd_decode_groups",
                            weight_static=False),
            ContractionSpec("ssd_decode", "ssd.readout", "one", "ssd_state",
                            "ssd_head_dim", group="ssd_decode_groups",
                            weight_static=False),
        ]

    # --- lm head (audio runs one per codebook; same (d, Vp) shape) ---
    V = int(getattr(cfg, "vocab_size", 0))
    if V:
        vp = -(-V // 256) * 256   # padded vocab (models.padded_vocab)
        specs.append(ContractionSpec("dense", "lm_head", "logit_tokens", d, vp))
    return specs


def contraction_set(arch, *, train: bool = False, mesh_shape=None,
                    quantize: bool = False) -> list[ContractionSpec]:
    """Every planned contraction ``arch`` will issue, as symbolic specs.

    ``arch`` is a :class:`ModelConfig`, a registry arch id
    (``"mamba2_370m"``), or a paper workload name (``"deepseek_r1"``).

    ``train=True`` appends the two backward specs per forward contraction
    (``role.dA``/``role.dB`` — the planned custom-VJP grad GEMMs).
    ``mesh_shape=(data, model)`` scales the ``experts`` group to the
    per-shard expert count. ``quantize=True`` restricts the set to the
    contractions the int8 tier can serve (static-weight B operands).
    """
    if isinstance(arch, str) and arch in _PAPER_PROJECTIONS:
        specs = _paper_specs(arch)
    else:
        specs = _model_specs(_resolve_arch(arch))

    if mesh_shape:
        _, nm = _mesh_factors(mesh_shape)
        def _scale(s):
            if s.group == "experts":
                E = _resolve_arch(arch).num_experts
                return dataclasses.replace(
                    s, group=E // nm if nm > 1 and E % nm == 0 else E)
            return s
        specs = [_scale(s) for s in specs]

    if train:
        specs = specs + [b for s in specs for b in _backward_specs(s)]
    if quantize:
        specs = [s for s in specs
                 if s.weight_static and s.kind in ("dense", "grouped_moe")]
    return specs


def _backward_specs(s: ContractionSpec) -> list[ContractionSpec]:
    """Symbolic backward contractions of one forward spec.

    Dense ``(M,K,N)`` -> dA ``(M,N,K)``, dB ``(K,M,N)``
    (``core.decision.backward_shapes``); grouped keeps the group:
    dA ``(G,M,N,K)``, dB ``(G,K,M,N)`` — matching the planned custom-VJP
    grad rules in ``core.engine``. Shared-B grouped dB collapses to a dense
    ``(K, G*M, N)``; no current model spec is shared-B, so that case is
    resolved concretely in :func:`resolve_contractions` only.
    """
    return [
        dataclasses.replace(s, role=s.role + ".dA", m=s.m, k=s.n, n=s.k,
                            weight_static=False),
        dataclasses.replace(s, role=s.role + ".dB", m=s.k, k=s.m, n=s.n,
                            weight_static=False),
    ]


def _shape_env(cfg, batch: int, seq: int, *, kv_len=None, decode=False,
               mesh_shape=None, spec_verify=False) -> dict:
    """Symbol values for one (batch, seq) call context.

    ``kv_len`` set => serving against a KV/state cache of that length
    (attention keys span the cache, lm head sees one row per sequence);
    ``decode=True`` => single-token step (seq is the number of new tokens,
    normally 1). ``spec_verify=True`` => a speculative verify step: seq is
    γ+1 draft-scoring rows and the lm head runs on every one of them
    (``logit_tokens = batch * seq`` instead of ``batch``) — the only symbol
    speculation changes, since draft/verify projections otherwise share the
    multi-token continuation shapes.
    """
    patches = (cfg.num_patches
               if getattr(cfg, "frontend", "") == "vision_patches" else 0)
    S = seq + patches
    nd, _ = _mesh_factors(mesh_shape)
    tokens = batch * S
    heads = getattr(cfg, "num_heads", 0)
    env: dict = {"one": 1, "tokens": tokens, "batch": batch, "seq": S}

    if decode:
        env["attn_q"], env["attn_kv"] = 1, (kv_len or S)
    elif kv_len is not None:
        env["attn_q"], env["attn_kv"] = S, kv_len
    else:
        flash = S > _FLASH_Q_CHUNK and S % _FLASH_Q_CHUNK == 0
        env["attn_q"] = _FLASH_Q_CHUNK if flash else S
        env["attn_kv"] = S
    env["attn_groups"] = batch * heads
    env["head_dim"] = (cfg.resolved_head_dim
                       if hasattr(cfg, "resolved_head_dim") else
                       getattr(cfg, "head_dim", 0))

    if kv_len is not None or decode:
        env["logit_tokens"] = batch * S if spec_verify else batch
    else:
        cx = min(_XENT_CHUNK, seq)
        while cx > 1 and seq % cx:
            cx -= 1
        env["logit_tokens"] = batch * cx

    E = int(getattr(cfg, "num_experts", 0))
    if E:
        m_tokens = max(-(-tokens // nd), 1)
        env["capacity"] = moe_capacity(
            m_tokens, int(getattr(cfg, "experts_per_token", 0)) or 1, E,
            float(getattr(cfg, "capacity_factor", 1.25)), shard_round=True)
        env["experts"] = E

    sh = getattr(cfg, "ssm_heads", 0)
    if sh:
        chunk = getattr(cfg, "ssm_chunk", 256)
        n_chunks = max(1, -(-S // chunk))
        env.update(ssd_chunk=chunk, ssd_state=getattr(cfg, "ssm_state", 0),
                   ssd_head_dim=getattr(cfg, "ssm_head_dim", 64),
                   ssd_groups=batch * n_chunks * sh,
                   ssd_decode_groups=batch * sh)
    return env


def resolve_contractions(arch, batch: int, seq: int, *, train: bool = False,
                         mesh_shape=None, kv_len=None, decode: bool = False,
                         spec_verify: bool = False) -> list[Contraction]:
    """Concrete contraction inventory for one (batch, seq) call context.

    Returns deduplicated :class:`Contraction` entries whose ``key_shape()``
    tokens are exactly what ``core.plan_cache`` keys embed. ``train=True``
    includes both backward contractions per forward one (shared-B grouped
    dB resolves to its dense ``(K, G*M, N)`` form). ``decode=True`` keeps
    only the single-token inventory (SSD recurrence instead of the scan);
    prefill/train keeps the scan and drops the decode recurrence.
    ``spec_verify=True`` resolves a speculative verify context (seq = γ+1,
    lm head on every row — see :func:`_shape_env`).
    """
    cfg = _resolve_arch(arch) if not (
        isinstance(arch, str) and arch in _PAPER_PROJECTIONS) else arch
    specs = contraction_set(arch, mesh_shape=mesh_shape)
    env = _shape_env(cfg, batch, seq, kv_len=kv_len, decode=decode,
                     mesh_shape=mesh_shape, spec_verify=spec_verify
                     ) if not isinstance(cfg, str) else {
        "tokens": batch * seq}

    def val(x):
        return env[x] if isinstance(x, str) else x

    out: list[Contraction] = []
    seen: set = set()

    def emit(c: Contraction):
        key = (c.key_shape(), c.kind)
        if key not in seen:
            seen.add(key)
            out.append(c)

    for s in specs:
        if decode and s.kind == "ssd_scan":
            continue
        if not decode and s.kind == "ssd_decode":
            continue
        g = val(s.group)
        c = Contraction(s.kind, s.role, val(s.m), val(s.k), val(s.n),
                        group=g, shared_b=s.shared_b,
                        weight_static=s.weight_static)
        emit(c)
        if train:
            emit(Contraction(c.kind, c.role + ".dA", c.m, c.n, c.k,
                             group=c.group, shared_b=c.shared_b,
                             weight_static=False))
            if c.group != 1 and c.shared_b:
                emit(Contraction("dense", c.role + ".dB", c.k,
                                 c.group * c.m, c.n, weight_static=False))
            else:
                emit(Contraction(c.kind, c.role + ".dB", c.k, c.m, c.n,
                                 group=c.group, shared_b=c.shared_b,
                                 weight_static=False))
    return out


def dense_projection_shapes(arch) -> list[tuple[int, int]]:
    """Deduplicated dense-projection ``(K, N)`` pairs of one arch.

    The registry-backed successor of ``core.engine.projection_shapes``:
    per-token 2-D weight contractions only (attention/ssd/lm-head
    projections), excluding the grouped/einsum kinds.
    """
    out: list[tuple[int, int]] = []
    for s in contraction_set(arch):
        if s.kind != "dense":
            continue
        kn = (s.k, s.n)
        if kn not in out:
            out.append(kn)
    return out


def grouped_moe_shapes(arch, m_tokens: int,
                       mesh_shape=None) -> list[tuple[int, int, int, int]]:
    """Grouped MoE expert shapes ``(E, C, K, N)`` at ``m_tokens`` rows.

    Registry-backed successor of ``core.engine.grouped_expert_shapes``;
    deduplicated, mesh-scaled like the serve path (tokens over data shards,
    experts over model shards).
    """
    cfg = _resolve_arch(arch)
    E = int(getattr(cfg, "num_experts", 0))
    if not E:
        return []
    specs = [s for s in contraction_set(cfg, mesh_shape=mesh_shape)
             if s.kind == "grouped_moe"]
    nd, nm = _mesh_factors(mesh_shape)
    if nm > 1 and E % nm == 0:
        E //= nm
    mt = max(-(-m_tokens // nd), 1)
    top_k = int(getattr(cfg, "experts_per_token", 0)) or 1
    # shard_round=True: the model layer stack serves with the 256-rounded
    # shardable capacity, and the grouped plan-cache keys embed C
    C = moe_capacity(mt, top_k, E, float(getattr(cfg, "capacity_factor", 1.25)),
                     shard_round=True)
    out: list[tuple[int, int, int, int]] = []
    for s in specs:
        g = s.group if isinstance(s.group, int) else E
        shape = (g, C, s.k, s.n)
        if shape not in out:
            out.append(shape)
    return out


def warm_shapes(workload: str = "deepseek_r1") -> list[tuple[int, int, int]]:
    """(M, K, N) grid the tune CLI warms the plan cache with.

    ``workload`` is any name ``contraction_set`` accepts — a paper workload
    or a registry arch id; the dense projection pairs come from the
    registry, swept over the WARM_TOKENS grid plus square operator sizes.
    """
    pairs = [(s.k, s.n) for s in contraction_set(workload) if s.kind == "dense"
             and isinstance(s.k, int) and isinstance(s.n, int)]
    out = [(m, k, n) for m in WARM_TOKENS for k, n in pairs]
    out += [(s, s, s) for s in WARM_SQUARE]
    return out


def shape_token(plan_key: str) -> str:
    """Extract the shape token (``MxKxN`` / ``gGxMxKxN|sb=b``) of a plan key.

    Mirrors ``core.plan_cache.plan_key``'s layout: part 2 is the shape;
    grouped shapes carry their ``sb=`` flag as the following part.
    """
    parts = plan_key.split("|")
    tok = parts[2]
    if tok.startswith("g") and len(parts) > 3 and parts[3].startswith("sb="):
        tok += "|" + parts[3]
    return tok


# Back-compat alias for the paper projection tables; prefer
# ``paper_projection_shapes(name)`` / ``contraction_set(name)``.
LLM_SHAPES = _PAPER_PROJECTIONS
