"""Serving workload shape tables shared by benchmarks and the tune CLI.

Linear-layer (N, K) projection shapes extracted from the paper's three LLM
workloads (§IV-B): DeepSeek-R1-, Qwen3.5- and HunyuanVideo-style projections.
Kept under ``src/`` (not ``benchmarks/``) so installed entry points —
``repro.tools.tune`` cache warming — and the benchmark suite price the same
shapes and cannot drift apart.
"""
from __future__ import annotations

LLM_SHAPES = {
    "deepseek_r1": [(7168, 18432), (18432, 7168), (7168, 2048), (2048, 7168),
                    (7168, 4096), (4096, 7168), (1536, 7168), (7168, 1536),
                    (7168, 9216), (9216, 7168), (7168, 7168)],
    "qwen3_5": [(5120, 25600), (25600, 5120), (5120, 5120), (5120, 640),
                (640, 5120), (5120, 13824), (13824, 5120)],
    "hunyuan_video": [(3072, 12288), (12288, 3072), (3072, 3072),
                      (3072, 9216), (9216, 3072), (3072, 6144)],
}

# Tokens-per-trace (batch x seq) grid and square operator sizes used to
# pre-warm the plan cache for serving.
WARM_TOKENS = [128, 512, 2048, 8192]
WARM_SQUARE = [512, 1024, 2048, 4096, 8192, 16384]


def warm_shapes(workload: str = "deepseek_r1") -> list[tuple[int, int, int]]:
    """(M, K, N) grid the tune CLI warms the plan cache with."""
    out = [(m, k, n) for m in WARM_TOKENS for k, n in LLM_SHAPES[workload]]
    out += [(s, s, s) for s in WARM_SQUARE]
    return out


def moe_capacity(tokens: int, top_k: int, num_experts: int,
                 capacity_factor: float, shard_round: bool = False) -> int:
    """Per-expert token capacity ``C = max(ceil(T·k/E·cf), 8)``.

    THE one definition of MoE capacity — shared by ``models.moe.moe_apply``,
    the layer stack (``models.model``, which passes ``shard_round=True`` to
    round capacities above 256 up to a 256 multiple for shardability), and
    ``core.engine.grouped_expert_shapes`` (warm-bucket pre-planning). The
    grouped plan-cache keys embed C, so these sites must agree bit-for-bit;
    sharing the formula is what enforces it.
    """
    import math
    c = max(math.ceil(tokens * top_k / num_experts * capacity_factor), 8)
    if shard_round and c > 256:
        c = -(-c // 256) * 256
    return c
