"""FalconGEMM public API: decision-dispatched LCMA matmul + model integration.

``falcon_matmul(a, b, cfg)`` is the drop-in ``a @ b`` replacement used by the
model zoo's linear layers (the paper's PyTorch-backend integration, §IV-C):

  1. the Decision Module predicts, from the *static trace-time shapes* (scaled
     to per-device shapes by ``cfg.shards`` under pjit), whether an LCMA beats
     standard GEMM on the target hardware,
  2. if yes, the Deployment Module's generated fused implementation is traced
     (pure JAX ops -> GSPMD-shardable; or the Pallas kernel pipeline on TPU),
  3. otherwise it falls back to ``jnp.dot`` — "keep the best performance".

Static weights can be pre-combined offline (``precombine_weights``), removing
the Combine-B stage from serving entirely (paper §IV-C "offline Combine B").
"""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from . import algorithms, codegen, decision as dec, plan_cache
from .hardware import HardwareProfile, get_profile
from .lcma import LCMA

log = logging.getLogger(__name__)

__all__ = ["FalconConfig", "falcon_matmul", "falcon_dense", "plan",
           "precombine_weights", "matmul_with_precombined"]


@dataclasses.dataclass(frozen=True)
class FalconConfig:
    """Trace-time policy for FalconGEMM dispatch."""

    enabled: bool = True
    hardware: str = "tpu_v5e"
    backend: str = "jnp"             # "jnp" | "pallas" | "pallas_interpret"
    fused: bool = True
    mode: str = "auto"               # "auto" | "gemm" | explicit scheme name
    candidates: tuple[str, ...] | None = None
    min_speedup: float = 1.02        # require a predicted >=2% win before switching
    max_grid: int = 5
    # Per-device scaling of (M, K, N) under pjit: number of shards per dim.
    shards: tuple[int, int, int] = (1, 1, 1)
    # Memoize auto-mode Decisions in the process plan cache (serving hot path
    # re-traces the same shapes; see core/plan_cache.py).
    use_plan_cache: bool = True

    @property
    def profile(self) -> HardwareProfile:
        return get_profile(self.hardware)

    def candidate_schemes(self) -> list[LCMA]:
        if self.candidates is not None:
            return [algorithms.get(n) for n in self.candidates]
        return algorithms.candidates(max_grid=self.max_grid)


def plan(M: int, K: int, N: int, cfg: FalconConfig, dtype: str = "bfloat16",
         precombined_b: bool = False) -> dec.Decision:
    """Run the Decision Module for a (possibly sharded) matmul shape.

    Auto-mode decisions are memoized in the process plan cache (keyed on the
    local shape, dtype, hardware fingerprint and dispatch policy), so repeated
    trace-time shapes — the serving hot path — skip candidate enumeration.
    """
    sm, sk, sn = cfg.shards
    Ml, Kl, Nl = max(M // sm, 1), max(K // sk, 1), max(N // sn, 1)
    if cfg.mode == "gemm" or not cfg.enabled:
        t = dec.gemm_time(Ml, Nl, Kl, cfg.profile, dtype)
        return dec.Decision(Ml, Nl, Kl, dtype, None, t, None, ())
    if cfg.mode != "auto":
        l = algorithms.get(cfg.mode)
        est = dec.estimate(l, Ml, Nl, Kl, cfg.profile, dtype, fused=cfg.fused,
                           precombined_b=precombined_b)
        return dec.Decision(Ml, Nl, Kl, dtype, l,
                            dec.gemm_time(Ml, Nl, Kl, cfg.profile, dtype),
                            est.time, (est,))
    cache = key = None
    if cfg.use_plan_cache:
        cache = plan_cache.default_cache()
        key = plan_cache.plan_key(
            Ml, Kl, Nl, cfg.profile, dtype, fused=cfg.fused,
            precombined_b=precombined_b, mode=cfg.mode,
            candidates=cfg.candidates, max_grid=cfg.max_grid,
            min_speedup=cfg.min_speedup)
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    d = dec.decide(Ml, Nl, Kl, cfg.profile, dtype,
                   candidates=cfg.candidate_schemes(), fused=cfg.fused,
                   precombined_b=precombined_b, min_speedup=cfg.min_speedup)
    if cache is not None:
        cache.insert(key, d)
    return d


def _pad2(x: jnp.ndarray, d0: int, d1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % d0
    p1 = (-x.shape[1]) % d1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _lcma_apply(a2: jnp.ndarray, b: jnp.ndarray, l: LCMA, cfg: FalconConfig) -> jnp.ndarray:
    M, K = a2.shape
    _, N = b.shape
    if cfg.backend in ("pallas", "pallas_interpret"):
        from repro.kernels import ops
        return ops.falcon_matmul_pallas(
            a2, b, l, interpret=(cfg.backend == "pallas_interpret"))
    gen = codegen.generate(l, codegen.CodegenOptions(fused=cfg.fused))
    ap = _pad2(a2, l.m, l.k)
    bp = _pad2(b, l.k, l.n)
    c = gen.fn(ap, bp)
    return c[:M, :N]


def falcon_matmul(a: jnp.ndarray, b: jnp.ndarray, cfg: FalconConfig | None = None,
                  dtype_hint: str | None = None) -> jnp.ndarray:
    """``a @ b`` with FalconGEMM dispatch. ``a``: (..., M, K), ``b``: (K, N)."""
    cfg = cfg or FalconConfig()
    *lead, M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    Mflat = int(np.prod(lead)) * M if lead else M
    dtype = dtype_hint or str(a.dtype)
    d = plan(Mflat, K, N, cfg, dtype)
    if not d.use_lcma:
        return jnp.matmul(a, b)
    a2 = a.reshape(Mflat, K) if lead else a
    c = _lcma_apply(a2, b, d.algo, cfg)
    return c.reshape(*lead, M, N) if lead else c


def falcon_dense(x: jnp.ndarray, w: jnp.ndarray, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Linear layer contraction: x (..., K) @ w (K, N)."""
    cfg = cfg or FalconConfig()
    if cfg.backend == "shard_map_local":
        out = _falcon_dense_shardmap(x, w, cfg)
        if out is not None:
            return out
    *lead, K = x.shape
    return falcon_matmul(x.reshape(-1, K), w, cfg).reshape(*lead, w.shape[1])


def _falcon_dense_shardmap(x: jnp.ndarray, w: jnp.ndarray,
                           cfg: FalconConfig) -> jnp.ndarray | None:
    """Apply LCMA to the per-device LOCAL matmul inside ``jax.shard_map``.

    Lesson from EXPERIMENTS.md §Perf A1: LCMA submatrix slicing on a
    GSPMD-sharded global matmul makes the partitioner reshard every slice
    (7x collective blow-up). The correct placement is the device-local GEMM:
    here tokens are sharded over the batch axes, the weight is gathered to a
    local replica (the same all-gather ZeRO does for the plain matmul), and
    the Decision Module prices the *local* shapes it actually sees.

    Only supported under ``parallel_style="fsdp_only"`` (no TP: the local
    contraction is the full K x N). Returns None to fall back otherwise.
    """
    from repro.parallel.sharding import get_parallel_style, resolve_batch_axes
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    if (mesh is None or not mesh.axis_names
            or get_parallel_style() != "fsdp_only"):
        return None
    sizes = dict(mesh.shape)
    axes = tuple(a for a in resolve_batch_axes() if a in set(mesh.axis_names))
    nb = int(np.prod([sizes[a] for a in axes])) if axes else 1
    *lead, K = x.shape
    T = int(np.prod(lead))
    if nb <= 1 or T % nb != 0:
        return None
    N = w.shape[1]
    Tl = T // nb
    d = plan(Tl, K, N, dataclasses.replace(cfg, shards=(1, 1, 1)),
             str(x.dtype))

    def body(xl, wl):
        if d.use_lcma:
            c = _lcma_apply(xl, wl, d.algo, dataclasses.replace(cfg, backend="jnp"))
        else:
            c = jnp.matmul(xl, wl)
        return c

    # flatten tokens so the (possibly small) batch dim times seq shards over
    # the full mesh: (B, S, K) -> (B*S, K) with B*S % n_devices == 0
    xspec = P(axes, None)
    out = jax.shard_map(
        body, in_specs=(xspec, P(None, None)),
        out_specs=xspec, check_vma=False)(x.reshape(T, K), w)
    return out.reshape(*lead, N)


# ---------------------------------------------------------------------------
# Offline Combine B (static weights, serving path)
# ---------------------------------------------------------------------------

def precombine_weights(w: jnp.ndarray, l: LCMA) -> jnp.ndarray:
    """Offline Combine B of a static weight matrix: (K, N) -> (R, K/k, N/n)."""
    gen = codegen.generate(l, codegen.CodegenOptions(precombined_b=True))
    return gen.combine_b(_pad2(w, l.k, l.n))


def matmul_with_precombined(a: jnp.ndarray, bt: jnp.ndarray, l: LCMA,
                            n_logical: int, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Serving-path matmul against pre-combined weights B̃ (R, K/k, N/n)."""
    cfg = cfg or FalconConfig()
    gen = codegen.generate(l, codegen.CodegenOptions(
        fused=cfg.fused, precombined_b=True))
    *lead, M, K = a.shape
    a2 = a.reshape(-1, K)
    ap = _pad2(a2, l.m, l.k)
    assert ap.shape[1] // l.k == bt.shape[1], (ap.shape, bt.shape, l.key)
    c = gen.fn(ap, bt)[: a2.shape[0], :n_logical]
    return c.reshape(*lead, M, n_logical) if lead else c
