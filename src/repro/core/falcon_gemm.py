"""FalconGEMM dispatch core: decision-dispatched LCMA matmul + planning.

``falcon_matmul(a, b)`` is the drop-in ``a @ b`` replacement used by the
model zoo's linear layers (the paper's PyTorch-backend integration, §IV-C):

  1. the Decision Module predicts, from the *static trace-time shapes* (scaled
     to per-device shapes by ``cfg.shards`` under pjit), whether an LCMA beats
     standard GEMM on the target hardware,
  2. if yes, the chosen execution **backend** (``core.backends`` registry:
     generated pure-JAX combines, the Pallas kernel pipeline, the shard_map
     local-matmul placement, or anything user-registered) runs the scheme,
  3. otherwise it falls back to ``jnp.dot`` — "keep the best performance".

Configuration is context-scoped (``repro.api.use`` / ``FalconEngine``); the
explicit ``cfg`` argument survives as a compatibility override. Static weights
can be pre-combined offline (``precombine_weights`` / ``PlannedWeight``),
removing the Combine-B stage from serving entirely (paper §IV-C "offline
Combine B").
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

from . import algorithms, backends, codegen, decision as dec, plan_cache
from .hardware import HardwareProfile, get_profile
from .lcma import LCMA

log = logging.getLogger(__name__)

__all__ = ["FalconConfig", "falcon_matmul", "falcon_dense", "plan",
           "plan_batched", "plan_sharded", "plan_training",
           "precombine_weights", "matmul_with_precombined",
           "grouped_matmul_generated", "grouped_matmul_with_precombined"]


@dataclasses.dataclass(frozen=True)
class FalconConfig:
    """Trace-time policy for FalconGEMM dispatch."""

    enabled: bool = True
    hardware: str = "tpu_v5e"
    backend: str = "jnp"             # any name in core.backends registry
    fused: bool = True
    mode: str = "auto"               # "auto" | "gemm" | explicit scheme name
    candidates: tuple[str, ...] | None = None
    min_speedup: float = 1.02        # require a predicted >=2% win before switching
    max_grid: int = 5
    # Static numerical-accuracy ceiling for this call site: candidates whose
    # Higham-style relative-error bound (``LCMA.stability.error_bound``)
    # exceeds the budget are rejected BEFORE pricing (falcon-check's
    # ``stability`` pass, read by the Decision Module). None disables.
    accuracy_budget: float | None = None
    # Put the int8-quantized tier into the Decision Module's search: every
    # budget-eligible candidate is additionally priced quantized
    # (``decision.estimate_quant``) and the winner's tier lands in
    # ``Decision.precision``. Selection stays gated by ``accuracy_budget``
    # (int8 eps = 1/(2*127) in the stability model).
    quantize: bool = False
    # Per-device scaling of (M, K, N) under pjit: number of shards per dim.
    shards: tuple[int, int, int] = (1, 1, 1)
    # Memoize auto-mode Decisions in the process plan cache (serving hot path
    # re-traces the same shapes; see core/plan_cache.py).
    use_plan_cache: bool = True
    # Route autodiff through the planned custom-VJP: the backward GEMMs
    # (dA = g Bᵀ, dB = Aᵀ g) become independently planned falcon contractions
    # instead of the autodiff transpose of the combine graph. False restores
    # differentiate-through semantics (and forward-mode jvp support).
    planned_vjp: bool = True

    @property
    def profile(self) -> HardwareProfile:
        return get_profile(self.hardware)

    def candidate_schemes(self) -> list[LCMA]:
        if self.candidates is not None:
            return [algorithms.get(n) for n in self.candidates]
        return algorithms.candidates(max_grid=self.max_grid)


# Once-per-key warning dedup for non-divisible shard shapes. Bounded: a
# long-running serve/replan process sees an unbounded stream of distinct
# (shape, shards) keys, and an ever-growing set is a slow leak — oldest keys
# are dropped (worst case: a very old shape warns again). Locked: plan() is
# reached from multiple serve threads, and an unguarded check-then-mutate on
# the OrderedDict can race into a KeyError.
_WARNED_SHARDS_MAX = 512
_warned_shards: "collections.OrderedDict[tuple, None]" = collections.OrderedDict()
_warned_shards_lock = threading.Lock()


def _warn_once_key(key: tuple) -> bool:
    """True if ``key`` has not warned yet; records it in the bounded LRU."""
    with _warned_shards_lock:
        if key in _warned_shards:
            _warned_shards.move_to_end(key)
            return False
        _warned_shards[key] = None
        if len(_warned_shards) > _WARNED_SHARDS_MAX:
            _warned_shards.popitem(last=False)
        return True


def _local_shape(M: int, K: int, N: int, cfg: FalconConfig) -> tuple[int, int, int]:
    """Scale a global shape to the per-device shape by ``cfg.shards``.

    Non-divisible shards round UP (ceil division): the per-device problem the
    partitioner actually materializes is the padded shard, and silently
    truncating (the old ``max(M // sm, 1)``) made the Decision Module price a
    smaller matmul than any device runs. Warns once per (shape, shards).
    """
    sm, sk, sn = cfg.shards
    if min(sm, sk, sn) < 1:
        raise ValueError(f"FalconConfig.shards must be >= 1, got {cfg.shards}")
    if M % sm or K % sk or N % sn:
        key = (M, K, N, cfg.shards)
        if _warn_once_key(key):
            log.warning(
                "FalconGEMM: shards %s do not divide (M=%d, K=%d, N=%d); "
                "pricing the rounded-up per-device shard (%d, %d, %d)",
                cfg.shards, M, K, N, -(-M // sm), -(-K // sk), -(-N // sn))
    return max(-(-M // sm), 1), max(-(-K // sk), 1), max(-(-N // sn), 1)


def plan(M: int, K: int, N: int, cfg: FalconConfig, dtype: str = "bfloat16",
         precombined_b: bool = False, *, mesh=None,
         layouts: tuple[dec.ShardLayout, ...] | None = None,
         n_devices: int | None = None) -> dec.Decision:
    """Run the Decision Module for a (possibly sharded) matmul shape.

    Auto-mode decisions are memoized in the process plan cache (keyed on the
    local shape, dtype, hardware fingerprint and dispatch policy), so repeated
    trace-time shapes — the serving hot path — skip candidate enumeration.

    Passing a mesh context (``mesh=`` — a ``jax.sharding.Mesh``/abstract mesh
    — or explicit ``layouts``/``n_devices``) promotes the plan to the
    shard-aware tier: ``(M, K, N)`` is then the GLOBAL shape, candidate
    layouts come from ``parallel.sharding.layouts_for_mesh`` and the returned
    :class:`~repro.core.decision.ShardedDecision` prices local contraction
    plus collectives (see :func:`plan_sharded`).
    """
    if mesh is not None or layouts is not None or (n_devices or 0) > 1:
        return plan_sharded(M, K, N, cfg, dtype, precombined_b,
                            mesh=mesh, layouts=layouts, n_devices=n_devices)
    Ml, Kl, Nl = _local_shape(M, K, N, cfg)
    if cfg.mode == "gemm" or not cfg.enabled:
        t = dec.gemm_time(Ml, Nl, Kl, cfg.profile, dtype)
        return dec.Decision(Ml, Nl, Kl, dtype, None, t, None, ())
    if cfg.mode != "auto":
        l = algorithms.get(cfg.mode)
        est = dec.estimate(l, Ml, Nl, Kl, cfg.profile, dtype, fused=cfg.fused,
                           precombined_b=precombined_b)
        return dec.Decision(Ml, Nl, Kl, dtype, l,
                            dec.gemm_time(Ml, Nl, Kl, cfg.profile, dtype),
                            est.time, (est,))
    cache = key = None
    if cfg.use_plan_cache:
        cache = plan_cache.default_cache()
        key = plan_cache.plan_key(
            Ml, Kl, Nl, cfg.profile, dtype, fused=cfg.fused,
            precombined_b=precombined_b, mode=cfg.mode,
            candidates=cfg.candidates, max_grid=cfg.max_grid,
            min_speedup=cfg.min_speedup,
            accuracy_budget=cfg.accuracy_budget, quantize=cfg.quantize)
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    d = dec.decide(Ml, Nl, Kl, cfg.profile, dtype,
                   candidates=cfg.candidate_schemes(), fused=cfg.fused,
                   precombined_b=precombined_b, min_speedup=cfg.min_speedup,
                   accuracy_budget=cfg.accuracy_budget, quantize=cfg.quantize)
    if cache is not None:
        cache.insert(key, d)
    return d


def plan_sharded(M: int, K: int, N: int, cfg: FalconConfig,
                 dtype: str = "bfloat16", precombined_b: bool = False, *,
                 mesh=None, layouts: tuple[dec.ShardLayout, ...] | None = None,
                 n_devices: int | None = None) -> dec.ShardedDecision:
    """Run the shard-aware Decision Module for a distributed contraction.

    ``(M, K, N)`` is the GLOBAL shape. The candidate layouts and the device
    count come from an explicit ``layouts``/``n_devices`` pair, or are
    resolved from ``mesh`` (default: the ambient abstract mesh) through the
    ``parallel.sharding`` rules for the active parallel style. Each layout is
    priced as per-shard local time plus its collective bytes over the
    profile's measured-or-profiled collective bandwidth; plan-cache keys
    embed the layout context (candidate set, D, collective bw), so sharded
    plans never alias local ones.

    Non-auto modes restrict the algorithm axis (``"gemm"``/disabled price no
    LCMA; an explicit scheme prices only that scheme) while the layout axis is
    still searched.
    """
    if layouts is None or n_devices is None:
        from repro.parallel.sharding import layouts_for_mesh
        d_mesh, mesh_layouts = layouts_for_mesh(mesh)
        if n_devices is None:
            n_devices = d_mesh
        if layouts is None:
            layouts = mesh_layouts
    n_devices = max(int(n_devices), 1)
    layouts = tuple(layouts)
    if cfg.mode not in ("auto", "gemm") and cfg.enabled:
        # Forced scheme: search only the layout axis (no Eq. 8 guard, like
        # the forced branch of plan()).
        l = algorithms.get(cfg.mode)
        best = None
        for ly in layouts:
            Ml, Nl, Kl = ly.local_shape(M, N, K, n_devices)
            t_coll = dec.collective_cost(ly, M, N, K, n_devices,
                                         cfg.profile, dtype).time
            est = dec.estimate(l, Ml, Nl, Kl, cfg.profile, dtype,
                               fused=cfg.fused, precombined_b=precombined_b)
            sd = dec.ShardedDecision(
                M, N, K, dtype, l,
                dec.gemm_time(Ml, Nl, Kl, cfg.profile, dtype) + t_coll,
                est.time + t_coll, (est,), layout=ly.name,
                n_devices=n_devices, collective_seconds=t_coll,
                local_shape_mnk=(Ml, Nl, Kl))
            if best is None or sd.seconds < best.seconds:
                best = sd
        return best
    cand = [] if (cfg.mode == "gemm" or not cfg.enabled) \
        else cfg.candidate_schemes()
    cache = key = None
    if cfg.use_plan_cache and cfg.mode == "auto" and cfg.enabled:
        cache = plan_cache.default_cache()
        key = plan_cache.plan_key(
            M, K, N, cfg.profile, dtype, fused=cfg.fused,
            precombined_b=precombined_b, mode=cfg.mode,
            candidates=cfg.candidates, max_grid=cfg.max_grid,
            min_speedup=cfg.min_speedup,
            accuracy_budget=cfg.accuracy_budget, quantize=cfg.quantize,
            layout=",".join(l.name for l in layouts), n_devices=n_devices)
        hit = cache.lookup(key)
        if isinstance(hit, dec.ShardedDecision):
            return hit
    d = dec.decide_sharded(M, N, K, cfg.profile, dtype, n_devices=n_devices,
                           layouts=layouts, candidates=cand,
                           fused=cfg.fused, precombined_b=precombined_b,
                           min_speedup=cfg.min_speedup,
                           accuracy_budget=cfg.accuracy_budget,
                           quantize=cfg.quantize)
    if cache is not None:
        cache.insert(key, d)
    return d


def plan_batched(B: int, M: int, K: int, N: int, cfg: FalconConfig,
                 dtype: str = "bfloat16", precombined_b: bool = False,
                 shared_b: bool = False) -> dec.GroupedDecision:
    """Run the Decision Module for a grouped batched contraction.

    One decision — and ONE plan-cache key (``gBxMxKxN``) — for the whole
    ``B x (M, K) @ (K, N)`` group, instead of pricing a per-element 2-D core
    that batching would then ``vmap``. The grouped model amortizes Combine
    setup across the group: Combine B is priced once when the B operand is
    shared (``shared_b=True`` — attention weights, PlannedWeights) and the
    R*B intermediate products are priced as one grouped GEMM. ``cfg.shards``
    scales the per-element (M, K, N); the group dim is not sharded here
    (expert parallelism shards it upstream, inside ``shard_map``).
    """
    Ml, Kl, Nl = _local_shape(M, K, N, cfg)
    B = int(B)
    if B < 1:
        raise ValueError(f"plan_batched: group size must be >= 1, got {B}")
    if cfg.mode == "gemm" or not cfg.enabled:
        t = dec.gemm_time_batched(B, Ml, Nl, Kl, cfg.profile, dtype,
                                  shared_b=shared_b)
        return dec.GroupedDecision(Ml, Nl, Kl, dtype, None, t, None, (),
                                   B=B, shared_b=shared_b)
    if cfg.mode != "auto":
        l = algorithms.get(cfg.mode)
        est = dec.estimate_grouped(l, B, Ml, Nl, Kl, cfg.profile, dtype,
                                   fused=cfg.fused, precombined_b=precombined_b,
                                   shared_b=shared_b)
        return dec.GroupedDecision(
            Ml, Nl, Kl, dtype, l,
            dec.gemm_time_batched(B, Ml, Nl, Kl, cfg.profile, dtype,
                                  shared_b=shared_b),
            est.time, (est,), B=B, shared_b=shared_b)
    cache = key = None
    if cfg.use_plan_cache:
        cache = plan_cache.default_cache()
        key = plan_cache.plan_key(
            Ml, Kl, Nl, cfg.profile, dtype, fused=cfg.fused,
            precombined_b=precombined_b, mode=cfg.mode,
            candidates=cfg.candidates, max_grid=cfg.max_grid,
            min_speedup=cfg.min_speedup, batch=B, shared_b=shared_b,
            accuracy_budget=cfg.accuracy_budget, quantize=cfg.quantize)
        hit = cache.lookup(key)
        if isinstance(hit, dec.GroupedDecision):
            return hit
    d = dec.decide_batched(B, Ml, Nl, Kl, cfg.profile, dtype,
                           candidates=cfg.candidate_schemes(), fused=cfg.fused,
                           precombined_b=precombined_b, shared_b=shared_b,
                           min_speedup=cfg.min_speedup,
                           accuracy_budget=cfg.accuracy_budget,
                           quantize=cfg.quantize)
    if cache is not None:
        cache.insert(key, d)
    return d


def plan_training(M: int, K: int, N: int, cfg: FalconConfig,
                  dtype: str = "bfloat16") -> tuple[dec.Decision, dec.Decision,
                                                    dec.Decision]:
    """Plan a contraction's forward AND both backward shapes.

    Training runs three falcon contractions per layer: the forward
    ``(M, K) @ (K, N)`` plus the two gradients ``dA = g Bᵀ`` (rows M,
    contract N, cols K) and ``dB = Aᵀ g`` (rows K, contract M, cols N).
    Each goes through the Decision Module and plan cache under its own key,
    so a training warm pass (``engine.warm_buckets(train=True)`` /
    ``tools.tune --train``) leaves the whole jitted step plan-cache-hot.
    Returns ``(d_fwd, d_dA, d_dB)``.
    """
    (sa, sb) = dec.backward_shapes(M, K, N)
    return (plan(M, K, N, cfg, dtype),
            plan(*sa, cfg, dtype),
            plan(*sb, cfg, dtype))


def _pad2(x: jnp.ndarray, d0: int, d1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % d0
    p1 = (-x.shape[1]) % d1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _lcma_apply(a2: jnp.ndarray, b: jnp.ndarray, l: LCMA, cfg: FalconConfig) -> jnp.ndarray:
    """Execute the chosen LCMA on 2-D operands via the registered backend."""
    return backends.get_backend(cfg.backend).apply(a2, b, l, cfg)


def _pad3(x: jnp.ndarray, d0: int, d1: int) -> jnp.ndarray:
    p0 = (-x.shape[1]) % d0
    p1 = (-x.shape[2]) % d1
    if p0 or p1:
        x = jnp.pad(x, ((0, 0), (0, p0), (0, p1)))
    return x


def grouped_matmul_generated(a3: jnp.ndarray, b: jnp.ndarray, l: LCMA,
                             cfg: FalconConfig) -> jnp.ndarray:
    """Grouped LCMA via the generated pure-JAX combines (the jnp backend).

    a3 (G, M, K) x b [(K, N) shared | (G, K, N) per-group] -> (G, M, N).
    The group-parallel lowering: per-group Combine A (one vmapped combine),
    Combine B hoisted ONCE when ``b`` is shared, and the G*R intermediate
    products as a single grouped ``dot_general`` (batch dims (g, r) — XLA
    sees one batched GEMM, not G fragmented launches), then per-group
    Combine H from the float32 accumulator.
    """
    G, M, K = a3.shape
    gen = codegen.generate(l, codegen.CodegenOptions(fused=cfg.fused))
    at = jax.vmap(gen.combine_a)(_pad3(a3, l.m, l.k))      # (G, R, X, Ks)
    if b.ndim == 2:
        N = b.shape[1]
        bt = gen.combine_b(_pad2(b, l.k, l.n))             # hoisted: once
        h = jnp.einsum("grxy,ryz->grxz", at, bt,
                       preferred_element_type=jnp.float32)
    else:
        N = b.shape[2]
        bt = jax.vmap(gen.combine_b)(_pad3(b, l.k, l.n))   # (G, R, Ks, Ns)
        h = jnp.einsum("grxy,gryz->grxz", at, bt,
                       preferred_element_type=jnp.float32)
    c = jax.vmap(gen.stages["combine_h"], in_axes=(0, None))(h, a3.dtype)
    return c[:, :M, :N]


def grouped_matmul_with_precombined(a3: jnp.ndarray, bt: jnp.ndarray, l: LCMA,
                                    n_logical: int,
                                    cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Grouped serving-path matmul against precombined B̃ (generated combines).

    ``bt`` is (R, K/k, N/n) — one shared weight — or (G, R, K/k, N/n) for
    stacked per-group weights (a stacked :class:`PlannedWeight`, e.g. MoE
    experts combined offline). Combine B never runs.
    """
    if cfg is None:
        from . import engine
        cfg = engine.current_config()
    G, M, K = a3.shape
    gen = codegen.generate(l, codegen.CodegenOptions(fused=cfg.fused))
    ap = _pad3(a3, l.m, l.k)
    if ap.shape[2] // l.k != bt.shape[-2]:
        raise ValueError(
            f"grouped_matmul_with_precombined: activation K={K} (padded "
            f"{ap.shape[2]}, grid k={l.k}) does not match precombined "
            f"B̃ {tuple(bt.shape)} for scheme {l.name} {l.key}")
    at = jax.vmap(gen.combine_a)(ap)
    if bt.ndim == 3:
        h = jnp.einsum("grxy,ryz->grxz", at, bt.astype(at.dtype),
                       preferred_element_type=jnp.float32)
    else:
        if bt.shape[0] != G:
            raise ValueError(
                f"grouped_matmul_with_precombined: group sizes differ: "
                f"{a3.shape} vs B̃ {tuple(bt.shape)}")
        h = jnp.einsum("grxy,gryz->grxz", at, bt.astype(at.dtype),
                       preferred_element_type=jnp.float32)
    c = jax.vmap(gen.stages["combine_h"], in_axes=(0, None))(h, a3.dtype)
    return c[:, :M, :n_logical]


def _lcma_apply_grouped(a3: jnp.ndarray, b: jnp.ndarray, l: LCMA,
                        cfg: FalconConfig) -> jnp.ndarray:
    """Execute a grouped LCMA via the backend's grouped path (or fallback).

    Backends without a native ``apply_grouped`` fall back to the generated
    grouped lowering — still one grouped GEMM, never a per-element loop.
    """
    be = backends.get_backend(cfg.backend)
    if be.apply_grouped is not None:
        return be.apply_grouped(a3, b, l, cfg)
    return grouped_matmul_generated(a3, b, l, cfg)


def falcon_matmul(a: jnp.ndarray, b, cfg: FalconConfig | None = None,
                  dtype_hint: str | None = None) -> jnp.ndarray:
    """``a @ b`` with FalconGEMM dispatch. ``a``: (..., M, K), ``b``: (K, N).

    Compatibility form of the unified API: ``cfg=None`` resolves the
    context-scoped config (``repro.api.use``). ``b`` may be a
    :class:`~repro.core.engine.PlannedWeight` (offline Combine-B weights).
    """
    from . import engine
    return engine.matmul(a, b, cfg=cfg, dtype_hint=dtype_hint)


def falcon_dense(x: jnp.ndarray, w, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Linear layer contraction: x (..., K) @ w (K, N).

    ``w`` may be a raw weight matrix or a ``PlannedWeight``; ``cfg=None``
    resolves the context-scoped config.
    """
    from . import engine
    return engine.dense(x, w, cfg=cfg)


def _falcon_dense_shardmap(x: jnp.ndarray, w: jnp.ndarray,
                           cfg: FalconConfig) -> jnp.ndarray | None:
    """Apply LCMA to the per-device LOCAL matmul inside ``shard_map``.

    Lesson from EXPERIMENTS.md §Perf A1: LCMA submatrix slicing on a
    GSPMD-sharded global matmul makes the partitioner reshard every slice
    (7x collective blow-up). The correct placement is the device-local GEMM:
    here tokens are sharded over the batch axes, the weight is gathered to a
    local replica (the same all-gather ZeRO does for the plain matmul), and
    the Decision Module prices the *local* shapes it actually sees.

    Only supported under ``parallel_style="fsdp_only"`` (no TP: the local
    contraction is the full K x N). Returns None to fall back otherwise.

    The plan is the *sharded* tier: the global (T, K, N) is priced per layout
    — batch-sharded local contraction plus the weight all-gather's collective
    bytes vs a fully replicated lowering — so the claim this hook makes on
    the contraction is no longer unpriced. When the replicated layout wins
    (collective-starved link, tiny T) the hook declines and lets GSPMD place
    the op.
    """
    from repro.parallel.sharding import get_parallel_style, resolve_batch_axes
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    if mesh is None or get_parallel_style() != "fsdp_only":
        return None
    sizes = dict(mesh.shape)
    axes = tuple(a for a in resolve_batch_axes() if a in set(mesh.axis_names))
    nb = int(np.prod([sizes[a] for a in axes])) if axes else 1
    *lead, K = x.shape
    T = int(np.prod(lead))
    if nb <= 1 or T % nb != 0:
        return None
    N = w.shape[1]
    d = plan_sharded(T, K, N, dataclasses.replace(cfg, shards=(1, 1, 1)),
                     str(x.dtype), n_devices=nb, layouts=dec.fsdp_layouts())
    if not d.shard_layout.shard[0]:
        return None   # replicated layout priced cheaper: let GSPMD place it

    def body(xl, wl):
        if d.use_lcma:
            c = _lcma_apply(xl, wl, d.algo, dataclasses.replace(cfg, backend="jnp"))
        else:
            c = jnp.matmul(xl, wl)
        return c

    # flatten tokens so the (possibly small) batch dim times seq shards over
    # the full mesh: (B, S, K) -> (B*S, K) with B*S % n_devices == 0
    xspec = P(axes, None)
    out = compat.shard_map(
        body, in_specs=(xspec, P(None, None)),
        out_specs=xspec, check_vma=False)(x.reshape(T, K), w)
    return out.reshape(*lead, N)


# ---------------------------------------------------------------------------
# Offline Combine B (static weights, serving path)
# ---------------------------------------------------------------------------

def precombine_weights(w: jnp.ndarray, l: LCMA) -> jnp.ndarray:
    """Offline Combine B of a static weight matrix: (K, N) -> (R, K/k, N/n)."""
    gen = codegen.generate(l, codegen.CodegenOptions(precombined_b=True))
    return gen.combine_b(_pad2(w, l.k, l.n))


def matmul_with_precombined(a: jnp.ndarray, bt: jnp.ndarray, l: LCMA,
                            n_logical: int, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Serving-path matmul against pre-combined weights B̃ (R, K/k, N/n)."""
    if cfg is None:
        from . import engine
        cfg = engine.current_config()
    gen = codegen.generate(l, codegen.CodegenOptions(
        fused=cfg.fused, precombined_b=True))
    *lead, M, K = a.shape
    a2 = a.reshape(-1, K)
    ap = _pad2(a2, l.m, l.k)
    if ap.shape[1] // l.k != bt.shape[1]:
        # a bare assert here vanished under ``python -O`` and let mismatched
        # operands flow into the combines, producing garbage instead of a
        # shape error
        raise ValueError(
            f"matmul_with_precombined: activation K={K} (padded "
            f"{ap.shape[1]}, grid k={l.k}) does not match precombined "
            f"B̃ {tuple(bt.shape)} for scheme {l.name} {l.key}")
    c = gen.fn(ap, bt)[: a2.shape[0], :n_logical]
    return c.reshape(*lead, M, n_logical) if lead else c
