"""LCMA discovery: rounding-homotopy ALS over the matmul tensor (beyond-paper).

The paper consumes AlphaTensor's published schemes; this module can *find*
ternary rank-R decompositions directly, which is how this codebase recovered
its rank-23 <3,3,3> (Laderman-family) coefficients offline. Method:

  1. alternating least squares on U, V, W (each factor solve is linear),
  2. an increasing ridge penalty pulling entries toward round(x) in {-1,0,1}
     (the homotopy: lam 0 -> 3.0),
  3. final projection + EXACT verification of the Brent equations
     (``repro.analysis.brent`` — integer arithmetic, no float tolerance), so
     a candidate that survives this function is certified, not just
     numerically spot-checked, before it can reach ``algorithms.register()``.

Not a training-time component — a tool for growing ``S_LCMA`` beyond the
built-in library (``discover(3, 3, 3, 23)`` reproduces rank-23 in minutes on
this container; small cases like <2,2,2>;7 take seconds).
"""
from __future__ import annotations

import logging

import numpy as np

from .lcma import LCMA, matmul_tensor

log = logging.getLogger(__name__)

__all__ = ["discover"]


def _target(m: int, k: int, n: int) -> np.ndarray:
    return matmul_tensor(m, k, n).astype(float)


def _solve(G: np.ndarray, Ep: np.ndarray, d1: int, d2: int, lam: float,
           target: np.ndarray, R: int) -> np.ndarray:
    X = np.zeros((R, d1, d2))
    A = G @ G.T + lam * np.eye(R)
    for p in range(d1):
        for q in range(d2):
            b = G @ Ep[p, q] + lam * target[:, p, q]
            X[:, p, q] = np.linalg.solve(A, b)
    return X


def discover(m: int, k: int, n: int, R: int, *, restarts: int = 20,
             als_iters: int = 60, seed: int = 0,
             init: LCMA | None = None) -> LCMA | None:
    """Search for a ternary <m,k,n>;R scheme. Returns None if not found."""
    E = _target(m, k, n)
    rng = np.random.default_rng(seed)
    rnd = lambda X: np.clip(np.round(X), -1, 1)

    def sweeps(U, V, W, lam, nit):
        for _ in range(nit):
            G = np.einsum("ria,rbj->riabj", U, V).reshape(R, -1)
            W = _solve(G, np.transpose(E, (4, 5, 0, 1, 2, 3)).reshape(m, n, -1),
                       m, n, lam, rnd(W), R)
            G = np.einsum("rbj,rcd->rbjcd", V, W).reshape(R, -1)
            U = _solve(G, E.reshape(m, k, -1), m, k, lam, rnd(U), R)
            G = np.einsum("ria,rcd->riacd", U, W).reshape(R, -1)
            V = _solve(G, np.transpose(E, (2, 3, 0, 1, 4, 5)).reshape(k, n, -1),
                       k, n, lam, rnd(V), R)
        return U, V, W

    for restart in range(restarts):
        if init is not None and restart == 0:
            U = init.U.astype(float)
            V = init.V.astype(float)
            W = init.W.astype(float)
        else:
            # gaussian init converges far more reliably than ternary+noise
            U = rng.normal(0, 0.7, (R, m, k))
            V = rng.normal(0, 0.7, (R, k, n))
            W = rng.normal(0, 0.7, (R, m, n))
        U, V, W = sweeps(U, V, W, 0.0, als_iters)
        for lam in (1e-4, 1e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0):
            U, V, W = sweeps(U, V, W, lam, max(als_iters // 2, 30))
        try:
            cand = LCMA(f"discovered-{m}{k}{n}r{R}", m, k, n, R,
                        rnd(U).astype(np.int8), rnd(V).astype(np.int8),
                        rnd(W).astype(np.int8))
        except ValueError:
            continue
        # Exact Brent-equation gate (falcon-check pass 1): only a scheme with
        # ZERO violated equations may escape discovery. A near-miss iterate
        # is logged with the violation count so a long search is debuggable.
        from repro.analysis.brent import check_scheme
        findings = check_scheme(cand)
        if not findings:
            return cand
        log.debug("discover(%d,%d,%d;R=%d) restart %d: %s",
                  m, k, n, R, restart, findings[0].message)
    return None
