"""Decision Module: analytical LCMA selection (paper §III-C, Table II).

Given ``(M, N, K)``, a dtype and a ``HardwareProfile``, iterate the candidate
set ``S_LCMA`` and pick the scheme with the best predicted runtime, or fall
back to standard GEMM. The model is the paper's per-stage arithmetic-intensity
analysis:

  standard GEMM:   AI = 2MNK / (MK + NK + MN)           (Eq. 8 guard)
  Combine A:       flops = (|U|0 - R) * (M/m)(K/k),  bytes = MK (1 + R/(mk))
  Combine B:       flops = (|V|0 - R) * (K/k)(N/n),  bytes = NK (1 + R/(nk))
  GEMM stage:      flops = 2RMNK/(mkn),               bytes = R(MK/mk + NK/nk + MN/mn)
  Combine H:       flops = (|W|0 - mn) * (M/m)(N/n),  bytes = MN (1 + R/(mn))

With the fused GEMM+Combine-H of Algorithm 2, H never reaches HBM: the fused
stage writes C once (MN) and the R/mn overhead term vanishes (Eq. 9 -> 10).

Each stage's time is ``max(compute_time, memory_time)`` — the roofline model
of compute/memory pipeline overlap *within* a stage; stages are serialized
(the paper notes Combine A cannot fully overlap the GEMM, §IV-E).

Padding honesty: LCMA requires dimensions divisible by the grid; the model
charges the *padded* problem for LCMA while standard GEMM runs unpadded, so
boundary waste is priced into the decision.
"""
from __future__ import annotations

import dataclasses

from . import algorithms
from .hardware import HardwareProfile, get_profile
from .lcma import LCMA

__all__ = ["StageCost", "LCMAEstimate", "Decision", "GroupedDecision",
           "gemm_time", "lcma_time", "estimate", "decide",
           "eq8_is_memory_bound", "eq10_profitable", "effective_tflops",
           "backward_shapes", "gemm_time_batched", "estimate_grouped",
           "decide_batched", "batched_is_memory_bound",
           "estimate_quant", "estimate_grouped_quant",
           "ShardLayout", "ShardedEstimate", "ShardedDecision",
           "default_layouts", "fsdp_layouts", "layout_by_name",
           "collective_bytes", "collective_cost", "local_shape",
           "estimate_sharded", "gemm_time_sharded", "decide_sharded"]


def backward_shapes(M: int, K: int, N: int) -> tuple[tuple[int, int, int],
                                                     tuple[int, int, int]]:
    """The two backward contraction shapes of a forward ``(M, K) @ (K, N)``.

    In (rows, contract, cols) convention:

      * ``dA = g @ Bᵀ``  — ``(M, N, K)``
      * ``dB = Aᵀ @ g``  — ``(K, M, N)``

    Training prices (and pre-plans) all three independently: the backward
    aspect ratios differ from the forward's, so the Decision Module may pick
    a different scheme — or an LCMA where the forward ran plain GEMM.
    """
    return (M, N, K), (K, M, N)


@dataclasses.dataclass(frozen=True)
class StageCost:
    name: str
    flops: float
    bytes: float
    compute_time: float
    memory_time: float

    @property
    def time(self) -> float:
        return max(self.compute_time, self.memory_time)

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time >= self.memory_time else "memory"

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)


@dataclasses.dataclass(frozen=True)
class LCMAEstimate:
    lcma: LCMA
    stages: tuple[StageCost, ...]
    padded_shape: tuple[int, int, int]
    precision: str = "fp"        # "fp" (io dtype) or "int8" (quantized tier)

    @property
    def time(self) -> float:
        return sum(s.time for s in self.stages)


@dataclasses.dataclass(frozen=True)
class Decision:
    M: int
    N: int
    K: int
    dtype: str
    algo: LCMA | None            # None => standard GEMM
    gemm_seconds: float
    lcma_seconds: float | None
    estimates: tuple[LCMAEstimate, ...]
    precision: str = "fp"        # "fp" or "int8": the winning tier's precision

    @property
    def use_lcma(self) -> bool:
        return self.algo is not None

    @property
    def quantized(self) -> bool:
        return self.use_lcma and self.precision == "int8"

    @property
    def speedup(self) -> float:
        if self.lcma_seconds is None:
            return 1.0
        return self.gemm_seconds / self.lcma_seconds

    @property
    def seconds(self) -> float:
        return self.lcma_seconds if self.use_lcma else self.gemm_seconds


@dataclasses.dataclass(frozen=True)
class GroupedDecision(Decision):
    """A Decision for a grouped batched contraction ``B x [(M, K) @ (K, N)]``.

    ``M/N/K`` are the *per-group-element* shape; ``B`` is the group size.
    ``shared_b=True`` marks the broadcast-B case (one (K, N) operand shared by
    every group element — attention weights, PlannedWeights, any ``vmap`` with
    a closed-over matrix): Combine B is then priced ONCE for the whole group
    (the paper's Group-Parallel amortization), not B times.
    """

    B: int = 1
    shared_b: bool = False

    @property
    def hoists_combine_b(self) -> bool:
        """True when the grouped lowering runs Combine B once for the group."""
        return self.use_lcma and self.shared_b and self.B > 1


_DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
                "int8": 1}


def _dtype_bytes(dtype: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is not None:
        return b
    try:
        # jnp.dtype knows the extended types numpy alone does not
        # (float8_e4m3fn & friends via ml_dtypes). Lazy import keeps the
        # decision model importable without initializing jax.
        import jax.numpy as jnp
        b = int(jnp.dtype(dtype).itemsize)
    except TypeError as e:
        raise ValueError(
            f"decision model: unknown dtype {dtype!r}; pass a numpy/ml_dtypes "
            f"dtype name (e.g. 'bfloat16', 'float8_e4m3fn', 'int32')") from e
    _DTYPE_BYTES[dtype] = b
    return b


def _pad_up(x: int, d: int) -> int:
    return ((x + d - 1) // d) * d


def _resolve_hw(hw: HardwareProfile | str) -> HardwareProfile:
    """Accept a profile by name so calibrated (autotuned) profiles written to
    disk by ``repro.tools.tune`` are consumed transparently."""
    return get_profile(hw) if isinstance(hw, str) else hw


def _filter_by_budget(candidates: list[LCMA], accuracy_budget: float | None,
                      dtype: str) -> list[LCMA]:
    """Drop candidates whose static error bound exceeds the budget.

    ``accuracy_budget`` is an absolute relative-error ceiling for the call
    site (same units as ``SchemeStability.error_bound``: a multiple of 1.0,
    not of ulp). ``None`` disables the filter. The bound comes from the
    ``stability`` pass of falcon-check (Higham-style growth computed from the
    coefficient tensors alone), so rejection is static — no kernel runs, no
    sampling.
    """
    if accuracy_budget is None:
        return candidates
    return [l for l in candidates
            if l.stability.within_budget(accuracy_budget, dtype)]


def gemm_time(M: int, N: int, K: int, hw: HardwareProfile | str,
              dtype: str = "bfloat16") -> float:
    """Standard GEMM roofline time (Eq. 8 dichotomy)."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    flops = 2.0 * M * N * K
    mem = (M * K + K * N + M * N) * by
    return max(flops / hw.flops_for(dtype), mem / hw.beta)


def eq8_is_memory_bound(M: int, N: int, K: int, hw: HardwareProfile | str,
                        dtype: str = "bfloat16") -> bool:
    """Paper Eq. 8: when standard GEMM is memory-bound, no LCMA can win."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    ai = 2.0 * M * N * K / ((M * K + K * N + M * N) * by)
    return ai <= hw.flops_for(dtype) / hw.beta


def estimate(l: LCMA, M: int, N: int, K: int, hw: HardwareProfile | str,
             dtype: str = "bfloat16", fused: bool = True,
             precombined_b: bool = False,
             pad_multiple: tuple[int, int, int] = (1, 1, 1)) -> LCMAEstimate:
    """Per-stage cost of one LCMA application (Table II + fused correction)."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    m, k, n, R = l.m, l.k, l.n, l.R
    # LCMA pays for padding to grid (and optionally kernel-tile) multiples.
    Mp = _pad_up(M, m * pad_multiple[0])
    Kp = _pad_up(K, k * pad_multiple[1])
    Np = _pad_up(N, n * pad_multiple[2])
    Ms, Ks, Ns = Mp // m, Kp // k, Np // n
    Fa = hw.flops_add
    Fx = hw.flops_for(dtype) * hw.lcma_gemm_efficiency
    stages = []

    def stage(name, flops, nbytes, unit):
        stages.append(StageCost(name, flops, nbytes, flops / unit, nbytes / hw.beta))

    stage("combine_a", (l.nnz_u - R) * Ms * Ks, (Mp * Kp + R * Ms * Ks) * by, Fa)
    if not precombined_b:
        stage("combine_b", (l.nnz_v - R) * Ks * Ns, (Kp * Np + R * Ks * Ns) * by, Fa)
    gemm_flops = 2.0 * R * Ms * Ns * Ks
    if fused:
        # Fused GEMM + Combine H: H stays on-chip; write C exactly once.
        gemm_bytes = (R * (Ms * Ks + Ks * Ns) + Mp * Np) * by
        stage("gemm+combine_h", gemm_flops, gemm_bytes, Fx)
    else:
        gemm_bytes = R * (Ms * Ks + Ks * Ns + Ms * Ns) * by
        stage("gemm", gemm_flops, gemm_bytes, Fx)
        stage("combine_h", (l.nnz_w - m * n) * Ms * Ns, (Mp * Np + R * Ms * Ns) * by, Fa)
    return LCMAEstimate(l, tuple(stages), (Mp, Np, Kp))


def lcma_time(l: LCMA, M: int, N: int, K: int, hw: HardwareProfile, **kw) -> float:
    return estimate(l, M, N, K, hw, **kw).time


def eq10_profitable(l: LCMA, M: int, N: int, K: int, hw: HardwareProfile | str,
                    dtype: str = "bfloat16") -> bool:
    """Paper Eq. 10 closed form (fused; combine stages memory-bound regime)."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    m, k, n, R = l.m, l.k, l.n, l.R
    num = 2.0 * M * N * K * (1.0 - R / (m * n * k))
    den = (M * K * (1 + R / (m * k)) + N * K * (1 + R / (n * k)) + M * N) * by
    return num / den > hw.flops_for(dtype) / hw.beta


# ---------------------------------------------------------------------------
# Quantized (int8) tier (paper §IV-C: quantization fused into the Combines)
#
# The quantized pipeline (kernels/quant_combine.py) folds symmetric 127-level
# block-scaled quantization into Combine A/B, runs the R-batched GEMM on int8
# operands with an int32 accumulator, and dequantizes inside the fused
# Combine-H epilogue. The cost model prices that pipeline honestly:
#
#   * combine stages pay the quant pass (abs-max + scale) in flops, write the
#     combined operand as int8 (1 B/elem) plus f32 scales (one per reduction
#     block of _QUANT_BLOCK elements);
#   * the GEMM stage reads int8 operands — 1/4 the fp32 traffic — at the
#     profile's int8 throughput (``hw.flops_for("int8")``; falls back to
#     flops_mul when the profile has no measured int8 rate);
#   * the output is written once in the io dtype (dequantized on-chip).
#
# Eq. 8 deliberately does NOT gate this tier: the guard models same-dtype
# traffic, and int8 operands cut the memory side ~4x, so a memory-bound fp
# GEMM can still be a quantized-LCMA win. Selection is instead gated by the
# accuracy budget: the static int8 error bound (stability pass, eps =
# 1/(2*127)) must fit the caller's ``accuracy_budget``.
# ---------------------------------------------------------------------------

# Reduction-block depth of the block-scaled quantization (kernel default).
_QUANT_BLOCK = 128


def _quant_eligible(l: LCMA, accuracy_budget: float | None) -> bool:
    """Static eligibility of scheme ``l`` for the int8 tier.

    Requires (a) the quant reduction block cannot overflow the int32
    accumulator, and (b) when a budget is set, the scheme's int8 error bound
    fits it. Import is lazy: ``repro.analysis`` imports ``repro.core``.
    """
    from repro.analysis import stability as _stab
    if _QUANT_BLOCK > _stab.max_safe_accum_depth(32):
        return False
    if accuracy_budget is None:
        return True
    return l.stability.within_budget(accuracy_budget, "int8")


def estimate_quant(l: LCMA, M: int, N: int, K: int,
                   hw: HardwareProfile | str, dtype: str = "bfloat16",
                   fused: bool = True, precombined_b: bool = False,
                   pad_multiple: tuple[int, int, int] = (1, 1, 1),
                   ) -> LCMAEstimate:
    """Per-stage cost of one *quantized* LCMA application.

    ``dtype`` is the io dtype (A input, C output); the combined operands move
    as int8 with f32 block scales. The quantized pipeline is fused-only
    (dequantization lives in the Combine-H epilogue), so ``fused`` is
    accepted for signature symmetry but the GEMM stage is always priced
    fused. ``precombined_b=True`` models an offline-quantized B̃q (the
    PlannedWeight path): no Combine-B stage, int8 B traffic only.
    """
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    m, k, n, R = l.m, l.k, l.n, l.R
    Mp = _pad_up(M, m * pad_multiple[0])
    Kp = _pad_up(K, k * pad_multiple[1])
    Np = _pad_up(N, n * pad_multiple[2])
    Ms, Ks, Ns = Mp // m, Kp // k, Np // n
    Ksb = -(-Ks // _QUANT_BLOCK)       # scale blocks along the reduction
    Fa = hw.flops_add
    Fq = hw.flops_for("int8") * hw.lcma_gemm_efficiency
    stages = []

    def stage(name, flops, nbytes, unit):
        stages.append(StageCost(name, flops, nbytes, flops / unit, nbytes / hw.beta))

    # Combine A + quantize: combine flops plus the quant pass (abs-max scan
    # and scale multiply, ~2 ops/elem of the combined tensor); reads fp A,
    # writes int8 Ã plus one f32 scale per block.
    stage("combine_a+quant",
          (l.nnz_u - R) * Ms * Ks + 2.0 * R * Ms * Ks,
          Mp * Kp * by + R * Ms * Ks + R * Ms * Ksb * 4, Fa)
    if not precombined_b:
        stage("combine_b+quant",
              (l.nnz_v - R) * Ks * Ns + 2.0 * R * Ks * Ns,
              Kp * Np * by + R * Ks * Ns + R * Ksb * Ns * 4, Fa)
    # Fused int8 GEMM + dequantizing Combine H: int8 operands (1 B/elem),
    # f32 scales, one fp output write.
    stage("gemm+combine_h[int8]", 2.0 * R * Ms * Ns * Ks,
          R * (Ms * Ks + Ks * Ns) + R * (Ms * Ksb + Ksb * Ns) * 4
          + Mp * Np * by, Fq)
    return LCMAEstimate(l, tuple(stages), (Mp, Np, Kp), precision="int8")


def estimate_grouped_quant(l: LCMA, B: int, M: int, N: int, K: int,
                           hw: HardwareProfile | str, dtype: str = "bfloat16",
                           fused: bool = True, precombined_b: bool = False,
                           shared_b: bool = False,
                           pad_multiple: tuple[int, int, int] = (1, 1, 1),
                           ) -> LCMAEstimate:
    """Grouped analogue of :func:`estimate_quant` (see :func:`estimate_grouped`
    for the B-scaling and ``eff_B`` launch-amortization model)."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    m, k, n, R = l.m, l.k, l.n, l.R
    Mp = _pad_up(M, m * pad_multiple[0])
    Kp = _pad_up(K, k * pad_multiple[1])
    Np = _pad_up(N, n * pad_multiple[2])
    Ms, Ks, Ns = Mp // m, Kp // k, Np // n
    Ksb = -(-Ks // _QUANT_BLOCK)
    nb = 1 if shared_b else B
    Fa = hw.flops_add
    eff = hw.lcma_gemm_efficiency
    eff_b = B * eff / (B * eff + 1.0 - eff)
    Fq = hw.flops_for("int8") * eff_b
    stages = []

    def stage(name, flops, nbytes, unit):
        stages.append(StageCost(name, flops, nbytes, flops / unit, nbytes / hw.beta))

    stage("combine_a+quant",
          ((l.nnz_u - R) * Ms * Ks + 2.0 * R * Ms * Ks) * B,
          (Mp * Kp * by + R * Ms * Ks + R * Ms * Ksb * 4) * B, Fa)
    if not precombined_b:
        stage("combine_b+quant",
              ((l.nnz_v - R) * Ks * Ns + 2.0 * R * Ks * Ns) * nb,
              (Kp * Np * by + R * Ks * Ns + R * Ksb * Ns * 4) * nb, Fa)
    stage("gemm+combine_h[int8]", 2.0 * R * Ms * Ns * Ks * B,
          B * (R * Ms * Ks + R * Ms * Ksb * 4 + Mp * Np * by)
          + nb * (R * Ks * Ns + R * Ksb * Ns * 4), Fq)
    return LCMAEstimate(l, tuple(stages), (Mp, Np, Kp), precision="int8")


def decide(M: int, N: int, K: int, hw: HardwareProfile | str, dtype: str = "bfloat16",
           candidates: list[LCMA] | None = None, fused: bool = True,
           precombined_b: bool = False,
           pad_multiple: tuple[int, int, int] = (1, 1, 1),
           min_speedup: float = 1.0,
           accuracy_budget: float | None = None,
           quantize: bool = False) -> Decision:
    """Select the best LCMA for (M, N, K) or fall back to standard GEMM.

    ``hw`` may be a ``HardwareProfile`` or a profile *name*; names resolve
    through ``hardware.get_profile``, which also finds calibrated profiles
    written to disk by the autotuner (``python -m repro.tools.tune``).

    ``accuracy_budget`` statically rejects candidates whose Higham-style
    error bound (``l.stability.error_bound(dtype)``) exceeds the given
    relative-error ceiling; filtered-out schemes never get priced, so a
    numerically aggressive scheme cannot win on speed alone.

    ``quantize=True`` additionally prices every budget-eligible candidate's
    int8 tier (:func:`estimate_quant`) and picks the best (scheme, precision)
    pair jointly; the winner's tier is reported in ``Decision.precision``.
    The Eq. 8 fast path only skips the *fp* estimates — the quantized tier
    moves ~4x less operand traffic, so it stays in the running even when the
    fp GEMM is memory-bound.
    """
    hw = _resolve_hw(hw)
    t_gemm = gemm_time(M, N, K, hw, dtype)
    if candidates is None:
        candidates = algorithms.candidates()
    candidates = _filter_by_budget(candidates, accuracy_budget, dtype)
    if eq8_is_memory_bound(M, N, K, hw, dtype):
        # Eq. 8 fast path: memory-bound GEMM => same-precision LCMA
        # cannot win. The quantized tier is exempt (see docstring).
        if not quantize:
            return Decision(M, N, K, dtype, None, t_gemm, None, ())
        ests: tuple[LCMAEstimate, ...] = ()
    else:
        ests = tuple(
            estimate(l, M, N, K, hw, dtype, fused=fused,
                     precombined_b=precombined_b, pad_multiple=pad_multiple)
            for l in candidates
        )
    if quantize:
        ests += tuple(
            estimate_quant(l, M, N, K, hw, dtype, fused=fused,
                           precombined_b=precombined_b,
                           pad_multiple=pad_multiple)
            for l in candidates if _quant_eligible(l, accuracy_budget)
        )
    best = min(ests, key=lambda e: e.time, default=None)
    if best is not None and best.time * min_speedup < t_gemm:
        return Decision(M, N, K, dtype, best.lcma, t_gemm, best.time, ests,
                        precision=best.precision)
    return Decision(M, N, K, dtype, None, t_gemm, None, ests)


# ---------------------------------------------------------------------------
# Group-parallel batched pricing (paper §III-B Group-Parallel Optimizations)
#
# A grouped contraction is B independent (M, K) @ (K, N) products executed as
# ONE planned unit: per-element Combine A, Combine B either hoisted (shared
# operand) or per element, and a single (B*R)-batched intermediate GEMM.
# Pricing the group as a whole — instead of vmapping a per-element Decision —
# is what lets LCMA overhead amortize across the batch: the per-element
# problem may be memory-bound (Eq. 8 declines) while the grouped problem,
# with Combine-B hoisted and the R*B products batched, is not.
# ---------------------------------------------------------------------------

def gemm_time_batched(B: int, M: int, N: int, K: int,
                      hw: HardwareProfile | str, dtype: str = "bfloat16",
                      shared_b: bool = False) -> float:
    """Roofline time of the batched-GEMM baseline for a grouped contraction.

    ``shared_b`` models the broadcast-B baseline (one weight read for the
    whole group) so the LCMA-vs-GEMM comparison stays apples-to-apples.
    """
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    nb = 1 if shared_b else B
    flops = 2.0 * B * M * N * K
    mem = (B * (M * K + M * N) + nb * K * N) * by
    return max(flops / hw.flops_for(dtype), mem / hw.beta)


def batched_is_memory_bound(B: int, M: int, N: int, K: int,
                            hw: HardwareProfile | str,
                            dtype: str = "bfloat16",
                            shared_b: bool = False) -> bool:
    """Grouped Eq. 8 guard: a memory-bound batched GEMM admits no LCMA win."""
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    nb = 1 if shared_b else B
    ai = 2.0 * B * M * N * K / ((B * (M * K + M * N) + nb * K * N) * by)
    return ai <= hw.flops_for(dtype) / hw.beta


def estimate_grouped(l: LCMA, B: int, M: int, N: int, K: int,
                     hw: HardwareProfile | str, dtype: str = "bfloat16",
                     fused: bool = True, precombined_b: bool = False,
                     shared_b: bool = False,
                     pad_multiple: tuple[int, int, int] = (1, 1, 1)) -> LCMAEstimate:
    """Per-stage cost of one grouped LCMA application (Table II, amortized).

    Relative to ``estimate``: Combine A and the output scale by B; Combine B
    scales by 1 when the B operand is shared across the group (hoisted — run
    once, reused B times) and by B otherwise; the GEMM stage is one
    (B*R)-batched product whose B-side traffic is likewise 1x or Bx. The
    ``padded_shape`` reported is the per-element one.

    The grouped GEMM stage also amortizes the *launch inefficiency* the
    autotuner measures: ``lcma_gemm_efficiency`` is calibrated on the
    R-batched stage (one group), and modelling its shortfall as a fixed
    per-launch overhead gives the B-group efficiency

        eff_B = B * eff / (B * eff + 1 - eff)

    — eff at B=1, approaching 1 as the R*B products fill the pipeline. This
    is why a grouped decision can pick an LCMA where pricing one group
    element (and vmapping) declines.
    """
    hw = _resolve_hw(hw)
    by = _dtype_bytes(dtype)
    m, k, n, R = l.m, l.k, l.n, l.R
    Mp = _pad_up(M, m * pad_multiple[0])
    Kp = _pad_up(K, k * pad_multiple[1])
    Np = _pad_up(N, n * pad_multiple[2])
    Ms, Ks, Ns = Mp // m, Kp // k, Np // n
    nb = 1 if shared_b else B          # Combine-B / B-operand multiplicity
    Fa = hw.flops_add
    eff = hw.lcma_gemm_efficiency
    eff_b = B * eff / (B * eff + 1.0 - eff)
    Fx = hw.flops_for(dtype) * eff_b
    stages = []

    def stage(name, flops, nbytes, unit):
        stages.append(StageCost(name, flops, nbytes, flops / unit, nbytes / hw.beta))

    stage("combine_a", (l.nnz_u - R) * Ms * Ks * B,
          (Mp * Kp + R * Ms * Ks) * B * by, Fa)
    if not precombined_b:
        stage("combine_b", (l.nnz_v - R) * Ks * Ns * nb,
              (Kp * Np + R * Ks * Ns) * nb * by, Fa)
    gemm_flops = 2.0 * R * Ms * Ns * Ks * B
    if fused:
        gemm_bytes = (B * R * Ms * Ks + nb * R * Ks * Ns + B * Mp * Np) * by
        stage("gemm+combine_h", gemm_flops, gemm_bytes, Fx)
    else:
        gemm_bytes = (B * R * (Ms * Ks + Ms * Ns) + nb * R * Ks * Ns) * by
        stage("gemm", gemm_flops, gemm_bytes, Fx)
        stage("combine_h", (l.nnz_w - m * n) * Ms * Ns * B,
              (Mp * Np + R * Ms * Ns) * B * by, Fa)
    return LCMAEstimate(l, tuple(stages), (Mp, Np, Kp))


def decide_batched(B: int, M: int, N: int, K: int, hw: HardwareProfile | str,
                   dtype: str = "bfloat16",
                   candidates: list[LCMA] | None = None, fused: bool = True,
                   precombined_b: bool = False, shared_b: bool = False,
                   pad_multiple: tuple[int, int, int] = (1, 1, 1),
                   min_speedup: float = 1.0,
                   accuracy_budget: float | None = None,
                   quantize: bool = False) -> GroupedDecision:
    """Select the best LCMA for a grouped contraction, or batched GEMM.

    The grouped analogue of :func:`decide`: one Decision for the whole
    ``B x (M, K) @ (K, N)`` group. ``B=1`` degenerates to the 2-D model
    (same estimates as ``decide``). ``accuracy_budget`` filters candidates
    by static error bound exactly as in :func:`decide`; ``quantize=True``
    prices the int8 tier jointly (and bypasses the grouped Eq. 8 guard for
    it), exactly as in :func:`decide`.
    """
    hw = _resolve_hw(hw)
    t_gemm = gemm_time_batched(B, M, N, K, hw, dtype, shared_b=shared_b)
    if candidates is None:
        candidates = algorithms.candidates()
    candidates = _filter_by_budget(candidates, accuracy_budget, dtype)
    if batched_is_memory_bound(B, M, N, K, hw, dtype, shared_b=shared_b):
        if not quantize:
            return GroupedDecision(M, N, K, dtype, None, t_gemm, None, (),
                                   B=B, shared_b=shared_b)
        ests: tuple[LCMAEstimate, ...] = ()
    else:
        ests = tuple(
            estimate_grouped(l, B, M, N, K, hw, dtype, fused=fused,
                             precombined_b=precombined_b, shared_b=shared_b,
                             pad_multiple=pad_multiple)
            for l in candidates
        )
    if quantize:
        ests += tuple(
            estimate_grouped_quant(l, B, M, N, K, hw, dtype, fused=fused,
                                   precombined_b=precombined_b,
                                   shared_b=shared_b,
                                   pad_multiple=pad_multiple)
            for l in candidates if _quant_eligible(l, accuracy_budget)
        )
    best = min(ests, key=lambda e: e.time, default=None)
    if best is not None and best.time * min_speedup < t_gemm:
        return GroupedDecision(M, N, K, dtype, best.lcma, t_gemm, best.time,
                               ests, precision=best.precision,
                               B=B, shared_b=shared_b)
    return GroupedDecision(M, N, K, dtype, None, t_gemm, None, ests,
                           B=B, shared_b=shared_b)


# ---------------------------------------------------------------------------
# Shard-aware pricing (communication-avoiding layouts as a candidate axis)
#
# Borrowed from the SFC communication-avoiding matmul line of work: a sharded
# contraction is priced as per-shard compute PLUS an explicit collective term,
#
#     T(layout) = T_local(M/s_M, N/s_N, K/s_K) + bytes_coll / bw_coll
#
# where the local term reuses the calibrated per-stage model above (so LCMA
# candidates are priced on the *local* shapes a device actually contracts)
# and the collective term charges ring all-gather / reduce-scatter traffic:
# each device moves (D-1)/D of the operand per all-gather or reduce-scatter
# and twice that for an all-reduce. Layout choice thereby becomes one more
# dimension of the candidate set `decide` searches over.
# ---------------------------------------------------------------------------

# bytes moved per device, as a multiple of the operand size, for one collective
_COLL_FACTOR = {"all_gather": 1.0, "reduce_scatter": 1.0, "all_reduce": 2.0}


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """A 1-D device layout for an ``(M, K) @ (K, N)`` contraction.

    ``shard`` flags which of (M, K, N) is divided across the D devices of the
    mesh axis; ``collectives`` lists the ``(op, operand)`` pairs the layout
    must run to materialize the full output, with operands named "A" (M*K),
    "B" (K*N) and "C" (M*N).
    """

    name: str
    shard: tuple[bool, bool, bool]                    # (M, K, N) sharded?
    collectives: tuple[tuple[str, str], ...] = ()     # ((op, operand), ...)

    def local_shape(self, M: int, N: int, K: int,
                    n_devices: int) -> tuple[int, int, int]:
        sm, sk, sn = self.shard
        d = max(int(n_devices), 1)
        ceil = lambda x: -(-x // d)  # noqa: E731
        return (ceil(M) if sm else M, ceil(N) if sn else N,
                ceil(K) if sk else K)


def local_shape(layout: ShardLayout, M: int, N: int, K: int,
                n_devices: int) -> tuple[int, int, int]:
    """Per-device ``(M, N, K)`` under ``layout`` (ceil-divided shards)."""
    return layout.local_shape(M, N, K, n_devices)


# Tensor-parallel projection layouts (activations replicated on the model
# axis; the weight is the shardable operand):
#   replicated — every device runs the full contraction, no communication;
#   col        — weight sharded on N (column-parallel); each device owns an
#                (M, N/D) slice of C, all-gathered for the next replicated op;
#   row        — weight sharded on K (row-parallel); each device holds a full
#                (M, N) partial sum, all-reduced.
_TP_LAYOUTS = (
    ShardLayout("replicated", (False, False, False)),
    ShardLayout("col", (False, False, True), (("all_gather", "C"),)),
    ShardLayout("row", (False, True, False), (("all_reduce", "C"),)),
)

# FSDP-style layouts (activations sharded on the batch/M axis; the weight
# sharded at rest must be gathered before use in either layout):
#   gathered — undo the batch shard too: gather A and B, contract everything
#              everywhere (what a naive resharding lowering does);
#   data     — keep M sharded, all-gather only the weight (the shard_map
#              local-matmul backend's actual data flow — ZeRO-style).
_FSDP_LAYOUTS = (
    ShardLayout("gathered", (False, False, False),
                (("all_gather", "A"), ("all_gather", "B"))),
    ShardLayout("data", (True, False, False), (("all_gather", "B"),)),
)

_LAYOUTS_BY_NAME = {l.name: l for l in _TP_LAYOUTS + _FSDP_LAYOUTS}


def default_layouts() -> tuple[ShardLayout, ...]:
    """Candidate layouts for tensor-parallel (replicated-activation) ops."""
    return _TP_LAYOUTS


def fsdp_layouts() -> tuple[ShardLayout, ...]:
    """Candidate layouts for batch-sharded (fsdp_only) dense ops."""
    return _FSDP_LAYOUTS


def layout_by_name(name: str) -> ShardLayout:
    try:
        return _LAYOUTS_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown shard layout {name!r}; known: "
                       f"{sorted(_LAYOUTS_BY_NAME)}") from None


def collective_bytes(layout: ShardLayout, M: int, N: int, K: int,
                     n_devices: int, dtype: str = "bfloat16") -> float:
    """Per-device bytes moved by ``layout``'s collectives (ring model)."""
    if n_devices <= 1:
        return 0.0
    by = _dtype_bytes(dtype)
    sizes = {"A": M * K, "B": K * N, "C": M * N}
    frac = (n_devices - 1) / n_devices
    return sum(_COLL_FACTOR[op] * sizes[operand] * by * frac
               for op, operand in layout.collectives)


def collective_cost(layout: ShardLayout, M: int, N: int, K: int,
                    n_devices: int, hw: HardwareProfile | str,
                    dtype: str = "bfloat16") -> StageCost:
    """The collective term as a StageCost (pure memory traffic, zero flops)."""
    hw = _resolve_hw(hw)
    nbytes = collective_bytes(layout, M, N, K, n_devices, dtype)
    bw = hw.coll_bw()
    t = nbytes / bw if nbytes else 0.0
    return StageCost(f"collective[{layout.name}]", 0.0, nbytes, 0.0, t)


@dataclasses.dataclass(frozen=True)
class ShardedEstimate:
    """Local per-stage estimate plus the layout's collective term."""

    local: LCMAEstimate
    collective: StageCost
    layout: str
    n_devices: int

    @property
    def time(self) -> float:
        return self.local.time + self.collective.time


def gemm_time_sharded(M: int, N: int, K: int, hw: HardwareProfile | str,
                      layout: ShardLayout, n_devices: int,
                      dtype: str = "bfloat16") -> float:
    """Roofline time of standard GEMM under ``layout``: local + collective."""
    hw = _resolve_hw(hw)
    Ml, Nl, Kl = layout.local_shape(M, N, K, n_devices)
    return (gemm_time(Ml, Nl, Kl, hw, dtype)
            + collective_cost(layout, M, N, K, n_devices, hw, dtype).time)


def estimate_sharded(l: LCMA, M: int, N: int, K: int,
                     hw: HardwareProfile | str, dtype: str = "bfloat16",
                     *, layout: ShardLayout, n_devices: int,
                     fused: bool = True, precombined_b: bool = False,
                     pad_multiple: tuple[int, int, int] = (1, 1, 1),
                     ) -> ShardedEstimate:
    """One LCMA candidate under ``layout``: the calibrated per-stage model on
    the per-shard (local) shape, plus the layout's collective term."""
    hw = _resolve_hw(hw)
    Ml, Nl, Kl = layout.local_shape(M, N, K, n_devices)
    loc = estimate(l, Ml, Nl, Kl, hw, dtype, fused=fused,
                   precombined_b=precombined_b, pad_multiple=pad_multiple)
    coll = collective_cost(layout, M, N, K, n_devices, hw, dtype)
    return ShardedEstimate(loc, coll, layout.name, n_devices)


@dataclasses.dataclass(frozen=True)
class ShardedDecision(Decision):
    """A Decision for a contraction distributed over a 1-D mesh axis.

    ``M/N/K`` are the *global* shape. ``gemm_seconds``/``lcma_seconds`` are
    end-to-end per-device times under the winning ``layout`` — local
    contraction plus ``collective_seconds`` — so ``seconds``/``speedup``
    compare complete distributed executions. ``local_shape_mnk`` is the
    per-device shape the winning layout actually contracts (what the executor
    should plan its kernels for).
    """

    layout: str = "replicated"
    n_devices: int = 1
    collective_seconds: float = 0.0
    local_shape_mnk: tuple[int, int, int] = (0, 0, 0)

    @property
    def communication_avoiding(self) -> bool:
        """True when a sharded layout beat full replication."""
        return any(self.shard_layout.shard)

    @property
    def shard_layout(self) -> ShardLayout:
        return layout_by_name(self.layout)

    @property
    def collective_fraction(self) -> float:
        return self.collective_seconds / self.seconds if self.seconds else 0.0


def decide_sharded(M: int, N: int, K: int, hw: HardwareProfile | str,
                   dtype: str = "bfloat16", *, n_devices: int,
                   layouts: tuple[ShardLayout, ...] | None = None,
                   candidates: list[LCMA] | None = None, fused: bool = True,
                   precombined_b: bool = False,
                   pad_multiple: tuple[int, int, int] = (1, 1, 1),
                   min_speedup: float = 1.0,
                   accuracy_budget: float | None = None,
                   quantize: bool = False) -> ShardedDecision:
    """Pick the best (layout, algorithm) pair for a distributed contraction.

    The layout axis widens :func:`decide`'s search: every candidate layout is
    priced as local-contraction time on its per-shard shape (via the same
    calibrated estimates, so Eq. 8 guards and padding honesty apply to the
    LOCAL problem) plus its collective bytes over the profile's measured or
    profiled collective bandwidth. With ``n_devices == 1`` every layout
    degenerates to the local model and the replicated plan wins by ties.
    """
    hw = _resolve_hw(hw)
    if layouts is None:
        layouts = default_layouts()
    best: ShardedDecision | None = None
    for ly in layouts:
        Ml, Nl, Kl = ly.local_shape(M, N, K, n_devices)
        t_coll = collective_cost(ly, M, N, K, n_devices, hw, dtype).time
        d = decide(Ml, Nl, Kl, hw, dtype, candidates=candidates, fused=fused,
                   precombined_b=precombined_b, pad_multiple=pad_multiple,
                   min_speedup=min_speedup, accuracy_budget=accuracy_budget,
                   quantize=quantize)
        sd = ShardedDecision(
            M, N, K, dtype, d.algo,
            d.gemm_seconds + t_coll,
            None if d.lcma_seconds is None else d.lcma_seconds + t_coll,
            d.estimates, precision=d.precision,
            layout=ly.name, n_devices=n_devices,
            collective_seconds=t_coll, local_shape_mnk=(Ml, Nl, Kl))
        if best is None or sd.seconds < best.seconds:
            best = sd
    assert best is not None, "decide_sharded: empty layout set"
    return best


def effective_tflops(M: int, N: int, K: int, seconds: float) -> float:
    """Paper's metric: 2MNK / time — LCMA can exceed the hardware peak."""
    return 2.0 * M * N * K / seconds / 1e12


def predicted_effective_tflops(l: LCMA | None, M: int, N: int, K: int,
                               hw: HardwareProfile, dtype: str = "bfloat16",
                               **kw) -> float:
    t = gemm_time(M, N, K, hw, dtype) if l is None else lcma_time(l, M, N, K, hw, dtype=dtype, **kw)
    return effective_tflops(M, N, K, t)
