"""Persistent, shape-keyed plan cache for the Decision Module.

``decide()`` enumerates every candidate LCMA and prices four pipeline stages
per candidate — cheap once, wasteful when a serving process re-traces the same
dozen linear-layer shapes millions of times (``launch/serve.py``,
``models/layers.py``). This module memoizes ``Decision`` objects behind a key
that captures everything the decision depends on:

  (M, K, N) local shape x dtype x hardware-profile fingerprint x dispatch
  policy (fused / precombined-B / candidate set / min_speedup)

The cache is a bounded in-memory LRU, optionally backed by a JSON file so a
warmed cache survives process restarts (the ``repro.tools.tune`` CLI writes
one next to the calibrated profile). The hardware fingerprint hashes the
profile's *numbers*, not just its name, so re-calibrating the machine
invalidates stale plans automatically.

Cached entries drop the per-candidate ``estimates`` breakdown on disk (it is
re-derivable); in-memory hits return the original ``Decision`` untouched.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import json
import logging
import os
import threading
import time

try:                      # POSIX advisory locks guard cross-process writes
    import fcntl
except ImportError:       # pragma: no cover - non-POSIX fallback
    fcntl = None

from . import algorithms
from . import decision as dec
from .hardware import HardwareProfile

log = logging.getLogger(__name__)

__all__ = ["CacheStats", "PlanCache", "plan_key", "default_cache", "configure",
           "stats", "flush", "reset", "DEFAULT_CAPACITY", "ENV_PATH"]

DEFAULT_CAPACITY = 4096
ENV_PATH = "FALCON_PLAN_CACHE"          # set => default cache persists here
_FORMAT_VERSION = 1


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    loaded: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses, inserts=self.inserts,
                    evictions=self.evictions, loaded=self.loaded,
                    hit_rate=round(self.hit_rate, 4))


def _profile_fingerprint(hw: HardwareProfile) -> str:
    """Short stable hash of the numbers a Decision depends on.

    Memoized on the (frozen, long-lived) profile object: plan_key runs on
    every trace-time plan() — the hot path this cache exists to shorten.
    """
    fp = getattr(hw, "_plan_fingerprint", None)
    if fp is None:
        blob = json.dumps([hw.name, hw.flops_mul, hw.flops_add, hw.beta,
                           hw.lcma_gemm_efficiency,
                           sorted((hw.dtype_flops or {}).items())])
        fp = hashlib.sha1(blob.encode()).hexdigest()[:12]
        object.__setattr__(hw, "_plan_fingerprint", fp)   # frozen dataclass
    return fp


def plan_key(M: int, K: int, N: int, hw: HardwareProfile, dtype: str, *,
             fused: bool = True, precombined_b: bool = False,
             mode: str = "auto", candidates: tuple[str, ...] | None = None,
             max_grid: int = 5, min_speedup: float = 1.0,
             batch: int = 1, shared_b: bool = False,
             layout: str | None = None, n_devices: int = 1,
             accuracy_budget: float | None = None,
             quantize: bool = False) -> str:
    """Cache key for one Decision-Module invocation (local, per-device shape).

    ``batch > 1`` keys a *grouped* decision (``plan_batched``): the whole
    ``B x (M, K) @ (K, N)`` group lives under ONE ``gBxMxKxN`` key — never B
    per-element keys — and the shared-B (hoisted Combine-B) variant is keyed
    separately because it prices differently. ``batch == 1`` keeps the
    historical key format, so existing persisted caches stay valid.

    ``layout`` keys a *sharded* decision (``plan_sharded``): ``M/K/N`` are
    then the GLOBAL shape and the key embeds the mesh layout context — the
    candidate-layout set, the device count and the collective bandwidth the
    collective term was priced against (so re-probing ``--collectives``
    invalidates stale sharded plans without touching local ones).

    ``accuracy_budget`` appends an ``ab=`` token only when a budget is set:
    a budget narrows the candidate set statically (stability-pass filter), so
    a budgeted plan must not alias the unbudgeted one — while budget-free
    keys keep the historical format and existing persisted caches stay valid.

    ``quantize`` appends a ``quant=1`` token only when the int8 tier was in
    the candidate search (same conditional-token discipline: fp-only keys are
    byte-identical to the historical format, old caches stay valid).
    """
    cands = ",".join(candidates) if candidates is not None else f"grid<={max_grid}"
    shape = f"{M}x{K}x{N}" if batch == 1 else \
        f"g{batch}x{M}x{K}x{N}|sb={int(shared_b)}"
    parts = [
        f"{hw.name}@{_profile_fingerprint(hw)}", dtype, shape,
        f"mode={mode}", f"fused={int(fused)}", f"pre={int(precombined_b)}",
        f"ms={min_speedup:g}", cands,
    ]
    if accuracy_budget is not None:
        parts.append(f"ab={accuracy_budget:g}")
    if quantize:
        parts.append("quant=1")
    if layout is not None:
        parts.append(f"ly={layout}xD{int(n_devices)}@cb={hw.coll_bw():g}")
    return "|".join(parts)


@contextlib.contextmanager
def _file_lock(lock_path: str, timeout: float = 10.0):
    """Advisory inter-process lock around cache-file writes.

    ``flock`` is taken on a sidecar ``.lock`` file (never on the cache file
    itself — ``os.replace`` swaps that inode out from under any holder).
    flock contends between distinct fds, so it also serializes writer threads
    that each own their own :class:`PlanCache` on the same path. On timeout
    the writer proceeds unlocked with a warning — a stale or wedged lock
    holder must never take down the serving process; merge-on-save plus the
    atomic rename keeps even that race loss-bounded (one writer's fresh
    entries) rather than corrupting.
    """
    fd = os.open(lock_path, os.O_CREAT | os.O_RDWR, 0o644)
    locked = False
    try:
        if fcntl is not None:
            deadline = time.monotonic() + timeout
            while True:
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                    locked = True
                    break
                except OSError:
                    if time.monotonic() >= deadline:
                        log.warning("plan cache lock %s: timeout after %.1fs; "
                                    "writing unlocked", lock_path, timeout)
                        break
                    time.sleep(0.01)
        yield
    finally:
        if locked:
            fcntl.flock(fd, fcntl.LOCK_UN)
        os.close(fd)


def _encode(d: dec.Decision) -> dict:
    out = {
        "M": d.M, "N": d.N, "K": d.K, "dtype": d.dtype,
        "algo": d.algo.name if d.algo is not None else None,
        "gemm_seconds": d.gemm_seconds, "lcma_seconds": d.lcma_seconds,
    }
    if d.algo is not None:
        # Content hash of the scheme definition: load-time and falcon-check's
        # cache-audit pass both prove the cached decision still refers to the
        # coefficients it priced (a renamed/edited scheme drops the entry).
        out["algo_fp"] = d.algo.fingerprint
    if d.precision != "fp":
        out["prec"] = d.precision
    if isinstance(d, dec.GroupedDecision):
        out["B"] = d.B
        out["shared_b"] = d.shared_b
    elif isinstance(d, dec.ShardedDecision):
        out["ly"] = d.layout
        out["D"] = d.n_devices
        out["coll_seconds"] = d.collective_seconds
        out["local_mnk"] = list(d.local_shape_mnk)
    return out


def _decode(payload: dict) -> dec.Decision | None:
    try:
        algo = payload.get("algo")
        l = algorithms.get(algo) if algo is not None else None
        fp = payload.get("algo_fp")
        if l is not None and fp is not None and fp != l.fingerprint:
            # The scheme registered under this name today is NOT the
            # definition the cached decision priced — stale entry, drop it.
            return None
        kw = dict(
            M=int(payload["M"]), N=int(payload["N"]), K=int(payload["K"]),
            dtype=str(payload["dtype"]), algo=l,
            gemm_seconds=float(payload["gemm_seconds"]),
            lcma_seconds=(None if payload["lcma_seconds"] is None
                          else float(payload["lcma_seconds"])),
            estimates=(),
            precision=str(payload.get("prec", "fp")),
        )
        if "B" in payload:   # grouped entry (plan_batched)
            return dec.GroupedDecision(B=int(payload["B"]),
                                       shared_b=bool(payload.get("shared_b")),
                                       **kw)
        if "ly" in payload:  # sharded entry (plan_sharded)
            dec.layout_by_name(str(payload["ly"]))  # drop unknown layouts
            return dec.ShardedDecision(
                layout=str(payload["ly"]), n_devices=int(payload["D"]),
                collective_seconds=float(payload["coll_seconds"]),
                local_shape_mnk=tuple(int(x) for x in payload["local_mnk"]),
                **kw)
        return dec.Decision(**kw)
    except (KeyError, TypeError, ValueError):
        return None       # unknown scheme / malformed entry: drop, don't crash


class PlanCache:
    """Bounded LRU of ``Decision`` objects with optional JSON persistence."""

    def __init__(self, path: str | None = None,
                 capacity: int = DEFAULT_CAPACITY, autoload: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.path = path
        self.capacity = capacity
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict[str, dec.Decision] = \
            collections.OrderedDict()
        if path and autoload and os.path.exists(path):
            try:
                self.load(path)
            except (OSError, ValueError) as e:
                # A broken cache file must never take down the serving path;
                # start empty and let save() overwrite it.
                log.warning("plan cache %s unreadable (%s); starting empty",
                            path, e)

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: str) -> dec.Decision | None:
        with self._lock:
            d = self._entries.get(key)
            if d is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return d

    def insert(self, key: str, d: dec.Decision) -> None:
        with self._lock:
            if key not in self._entries and len(self._entries) >= self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            self._entries[key] = d
            self._entries.move_to_end(key)
            self.stats.inserts += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def keys(self) -> list[str]:
        """Snapshot of cached plan keys (LRU order, oldest first).

        Introspection surface for the training warm pass and tests: a key
        embeds its local shape as ``{M}x{K}x{N}``, so callers can verify that
        e.g. both backward shapes of a layer were pre-planned."""
        with self._lock:
            return list(self._entries)

    def has_shape(self, M: int, K: int, N: int) -> bool:
        """True if any cached plan was keyed on local shape (M, K, N)."""
        token = f"|{M}x{K}x{N}|"
        with self._lock:
            return any(token in k for k in self._entries)

    # -- persistence --------------------------------------------------------

    def save(self, path: str | None = None, merge: bool = True) -> str:
        """Persist the cache: file lock -> merge on-disk entries -> atomic rename.

        Safe against concurrent writers (threads with their own caches, or
        separate serving processes sharing one warmed file): the sidecar lock
        serializes the read-merge-write, ``merge=True`` folds in entries some
        other writer landed since we loaded (our in-memory decisions win on
        key conflicts — they are newest), and the per-writer temp file +
        ``os.replace`` keeps readers from ever seeing a torn file.
        """
        path = path or self.path
        if path is None:
            raise ValueError("PlanCache.save: no path configured")
        apath = os.path.abspath(path)
        os.makedirs(os.path.dirname(apath), exist_ok=True)
        with _file_lock(apath + ".lock"):
            merged: list[tuple[str, dict]] = []
            if merge and os.path.exists(apath):
                try:
                    with open(apath) as f:
                        doc = json.load(f)
                    if doc.get("version") == _FORMAT_VERSION:
                        with self._lock:
                            merged = [(k, p) for k, p in doc.get("entries", [])
                                      if k not in self._entries]
                except (OSError, ValueError) as e:
                    log.warning("plan cache %s unreadable during save (%s); "
                                "overwriting", apath, e)
            with self._lock:
                entries = merged + [[k, _encode(d)]
                                    for k, d in self._entries.items()]
            doc = {"version": _FORMAT_VERSION, "entries": entries}
            # unique temp per writer: two unlocked writers must not share one
            tmp = f"{apath}.tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, apath)
        return path

    def load(self, path: str | None = None) -> int:
        """Merge entries from ``path``; returns the number of plans loaded."""
        path = path or self.path
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != _FORMAT_VERSION:
            return 0
        n = 0
        with self._lock:
            for key, payload in doc.get("entries", []):
                d = _decode(payload)
                if d is None:
                    continue
                if key not in self._entries and len(self._entries) >= self.capacity:
                    self._entries.popitem(last=False)
                    self.stats.evictions += 1
                self._entries[key] = d
                n += 1
            self.stats.loaded += n
        return n


# ---------------------------------------------------------------------------
# Process-default cache (what falcon_gemm.plan() consults)
# ---------------------------------------------------------------------------

_default: PlanCache | None = None
_default_lock = threading.Lock()


def default_cache() -> PlanCache:
    global _default
    with _default_lock:
        if _default is None:
            _default = PlanCache(path=os.environ.get(ENV_PATH) or None)
        return _default


def configure(path: str | None = None,
              capacity: int = DEFAULT_CAPACITY, autoload: bool = True) -> PlanCache:
    """Replace the process-default cache (e.g. point it at a warmed file)."""
    global _default
    with _default_lock:
        _default = PlanCache(path=path, capacity=capacity, autoload=autoload)
        return _default


def stats() -> CacheStats:
    return default_cache().stats


def flush() -> str | None:
    """Persist the default cache if it has a backing path."""
    c = default_cache()
    return c.save() if c.path else None


def reset() -> None:
    """Drop the process-default cache entirely (tests)."""
    global _default
    with _default_lock:
        _default = None
