from . import algorithms, codegen, decision, discovery, hardware, lcma
from .falcon_gemm import FalconConfig, falcon_dense, falcon_matmul

__all__ = ["algorithms", "codegen", "decision", "discovery", "hardware", "lcma",
           "FalconConfig", "falcon_dense", "falcon_matmul"]
