from . import (algorithms, autotune, codegen, decision, discovery, hardware,
               lcma, plan_cache)
from .falcon_gemm import FalconConfig, falcon_dense, falcon_matmul

__all__ = ["algorithms", "autotune", "codegen", "decision", "discovery",
           "hardware", "lcma", "plan_cache",
           "FalconConfig", "falcon_dense", "falcon_matmul"]
