from . import (algorithms, autotune, backends, codegen, decision, discovery,
               engine, hardware, lcma, plan_cache)
from .backends import available_backends, get_backend, register_backend
from .engine import FalconEngine, PlannedWeight, plan_weight, use
from .falcon_gemm import FalconConfig, falcon_dense, falcon_matmul

__all__ = ["algorithms", "autotune", "backends", "codegen", "decision",
           "discovery", "engine", "hardware", "lcma", "plan_cache",
           "FalconConfig", "falcon_dense", "falcon_matmul",
           "FalconEngine", "PlannedWeight", "plan_weight", "use",
           "register_backend", "get_backend", "available_backends"]
