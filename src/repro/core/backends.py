"""Execution backend registry for FalconGEMM dispatch.

The Decision Module picks *what* to run (an LCMA scheme or standard GEMM);
a **backend** decides *how* the chosen LCMA executes. Historically that was a
string switch buried in ``falcon_gemm._lcma_apply``; this registry makes new
execution strategies (a Low-Rank GEMM approximation, a CUDA-L2-style tuned
kernel, a remote accelerator) pluggable without touching dispatch:

    from repro.core.backends import register_backend, Backend

    def my_apply(a2, b, lcma, cfg):          # 2-D (M,K) @ (K,N) LCMA matmul
        ...
    register_backend("mine", my_apply)
    falcon_matmul(a, b, FalconConfig(backend="mine"))

An ``impl`` may be a bare callable (the 2-D apply) or a :class:`Backend` with
an optional ``dense_hook`` that intercepts whole layer contractions before the
2-D core (how ``shard_map_local`` places LCMA on the per-device local matmul).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

__all__ = ["Backend", "register_backend", "get_backend", "unregister_backend",
           "available_backends"]

# apply(a2, b, lcma, cfg) -> C : execute the LCMA matmul on 2-D operands.
ApplyFn = Callable
# dense_hook(x, w, cfg) -> out | None : optionally claim a full (..., K) @
# (K, N) layer contraction; returning None falls through to the 2-D core.
DenseHookFn = Callable
# apply_precombined(a2, bt, lcma, n_logical, cfg) -> C : execute against an
# offline-combined B̃ (R, K/k, N/n) — the PlannedWeight serving path. None
# means "no native path"; dispatch falls back to the generated jnp combines.
ApplyPrecombinedFn = Callable
# apply_grouped(a3, b, lcma, cfg) -> C3 : execute a grouped batched LCMA —
# a3 (G, M, K) against b (K, N) (shared; Combine B hoisted once) or
# (G, K, N) (per-group). None falls back to the generated grouped lowering.
ApplyGroupedFn = Callable
# apply_grouped_precombined(a3, bt, lcma, n_logical, cfg) -> C3 : grouped
# serving path against precombined B̃ (R, K/k, N/n) or stacked
# (G, R, K/k, N/n) — the stacked-PlannedWeight / MoE-expert case.
ApplyGroupedPrecombinedFn = Callable
# apply_quant(a2, bq, b_scales, lcma, n_logical, cfg) -> C : int8 serving
# path against offline-quantized B̃q (R, K/k, N/n) int8 + f32 block scales
# (the quantized PlannedWeight tier). None means the backend has no int8
# path and the quantized tier is not servable on it.
ApplyQuantFn = Callable


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered execution strategy."""

    name: str
    apply: ApplyFn
    dense_hook: DenseHookFn | None = None
    apply_precombined: ApplyPrecombinedFn | None = None
    apply_grouped: ApplyGroupedFn | None = None
    apply_grouped_precombined: ApplyGroupedPrecombinedFn | None = None
    apply_quant: ApplyQuantFn | None = None
    description: str = ""


_REGISTRY: dict[str, Backend] = {}
_LOCK = threading.Lock()


def register_backend(name: str, impl, *, dense_hook: DenseHookFn | None = None,
                     apply_precombined: ApplyPrecombinedFn | None = None,
                     apply_grouped: ApplyGroupedFn | None = None,
                     apply_grouped_precombined: ApplyGroupedPrecombinedFn | None = None,
                     apply_quant: ApplyQuantFn | None = None,
                     description: str = "", overwrite: bool = False) -> Backend:
    """Register an execution backend under ``name``.

    ``impl`` is either a callable ``(a2, b, lcma, cfg) -> C`` or a ready-made
    :class:`Backend`. Re-registering an existing name requires
    ``overwrite=True`` (guards against accidental shadowing of built-ins).
    Backends without the optional grouped hooks still serve grouped batched
    dispatch — the engine falls back to the generated grouped lowering.
    """
    if isinstance(impl, Backend):
        be = dataclasses.replace(impl, name=name)
    elif callable(impl):
        be = Backend(name=name, apply=impl, dense_hook=dense_hook,
                     apply_precombined=apply_precombined,
                     apply_grouped=apply_grouped,
                     apply_grouped_precombined=apply_grouped_precombined,
                     apply_quant=apply_quant,
                     description=description)
    else:
        raise TypeError(f"register_backend: impl must be callable or Backend, "
                        f"got {type(impl).__name__}")
    with _LOCK:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _REGISTRY[name] = be
    return be


def unregister_backend(name: str) -> None:
    """Remove a backend (tests / plugin teardown). Unknown names are no-ops."""
    with _LOCK:
        _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    _ensure_builtins()
    be = _REGISTRY.get(name)
    if be is None:
        raise KeyError(f"unknown FalconGEMM backend {name!r}; registered: "
                       f"{sorted(_REGISTRY)} (add one with register_backend)")
    return be


def available_backends() -> list[str]:
    _ensure_builtins()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in backends. Registered lazily so importing the registry never drags
# in the kernel stack, and so user registrations can happen before jax init.
# ---------------------------------------------------------------------------

_builtins_ready = False


def _jnp_apply(a2, b, l, cfg):
    from . import codegen
    from .falcon_gemm import _pad2
    M, _ = a2.shape
    N = b.shape[1]
    gen = codegen.generate(l, codegen.CodegenOptions(fused=cfg.fused))
    c = gen.fn(_pad2(a2, l.m, l.k), _pad2(b, l.k, l.n))
    return c[:M, :N]


def _jnp_apply_precombined(a2, bt, l, n_logical, cfg):
    from .falcon_gemm import matmul_with_precombined
    return matmul_with_precombined(a2, bt, l, n_logical, cfg)


def _jnp_apply_grouped(a3, b, l, cfg):
    from .falcon_gemm import grouped_matmul_generated
    return grouped_matmul_generated(a3, b, l, cfg)


def _jnp_apply_grouped_precombined(a3, bt, l, n_logical, cfg):
    from .falcon_gemm import grouped_matmul_with_precombined
    return grouped_matmul_with_precombined(a3, bt, l, n_logical, cfg)


def _pallas_apply_factory(interpret: bool):
    def apply(a2, b, l, cfg):
        from repro.kernels import ops
        return ops.falcon_matmul_pallas(a2, b, l, interpret=interpret)
    return apply


def _pallas_precombined_factory(interpret: bool):
    def apply_precombined(a2, bt, l, n_logical, cfg):
        from repro.kernels import ops
        return ops.falcon_matmul_pallas_precombined(
            a2, bt, l, n_logical, interpret=interpret)
    return apply_precombined


def _pallas_grouped_factory(interpret: bool):
    def apply_grouped(a3, b, l, cfg):
        from repro.kernels import ops
        return ops.falcon_grouped_matmul_pallas(a3, b, l, interpret=interpret)
    return apply_grouped


def _pallas_grouped_precombined_factory(interpret: bool):
    def apply_grouped_precombined(a3, bt, l, n_logical, cfg):
        from repro.kernels import ops
        return ops.falcon_grouped_matmul_pallas_precombined(
            a3, bt, l, n_logical, interpret=interpret)
    return apply_grouped_precombined


def _pallas_quant_factory(interpret: bool):
    def apply_quant(a2, bq, b_scales, l, n_logical, cfg):
        from repro.kernels import ops
        return ops.falcon_matmul_pallas_quant(
            a2, bq, b_scales, l, n_logical, interpret=interpret)
    return apply_quant


def _shardmap_dense_hook(x, w, cfg):
    from .falcon_gemm import _falcon_dense_shardmap
    return _falcon_dense_shardmap(x, w, cfg)


def _ensure_builtins() -> None:
    global _builtins_ready
    if _builtins_ready:
        return
    with _LOCK:
        if _builtins_ready:
            return
        defaults = {
            "jnp": Backend(
                "jnp", _jnp_apply,
                apply_precombined=_jnp_apply_precombined,
                apply_grouped=_jnp_apply_grouped,
                apply_grouped_precombined=_jnp_apply_grouped_precombined,
                # the quant pipeline only exists as Pallas kernels; interpret
                # mode runs them on CPU, so the jnp backend stays servable
                # in --quant mode
                apply_quant=_pallas_quant_factory(True),
                description="generated pure-JAX combines (GSPMD-shardable)"),
            "pallas": Backend(
                "pallas", _pallas_apply_factory(False),
                apply_precombined=_pallas_precombined_factory(False),
                apply_grouped=_pallas_grouped_factory(False),
                apply_grouped_precombined=_pallas_grouped_precombined_factory(False),
                apply_quant=_pallas_quant_factory(False),
                description="on-TPU Pallas kernel pipeline"),
            "pallas_interpret": Backend(
                "pallas_interpret", _pallas_apply_factory(True),
                apply_precombined=_pallas_precombined_factory(True),
                apply_grouped=_pallas_grouped_factory(True),
                apply_grouped_precombined=_pallas_grouped_precombined_factory(True),
                apply_quant=_pallas_quant_factory(True),
                description="Pallas pipeline in interpret mode (CPU CI)"),
            "shard_map_local": Backend(
                "shard_map_local", _jnp_apply,
                dense_hook=_shardmap_dense_hook,
                apply_precombined=_jnp_apply_precombined,
                apply_grouped=_jnp_apply_grouped,
                apply_grouped_precombined=_jnp_apply_grouped_precombined,
                description="LCMA on the per-device local matmul inside "
                            "shard_map (fsdp_only)"),
        }
        for name, be in defaults.items():
            _REGISTRY.setdefault(name, be)
        _builtins_ready = True
