"""Deployment Module: automated code generation for LCMAs (paper §III-A).

A meta-programming engine emits Python/JAX source for a given scheme
``L = <m,k,n,R,U,V,W>``.  The coefficient tensors are baked into the emitted
source as literal ``+``/``-`` terms, so:

  * zero coefficients are pruned at generation time (constant folding),
  * no runtime memory traffic is spent on coefficients (the paper stores them
    in the I-cache; here they live in the traced program),
  * XLA sees a fully unrolled combine, which it fuses into elementwise ops.

Two workflow variants are generated:

  * ``fused=True``  — Algorithm 2 (Group-Parallel): grouped combines, ONE
    batched GEMM over the rank dimension, Combine-H applied to the
    high-precision accumulator before any downcast (paper §IV-F).
  * ``fused=False`` — Algorithm 1 (staged, the H_r-parallel baseline): four
    separate stages, R fragmented GEMMs, H materialized (optionally downcast,
    reproducing the AlphaTensor-style precision loss).

The emitted source is kept on the returned object (``.source``) — it is the
deployment artifact, inspectable and diffable. The Pallas backend wires the
same coefficients into on-chip kernels (see ``repro.kernels``).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .lcma import LCMA

__all__ = ["CodegenOptions", "GeneratedLCMA", "generate"]


@dataclasses.dataclass(frozen=True)
class CodegenOptions:
    fused: bool = True
    accum_dtype: str = "float32"     # GEMM accumulation / H precision
    downcast_h: bool = False         # unfused: materialize H in input dtype
    precombined_b: bool = False      # offline Combine B for static weights
    gemm_backend: str = "batched"    # "batched" (Alg.2) | "loop" (Alg.1 fragmentation)

    def cache_key(self, name: str) -> tuple:
        return (name, self.fused, self.accum_dtype, self.downcast_h,
                self.precombined_b, self.gemm_backend)


@dataclasses.dataclass(frozen=True)
class GeneratedLCMA:
    """A deployed LCMA: generated source + compiled callables."""

    lcma: LCMA
    options: CodegenOptions
    source: str
    fn: Callable            # (A, B) -> C           [or (A, Bt) if precombined_b]
    combine_a: Callable     # (A,)  -> At (R, M/m, K/k)
    combine_b: Callable     # (B,)  -> Bt (R, K/k, N/n)
    stages: dict            # name -> callable, for the step-wise benchmark


# --------------------------------------------------------------------------
# Source emission helpers
# --------------------------------------------------------------------------

def _lin_comb(terms: list[tuple[int, str]]) -> str:
    """Emit ``+x - 2 * y + z`` from [(coeff, name), ...] for integer coeffs.

    Magnitudes other than 1 (AlphaTensor standard-arithmetic listings,
    Smirnov-family schemes) are emitted as literal scalings so constant
    folding still applies; dropping them silently computed wrong results.
    """
    if not terms:
        return "0.0"
    out = []
    for idx, (c, name) in enumerate(terms):
        term = name if abs(c) == 1 else f"{abs(c)} * {name}"
        if idx == 0:
            out.append(term if c > 0 else f"-{term}")
        else:
            out.append(f"+ {term}" if c > 0 else f"- {term}")
    return " ".join(out)


def _emit_combine(coeff: np.ndarray, part: str, out: str, d1: int, d2: int) -> list[str]:
    """Emit the group-combine of ``part_{i}_{l}`` into ``out_r`` for all r."""
    lines = []
    R = coeff.shape[0]
    for r in range(R):
        terms = [
            (int(coeff[r, i, l]), f"{part}_{i}_{l}")
            for i in range(d1) for l in range(d2)
            if coeff[r, i, l] != 0
        ]
        lines.append(f"{out}_{r} = {_lin_comb(terms)}")
    return lines


def _emit_slices(var: str, part: str, d1: int, d2: int, s1: str, s2: str) -> list[str]:
    lines = []
    for i in range(d1):
        for l in range(d2):
            lines.append(
                f"{part}_{i}_{l} = jax.lax.slice({var}, "
                f"({i} * {s1}, {l} * {s2}), (({i} + 1) * {s1}, ({l} + 1) * {s2}))"
            )
    return lines


def _emit_source(l: LCMA, o: CodegenOptions) -> str:
    m, k, n, R = l.m, l.k, l.n, l.R
    U, V, W = l.U, l.V, l.W
    body: list[str] = []
    e = body.append

    e("def combine_a(A):")
    e("    M, K = A.shape")
    e(f"    Ms, Ks = M // {m}, K // {k}")
    for ln in _emit_slices("A", "a", m, k, "Ms", "Ks"):
        e("    " + ln)
    e("    # Group Combine A (Eq. 3) -- coefficients are compile-time constants")
    for ln in _emit_combine(U, "a", "at", m, k):
        e("    " + ln)
    e("    return jnp.stack([" + ", ".join(f"at_{r}" for r in range(R)) + "])")
    e("")

    e("def combine_b(B):")
    e("    K, N = B.shape")
    e(f"    Ks, Ns = K // {k}, N // {n}")
    for ln in _emit_slices("B", "b", k, n, "Ks", "Ns"):
        e("    " + ln)
    e("    # Group Combine B (Eq. 4)")
    for ln in _emit_combine(V, "b", "bt", k, n):
        e("    " + ln)
    e("    return jnp.stack([" + ", ".join(f"bt_{r}" for r in range(R)) + "])")
    e("")

    # --- GEMM stage ---
    e("def gemm_stage(At, Bt):")
    if o.gemm_backend == "batched":
        e("    # single batched GEMM over the rank dimension (Eq. 5)")
        e("    H = jax.lax.dot_general(At, Bt, dimension_numbers=(((2,), (1,)), ((0,), (0,))),")
        e(f"                            preferred_element_type=jnp.{o.accum_dtype})")
    else:
        e("    # H_r-parallel baseline: R fragmented GEMMs (paper §II-B drawback 2)")
        e("    hs = []")
        e(f"    for r in range({R}):")
        e(f"        hs.append(jax.lax.dot_general(At[r], Bt[r], dimension_numbers=((( 1,), (0,)), ((), ())),")
        e(f"                                      preferred_element_type=jnp.{o.accum_dtype}))")
        e("    H = jnp.stack(hs)")
    if o.downcast_h:
        e("    H = H.astype(At.dtype)  # AlphaTensor-style downcast before materialization")
    e("    return H")
    e("")

    e("def combine_h(H, out_dtype):")
    e("    # Group Combine H (Eq. 6); fused path keeps H in accum dtype on-chip")
    rows = []
    for i in range(m):
        cols = []
        for j in range(n):
            terms = [(int(W[r, i, j]), f"H[{r}]") for r in range(R) if W[r, i, j] != 0]
            e(f"    c_{i}_{j} = ({_lin_comb(terms)}).astype(out_dtype)")
            cols.append(f"c_{i}_{j}")
        rows.append("jnp.concatenate([" + ", ".join(cols) + "], axis=1)")
    e("    return jnp.concatenate([" + ", ".join(rows) + "], axis=0)")
    e("")

    args = "A, Bt" if o.precombined_b else "A, B"
    e(f"def lcma_matmul({args}):")
    e('    """%s %s | fused=%s precombined_b=%s"""' % (l.name, l.key, o.fused, o.precombined_b))
    e("    out_dtype = A.dtype")
    e("    At = combine_a(A)")
    if not o.precombined_b:
        e("    Bt = combine_b(B)")
    e("    H = gemm_stage(At, Bt)")
    e("    return combine_h(H, out_dtype)")
    return "\n".join(body) + "\n"


# --------------------------------------------------------------------------
# Compilation
# --------------------------------------------------------------------------

@lru_cache(maxsize=512)
def _generate_cached(l_id: int, key: tuple) -> GeneratedLCMA:  # pragma: no cover
    raise RuntimeError("use generate()")


_CACHE: dict[tuple, GeneratedLCMA] = {}


def generate(l: LCMA, options: CodegenOptions | None = None) -> GeneratedLCMA:
    """Generate + compile the LCMA implementation for scheme ``l``."""
    o = options or CodegenOptions()
    key = o.cache_key(l.name)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    src = _emit_source(l, o)
    ns: dict = {"jax": jax, "jnp": jnp}
    exec(compile(src, f"<lcma:{l.name}>", "exec"), ns)  # noqa: S102 - trusted, self-emitted
    gen = GeneratedLCMA(
        lcma=l,
        options=o,
        source=src,
        fn=ns["lcma_matmul"],
        combine_a=ns["combine_a"],
        combine_b=ns["combine_b"],
        stages={
            "combine_a": ns["combine_a"],
            "combine_b": ns["combine_b"],
            "gemm": ns["gemm_stage"],
            "combine_h": ns["combine_h"],
        },
    )
    _CACHE[key] = gen
    return gen
