"""Hardware abstraction for the Decision Module and roofline analysis.

The paper abstracts a platform as ``(FLOPS_x, FLOPS_+, beta)`` (§III-C):
  * ``FLOPS_x`` — matrix-multiply throughput (MXU / Tensor Core),
  * ``FLOPS_+`` — elementwise add/sub throughput (VPU / CUDA cores),
  * ``beta``    — off-chip (HBM) bandwidth for the target dtype.

We extend it with the interconnect and on-chip capacities needed for the
multi-pod roofline and the Pallas resource planner.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

__all__ = ["HardwareProfile", "TPU_V5E", "TPU_V5E_POD", "CPU_HOST", "get_profile",
           "calibrate_cpu", "register_profile", "profile_dir", "profile_path",
           "save_profile", "load_profile", "ENV_PROFILE_DIR"]


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops_mul: float            # FLOPS_x  (per chip, matmul units, bf16 unless noted)
    flops_add: float            # FLOPS_+  (per chip, vector units)
    beta: float                 # HBM bytes/s per chip
    link_bw: float = 50e9       # ICI bytes/s per link per chip
    hbm_bytes: int = 16 << 30
    vmem_bytes: int = 16 << 20  # conservative Pallas VMEM budget
    mxu_align: int = 128        # MXU systolic dimension
    dtype_flops: dict | None = None  # per-dtype FLOPS_x override
    # throughput of the R-batched LCMA GEMM relative to one big GEMM
    # (1.0 on TPU MXU; <1 through XLA-CPU's batched dot — calibrated)
    lcma_gemm_efficiency: float = 1.0
    # effective per-device collective (all-gather / reduce-scatter) bytes/s,
    # measured by the autotuner's --collectives probe; 0.0 => not measured,
    # fall back to the static per-link ICI number.
    collective_bw: float = 0.0

    def flops_for(self, dtype: str) -> float:
        if self.dtype_flops and dtype in self.dtype_flops:
            return self.dtype_flops[dtype]
        return self.flops_mul

    def coll_bw(self) -> float:
        """Collective bandwidth for the sharded decision model: the measured
        value when the --collectives probe ran, else the profiled link rate."""
        return self.collective_bw if self.collective_bw > 0 else self.link_bw

    @property
    def ridge_intensity(self) -> float:
        """FLOPS_x / beta — the roofline ridge point (FLOP per byte)."""
        return self.flops_mul / self.beta

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "HardwareProfile":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


# TPU v5e: 197 TFLOP/s bf16 MXU, 819 GB/s HBM, ~50 GB/s/link ICI (per prompt).
# FLOPS_+ : VPU — 8 ALUs x (8,128) lanes x ~0.94 GHz ~= 7.7 TFLOP/s f32; we use
# a conservative 4.9 TFLOP/s to absorb load/store issue overheads.
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    flops_mul=197e12,
    flops_add=4.9e12,
    beta=819e9,
    link_bw=50e9,
    hbm_bytes=16 << 30,
    vmem_bytes=16 << 20,
    dtype_flops={"bfloat16": 197e12, "float32": 49.25e12, "int8": 394e12},
)

# A full v5e pod slice as used by the dry-run mesh (per-chip numbers identical;
# kept as a distinct profile so collective constants can differ later).
TPU_V5E_POD = dataclasses.replace(TPU_V5E, name="tpu_v5e_pod")

# The container host (1 core) — used for *measured* CPU benchmarks, mirroring
# the paper's CPU (x86/ARM) evaluations. Rough defaults; ``calibrate_cpu``
# measures the real numbers at benchmark time.
CPU_HOST = HardwareProfile(
    name="cpu_host",
    flops_mul=6.0e10,
    flops_add=1.5e10,
    beta=2.0e10,
    link_bw=1e9,
    hbm_bytes=32 << 30,
    vmem_bytes=32 << 20,   # L2/L3 analogue
    mxu_align=8,
    dtype_flops=None,
)

_PROFILES = {p.name: p for p in (TPU_V5E, TPU_V5E_POD, CPU_HOST)}

# Calibrated profiles written by ``repro.tools.tune`` live here; set the env
# var to relocate (CI, multi-host). Looked up lazily by ``get_profile``.
ENV_PROFILE_DIR = "FALCON_PROFILE_DIR"


def profile_dir() -> str:
    return os.environ.get(ENV_PROFILE_DIR) or os.path.join(
        os.path.expanduser("~"), ".cache", "falcon_gemm", "profiles")


def profile_path(name: str) -> str:
    return os.path.join(profile_dir(), f"{name}.json")


def register_profile(p: HardwareProfile) -> HardwareProfile:
    """Make a profile resolvable by name (``FalconConfig.hardware``)."""
    _PROFILES[p.name] = p
    return p


def save_profile(p: HardwareProfile, path: str | None = None,
                 metadata: dict | None = None) -> str:
    """Write a profile (plus optional calibration metadata) as JSON."""
    path = path or profile_path(p.name)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    doc = p.to_dict()
    if metadata:
        doc["_metadata"] = metadata
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)
    return path


def load_profile(path: str, register: bool = True) -> HardwareProfile:
    with open(path) as f:
        doc = json.load(f)
    p = HardwareProfile.from_dict(doc)
    if register:
        register_profile(p)
    return p


def get_profile(name: str) -> HardwareProfile:
    """Resolve a profile by name: built-ins/registered first, then the
    on-disk calibrated-profile directory (autotune output)."""
    p = _PROFILES.get(name)
    if p is not None:
        return p
    path = profile_path(name)
    if os.path.exists(path):
        return load_profile(path)
    raise KeyError(f"unknown hardware profile {name!r} "
                   f"(no built-in and no {path})")


_CPU_CAL_CACHE: dict = {}


def calibrate_cpu(size: int = 1024, dtype="float32") -> HardwareProfile:
    """Measure the host's (FLOPS_x, FLOPS_+, beta) for honest CPU decisions.

    beta is measured from a REAL Group-Combine-A (Strassen) rather than a
    plain stream add: through XLA-CPU the combine's slice+add+stack pattern
    reaches only a fraction of stream bandwidth (~3.5 GB/s on this container
    vs ~10 GB/s stream), and an uncalibrated model mispredicts the LCMA
    cutoff — a refuted-hypothesis lesson recorded in EXPERIMENTS.md §Perf.
    """
    key = (size, str(dtype))
    if key in _CPU_CAL_CACHE:
        return _CPU_CAL_CACHE[key]
    import jax
    import jax.numpy as jnp
    from repro.core import algorithms as _alg, codegen as _cg

    a = jnp.ones((size, size), dtype)
    b = jnp.ones((size, size), dtype)
    mm = jax.jit(lambda x, y: x @ y)
    gen = _cg.generate(_alg.get("strassen"))
    comb = jax.jit(gen.combine_a)

    def best(f, *args, reps=3):
        f(*args).block_until_ready()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            f(*args).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_mm = best(mm, a, b)
    t_comb = best(comb, a)
    flops_mul = 2 * size**3 / t_mm
    # batched-GEMM efficiency: the LCMA GEMM stage is an R-batched matmul
    h = size // 2
    ab = jnp.ones((7, h, h), dtype)
    bb = jnp.ones((7, h, h), dtype)
    bmm = jax.jit(lambda x, y: jax.lax.dot_general(
        x, y, (((2,), (1,)), ((0,), (0,)))))
    t_bmm = best(bmm, ab, bb)
    batched_flops = 2 * 7 * h**3 / t_bmm
    eff = min(batched_flops / flops_mul, 1.0)
    itemsize = jnp.dtype(dtype).itemsize
    # Combine-A moves MK reads + R*(M/2)(K/2) writes at the EFFECTIVE rate.
    comb_bytes = (size * size + 7 * (size // 2) ** 2) * itemsize
    beta = comb_bytes / t_comb
    flops_add = beta / itemsize  # 1 add per element at effective bandwidth
    prof = dataclasses.replace(
        CPU_HOST, flops_mul=flops_mul, flops_add=flops_add, beta=beta,
        lcma_gemm_efficiency=eff, name="cpu_host_calibrated",
    )
    _CPU_CAL_CACHE[key] = prof
    _PROFILES[prof.name] = prof  # resolvable via FalconConfig.hardware
    return prof
