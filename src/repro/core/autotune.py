"""Empirical autotuning: calibrate the Decision Module against measured reality.

The paper's Decision Module (§III-C) prices candidates with an *analytical*
roofline ``(FLOPS_x, FLOPS_+, beta)`` model. Real machines miss those peaks by
workload-dependent factors (XLA-CPU reaches ~35% of stream bandwidth through a
combine's slice+add+stack pattern; batched small GEMMs run below one big GEMM).
This module measures the factors the model actually uses, on a small grid of
probe shapes, and emits a calibrated :class:`HardwareProfile` that
``decision.decide`` consumes in place of the static tables in ``hardware.py``:

  * ``flops_mul``  — effective matmul throughput, from timing the backend's
    GEMM (``jnp.dot``, or the Pallas ``matmul_pallas`` kernel);
  * ``beta``       — effective HBM/memory bandwidth, from timing a real Group
    Combine A (the memory-bound LCMA stage), not a synthetic stream;
  * ``flops_add``  — elementwise throughput at that effective bandwidth;
  * ``lcma_gemm_efficiency`` — the R-batched LCMA GEMM stage relative to one
    big GEMM (through ``dot_general`` or the fused Pallas kernel).

Each probe is timed best-of-``reps`` after warmup; fits take the median across
probe shapes so one noisy probe cannot skew the profile. The measurement
clock is injectable (``timer=``) so tests can calibrate deterministically.

``python -m repro.tools.tune`` is the CLI wrapper that writes the profile JSON
(plus per-scheme Pallas block plans from ``kernels.tuning``) and warms the
persistent plan cache.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Sequence

from . import algorithms, codegen
from . import decision as dec
from .hardware import HardwareProfile, get_profile, register_profile, save_profile
from .lcma import LCMA

__all__ = ["ProbeMeasurement", "CalibrationReport", "autotune", "calibrate",
           "default_probe_shapes", "best_of_timer"]

# Probe grids per backend: big enough to exercise the pipelines, small enough
# to finish in seconds. Interpret-mode Pallas executes Python per grid step,
# so its probes stay tiny.
_PROBE_SHAPES = {
    "jnp": [(256, 256, 256), (256, 512, 384), (512, 512, 512)],
    "pallas": [(256, 256, 256), (256, 512, 384), (512, 512, 512)],
    "pallas_interpret": [(32, 32, 32), (64, 32, 64)],
}


def default_probe_shapes(backend: str) -> list[tuple[int, int, int]]:
    return list(_PROBE_SHAPES.get(backend, _PROBE_SHAPES["jnp"]))


def best_of_timer(reps: int = 3, warmup: int = 1) -> Callable:
    """Wall-clock best-of timer for jitted JAX callables (the default)."""
    import jax

    def timer(fn, *args) -> float:
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    return timer


@dataclasses.dataclass(frozen=True)
class ProbeMeasurement:
    """One (M, K, N) probe: raw seconds + the per-probe derived quantities."""
    M: int
    K: int
    N: int
    dtype: str
    t_gemm: float                # backend GEMM on the full problem
    t_combine_a: float           # Group Combine A of the probe scheme
    t_batched: float             # R-batched LCMA GEMM stage
    t_pipeline: float | None     # full LCMA pipeline (validation; may be skipped)
    flops_mul_est: float
    beta_est: float
    eff_est: float
    # (G*R)-batched grouped GEMM stage vs one big GEMM — validates the
    # decision model's eff_B amortization law (``estimate_grouped``); None
    # when the grouped probe is skipped (group_size <= 1)
    eff_grouped_est: float | None = None
    group_size: int = 1
    # int8 probes (``quant=True``): raw int8 GEMM throughput on the full
    # problem (the FLOPS_int8 the quantized tier is priced with) and the
    # fused Combine-A+quantize pass (the quant-pass beta). None when the
    # quant probe was skipped.
    t_gemm_int8: float | None = None
    t_quant_combine: float | None = None
    flops_int8_est: float | None = None
    beta_quant_est: float | None = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CalibrationReport:
    base: str
    backend: str
    dtype: str
    scheme: str
    probes: list[ProbeMeasurement]
    profile: HardwareProfile
    # per-probe relative error of the calibrated model's predicted LCMA
    # pipeline time vs the measured pipeline (empty when validation skipped)
    model_rel_err: list[float]
    # measured (G*R)-batched grouped-stage efficiency (median over probes)
    # vs the eff_B amortization law the grouped decision model assumes —
    # None when the grouped probe was skipped
    eff_grouped: float | None = None
    eff_grouped_predicted: float | None = None
    # medians of the int8 probes (``quant=True``); flops_int8 is what lands
    # in the profile's dtype_flops["int8"], beta_quant rides in metadata
    flops_int8: float | None = None
    beta_quant: float | None = None

    @property
    def max_rel_err(self) -> float | None:
        return max(self.model_rel_err) if self.model_rel_err else None

    def metadata(self) -> dict:
        return {
            "base": self.base, "backend": self.backend, "dtype": self.dtype,
            "scheme": self.scheme,
            "probes": [p.as_dict() for p in self.probes],
            "model_rel_err": self.model_rel_err,
            "eff_grouped": self.eff_grouped,
            "eff_grouped_predicted": self.eff_grouped_predicted,
            "flops_int8": self.flops_int8,
            "beta_quant": self.beta_quant,
        }


def _combine_bytes(l: LCMA, Mp: int, Kp: int, itemsize: int) -> int:
    # Combine A moves M*K reads + R*(M/m)*(K/k) writes (Table II).
    return (Mp * Kp + l.R * (Mp // l.m) * (Kp // l.k)) * itemsize


def _measure_probe(M: int, K: int, N: int, l: LCMA, backend: str, dtype: str,
                   timer: Callable, validate: bool,
                   group_size: int = 1, quant: bool = False) -> ProbeMeasurement:
    import jax
    import jax.numpy as jnp

    jdt = jnp.dtype(dtype)
    itemsize = jdt.itemsize
    a = jnp.ones((M, K), jdt)
    b = jnp.ones((K, N), jdt)

    def pad(x, d0, d1):
        return jnp.pad(x, ((0, (-x.shape[0]) % d0), (0, (-x.shape[1]) % d1)))

    ap = pad(a, l.m, l.k)
    bp = pad(b, l.k, l.n)
    Mp, Kp = ap.shape
    Np = bp.shape[1]
    X, Ks, Z = Mp // l.m, Kp // l.k, Np // l.n
    interpret = backend == "pallas_interpret"

    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import ops
        from repro.kernels.group_combine import group_combine
        from repro.kernels.fused_gemm import fused_gemm_combine_h

        # jit every timed callable: the GEMM wrapper is already @jax.jit'd,
        # and timing the combines eagerly would charge them per-call trace
        # overhead the GEMM doesn't pay, biasing beta/efficiency low.
        comb = jax.jit(lambda x: group_combine(x, l.U, interpret=interpret))
        bat = jax.jit(lambda x, y: fused_gemm_combine_h(
            x, y, l.W, out_dtype=jdt, interpret=interpret))
        t_gemm = timer(lambda x, y: ops.matmul_pallas(x, y, interpret=interpret), a, b)
        t_comb = timer(comb, ap)
        at = group_combine(ap, l.U, interpret=interpret)
        bt = group_combine(bp, l.V, interpret=interpret)
        t_bat = timer(bat, at, bt)
        t_pipe = (timer(lambda x, y: ops.falcon_matmul_pallas(
            x, y, l, interpret=interpret), a, b) if validate else None)
    else:
        gen = codegen.generate(l)
        mm = jax.jit(lambda x, y: jnp.dot(x, y))
        comb = jax.jit(gen.combine_a)
        bmm = jax.jit(lambda x, y: jax.lax.dot_general(
            x, y, (((2,), (1,)), ((0,), (0,)))))
        full = jax.jit(gen.fn)
        t_gemm = timer(mm, a, b)
        t_comb = timer(comb, ap)
        at = jnp.ones((l.R, X, Ks), jdt)
        bt = jnp.ones((l.R, Ks, Z), jdt)
        t_bat = timer(bmm, at, bt)
        t_pipe = timer(full, ap, bp) if validate else None

    flops_mul = 2.0 * M * N * K / t_gemm
    beta = _combine_bytes(l, Mp, Kp, itemsize) / t_comb
    batched_flops = 2.0 * l.R * X * Ks * Z / t_bat
    eff = min(batched_flops / flops_mul, 1.0)
    eff_grouped = None
    if group_size > 1 and backend not in ("pallas", "pallas_interpret"):
        # Grouped stage: G groups of R products as ONE (G*R)-batched GEMM —
        # the Execution Module's group-parallel lowering. Measured relative
        # to the big GEMM it validates the eff_B amortization law used by
        # decision.estimate_grouped (jnp backend only: the Pallas grouped
        # kernel adds a grid dim, not a bigger dot_general).
        G = int(group_size)
        ag = jnp.ones((G * l.R, X, Ks), jdt)
        bg = jnp.ones((G * l.R, Ks, Z), jdt)
        gmm = jax.jit(lambda x, y: jax.lax.dot_general(
            x, y, (((2,), (1,)), ((0,), (0,)))))
        t_grp = timer(gmm, ag, bg)
        eff_grouped = min(2.0 * G * l.R * X * Ks * Z / t_grp / flops_mul, 1.0)
    t_g8 = t_qc = flops_int8 = beta_quant = None
    if quant:
        # FLOPS_int8: the raw int8 GEMM (int32 accumulation) on the full
        # problem — the per-dtype peak the quantized tier's GEMM stage is
        # priced with (``hw.flops_for("int8")``).
        a8 = jnp.ones((M, K), jnp.int8)
        b8 = jnp.ones((K, N), jnp.int8)
        mm8 = jax.jit(lambda x, y: jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32))
        t_g8 = timer(mm8, a8, b8)
        flops_int8 = 2.0 * M * N * K / t_g8
        # quant-pass beta: the fused Combine-A + blockwise-quantize kernel —
        # reads the fp operand, writes int8 Ã plus f32 block scales.
        from repro.kernels.quant_combine import group_combine_quant
        qi = interpret or backend == "jnp"
        qcomb = jax.jit(lambda x: group_combine_quant(x, l.U, interpret=qi))
        t_qc = timer(qcomb, ap)
        by = next(d for d in range(min(128, Ks), 0, -1) if Ks % d == 0)
        qbytes = Mp * Kp * itemsize + l.R * X * Ks + l.R * X * (Ks // by) * 4
        beta_quant = qbytes / t_qc
    return ProbeMeasurement(M, K, N, dtype, t_gemm, t_comb, t_bat, t_pipe,
                            flops_mul, beta, eff,
                            eff_grouped_est=eff_grouped,
                            group_size=int(group_size),
                            t_gemm_int8=t_g8, t_quant_combine=t_qc,
                            flops_int8_est=flops_int8,
                            beta_quant_est=beta_quant)


def measure_collective_bw(size_bytes: int = 8 << 20, reps: int = 3,
                          warmup: int = 1,
                          timer: Callable | None = None) -> float | None:
    """Measure effective per-device collective bandwidth (bytes/s).

    Times a ring all-gather and a reduce-scatter over every local device
    (simulated host devices included) under ``shard_map`` and reports the
    slower of the two as bytes-moved-per-device / seconds — the number the
    sharded decision model divides collective bytes by. Returns ``None`` on
    single-device hosts, where the profile's static ``link_bw`` remains the
    fallback.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    D = len(jax.devices())
    if D < 2:
        return None
    timer = timer or best_of_timer(reps=reps, warmup=warmup)
    mesh = compat.make_mesh((D,), ("coll",))
    n = max(size_bytes // 4 // D, 1)          # float32 elements per shard
    x = jnp.ones((D * n,), jnp.float32)

    def ag(xl):
        return jax.lax.all_gather(xl, "coll", tiled=True)

    def rs(xl):
        return jax.lax.psum_scatter(xl, "coll", tiled=True)

    with compat.set_mesh(mesh):
        f_ag = jax.jit(compat.shard_map(ag, in_specs=P("coll"),
                                        out_specs=P(None), check_vma=False))
        f_rs = jax.jit(compat.shard_map(rs, in_specs=P(None),
                                        out_specs=P("coll"), check_vma=False))
        t_ag = timer(f_ag, x)
        t_rs = timer(f_rs, x)
    moved = (D - 1) * n * 4                   # ring model: (D-1)/D of total
    return moved / max(t_ag, t_rs)


def autotune(base: str | HardwareProfile = "cpu_host", backend: str = "jnp",
             shapes: Sequence[tuple[int, int, int]] | None = None,
             dtype: str = "float32", scheme: str = "strassen",
             reps: int = 3, warmup: int = 1,
             timer: Callable | None = None, name: str | None = None,
             validate: bool = True, group_size: int = 4,
             collectives: bool = False,
             quant: bool = False) -> CalibrationReport:
    """Measure the backend on probe shapes and fit a calibrated profile.

    Returns a :class:`CalibrationReport`; ``report.profile`` is registered
    with ``hardware`` so ``FalconConfig(hardware=report.profile.name)`` and
    ``decide(..., hw=report.profile.name)`` resolve it immediately.

    ``quant=True`` additionally measures the int8 stage — the raw int8 GEMM
    throughput and the fused Combine-A+quantize pass — and persists the
    measured FLOPS_int8 as the profile's ``dtype_flops["int8"]``, so the
    quantized decision tier is priced against measured (not assumed) int8
    throughput. The profile fingerprint hashes ``dtype_flops``, so persisted
    plan caches from an unquantized calibration invalidate automatically.
    """
    base_prof = get_profile(base) if isinstance(base, str) else base
    if backend not in ("jnp", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown autotune backend {backend!r}")
    shapes = list(shapes) if shapes is not None else default_probe_shapes(backend)
    timer = timer or best_of_timer(reps=reps, warmup=warmup)
    l = algorithms.get(scheme)

    probes = [_measure_probe(M, K, N, l, backend, dtype, timer, validate,
                             group_size=group_size, quant=quant)
              for (M, K, N) in shapes]

    flops_mul = statistics.median(p.flops_mul_est for p in probes)
    beta = statistics.median(p.beta_est for p in probes)
    eff = statistics.median(p.eff_est for p in probes)
    flops_add = beta / dec._dtype_bytes(dtype)  # 1 add/elem at effective BW

    flops_int8 = beta_quant = None
    if quant:
        f8s = [p.flops_int8_est for p in probes if p.flops_int8_est]
        bqs = [p.beta_quant_est for p in probes if p.beta_quant_est]
        flops_int8 = statistics.median(f8s) if f8s else None
        beta_quant = statistics.median(bqs) if bqs else None

    coll_bw = base_prof.collective_bw
    if collectives:
        measured = measure_collective_bw(reps=reps, warmup=warmup, timer=timer)
        if measured is not None:
            coll_bw = measured

    prof = dataclasses.replace(
        base_prof,
        name=name or f"{base_prof.name}_autotuned",
        flops_mul=flops_mul,
        flops_add=flops_add,
        beta=beta,
        lcma_gemm_efficiency=eff,
        collective_bw=coll_bw,
        # calibration is per measured dtype; the only per-dtype override a
        # calibrated profile carries is the measured int8 peak (quant=True)
        dtype_flops={"int8": flops_int8} if flops_int8 else None,
    )
    register_profile(prof)

    rel_err = []
    for p in probes:
        if p.t_pipeline is None:
            continue
        pred = dec.lcma_time(l, p.M, p.N, p.K, prof, dtype=dtype)
        rel_err.append(abs(pred - p.t_pipeline) / p.t_pipeline)

    # Validate the grouped decision model against the grouped-stage probe:
    # eff_B = B*eff/(B*eff + 1 - eff) should track the measured (G*R)-batched
    # efficiency. A large gap means grouped decisions on this host deserve a
    # second look (the report records both; tune CLI prints them).
    eff_grouped = eff_grouped_pred = None
    grouped_meas = [p.eff_grouped_est for p in probes
                    if p.eff_grouped_est is not None]
    if grouped_meas:
        eff_grouped = statistics.median(grouped_meas)
        G = next(p.group_size for p in probes if p.eff_grouped_est is not None)
        eff_grouped_pred = G * eff / (G * eff + 1.0 - eff)
        if abs(eff_grouped - eff_grouped_pred) > 0.25:
            import logging
            logging.getLogger(__name__).warning(
                "autotune: grouped GEMM stage measured %.2f efficiency vs "
                "eff_B model prediction %.2f (G=%d, eff=%.2f) — grouped "
                "decisions may be mispriced on this backend",
                eff_grouped, eff_grouped_pred, G, eff)

    return CalibrationReport(base=base_prof.name, backend=backend, dtype=dtype,
                             scheme=scheme, probes=probes, profile=prof,
                             model_rel_err=rel_err, eff_grouped=eff_grouped,
                             eff_grouped_predicted=eff_grouped_pred,
                             flops_int8=flops_int8, beta_quant=beta_quant)


def calibrate(path: str | None = None, block_plan_shapes: bool = True,
              **kw) -> tuple[CalibrationReport, str]:
    """``autotune`` + persist the profile JSON (the one-call convenience).

    The saved metadata embeds the probe measurements and, when requested, the
    per-candidate Pallas block plans from ``kernels.tuning`` for a
    representative serving shape — so a deploy host can inspect exactly what
    the tuner saw.
    """
    report = autotune(**kw)
    meta = report.metadata()
    if block_plan_shapes:
        from repro.kernels import tuning
        M, K, N = 4096, 4096, 4096
        meta["block_plans"] = {
            l.name: tuning.block_plans(l, M, K, N, dtype=report.dtype)
            for l in algorithms.candidates(max_grid=3)
        }
    out = save_profile(report.profile, path, metadata=meta)
    return report, out
