"""FalconEngine: the unified dispatch surface for FalconGEMM.

This module is the paper's Deployment-Module promise made real at the API
level — *portable execution across hardware and input configurations*:

* **Context-scoped config** — ``with use(cfg): ...`` installs a
  :class:`~repro.core.falcon_gemm.FalconConfig` in a contextvar;
  ``current_config()`` resolves it anywhere below (layers no longer thread an
  ``fcfg`` argument). Explicit ``cfg=`` arguments remain as overrides.
* **General entry points** — :func:`dot_general` / :func:`einsum` normalize
  batched and transposed contractions down to the planned 2-D core, so
  attention/MoE/SSD contractions hit the Decision Module, not just plain
  dense layers.
* **Backends** — execution strategies resolve through the
  ``core.backends`` registry (``FalconConfig.backend`` is just a name).
* **First-class precombined weights** — :class:`PlannedWeight` carries a
  weight together with its chosen LCMA and offline-combined B̃ (paper §IV-C
  "offline Combine B"); ``dense``/``dot_general``/``matmul`` accept it
  transparently, and :func:`precombine_params` lifts a whole model pytree.
* **Planned autodiff** — the dispatch core carries a ``jax.custom_vjp``: the
  backward GEMMs (``dA = g Bᵀ``, ``dB = Aᵀ g``) run as independently planned
  falcon contractions instead of the autodiff transpose of the combine
  graph, PlannedWeights are trainable, and
  :func:`refresh_planned_params` keeps B̃ consistent across optimizer steps.

``repro.api`` re-exports this surface; ``import repro.api as falcon``.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import algorithms, backends, workloads
from .falcon_gemm import (FalconConfig, _lcma_apply, _lcma_apply_grouped,
                          _pad2, grouped_matmul_with_precombined,
                          matmul_with_precombined, plan, plan_batched,
                          plan_training, precombine_weights)
from .lcma import LCMA

__all__ = ["use", "current_config", "active_config", "maybe_use",
           "config_scope", "matmul", "dense", "dot_general", "einsum",
           "grouped_matmul", "PlannedWeight", "plan_weight",
           "precombine_params", "refresh_planned_params",
           "projection_shapes", "grouped_expert_shapes", "warm_buckets",
           "FalconEngine"]


# ---------------------------------------------------------------------------
# Context-scoped configuration
# ---------------------------------------------------------------------------

_CONFIG: contextvars.ContextVar[FalconConfig | None] = \
    contextvars.ContextVar("falcon_config", default=None)


@contextlib.contextmanager
def use(cfg: FalconConfig):
    """Install ``cfg`` as the ambient FalconGEMM config for this context.

    Nests: the innermost ``use`` wins; on exit the previous config is
    restored (also on exception). Config resolution is a trace-time concern,
    so wrapping a ``jax.jit`` *call site* is sufficient — the contextvar is
    read while the function traces.
    """
    token = _CONFIG.set(cfg)
    try:
        yield cfg
    finally:
        _CONFIG.reset(token)


def active_config() -> FalconConfig | None:
    """The config installed by the innermost ``use``, or None outside any."""
    return _CONFIG.get()


def current_config() -> FalconConfig:
    """The ambient config: innermost ``use``, else the default FalconConfig."""
    return _CONFIG.get() or FalconConfig()


def _resolve(cfg: FalconConfig | None) -> FalconConfig:
    return cfg if cfg is not None else current_config()


@contextlib.contextmanager
def maybe_use(cfg: FalconConfig | None):
    """``use(cfg)`` when cfg is not None; no-op otherwise (shim helper)."""
    if cfg is None:
        yield None
    else:
        with use(cfg) as c:
            yield c


def warn_deprecated_fcfg(where: str, stacklevel: int = 3) -> None:
    warnings.warn(
        f"{where}: passing a FalconConfig argument is deprecated; wrap "
        f"the call in `with falcon.use(cfg):` instead",
        DeprecationWarning, stacklevel=stacklevel)


def deprecated_fcfg(fcfg: FalconConfig | None, where: str):
    """Deprecation shim for the legacy per-call ``fcfg`` parameter.

    Returns a context manager that installs ``fcfg`` (warning at the call
    site) or does nothing when ``fcfg`` is None — so ported code paths are
    warning-free under ``-W error::DeprecationWarning``.
    """
    if fcfg is not None:
        warn_deprecated_fcfg(where, stacklevel=4)
    return maybe_use(fcfg)


@contextlib.contextmanager
def config_scope(fcfg: FalconConfig | None, where: str, default_factory):
    """Model-entry config resolution: deprecated override, ambient, default.

    The ordering is load-bearing: the deprecated ``fcfg`` (if any) is
    installed *before* ``active_config()`` is consulted, so an explicit
    legacy argument still overrides the ambient context; absent both, the
    config comes from ``default_factory()`` (e.g. the model's own
    ``falcon_config_for``).
    """
    with deprecated_fcfg(fcfg, where):
        with use(active_config() or default_factory()):
            yield


# ---------------------------------------------------------------------------
# Planned (precombined) weights
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PlannedWeight:
    """A weight bundled with its chosen LCMA and offline-combined B̃.

    ``dense`` / ``matmul`` / ``dot_general`` accept a PlannedWeight wherever
    a (K, N) weight matrix is expected. ``algo is None`` marks a weight the
    Decision Module left on standard GEMM. Registered as a pytree whose
    children are the arrays, so planned params flow through ``jax.jit``,
    ``lax.scan`` layer stacking, and checkpoint trees unchanged; the scheme
    name and logical shape ride in the static treedef.

    Stacked weights (leading layer/codebook dim) are supported: children are
    stacked alike, ``pw[i]`` slices both.

    When planned under ``cfg.quantize``, a 2-D weight additionally carries the
    offline-quantized B̃q (int8) and its f32 block scales
    (``kernels.quant_combine.quantize_b_blockwise``), so the serve path can
    route through the backend's int8 ``apply_quant`` pipeline whenever the
    Decision Module picks the quantized tier at the actual M.
    """

    w: Any                  # original weight (K, N) [or (L, K, N)]; None if dropped
    bt: Any                 # precombined B̃ (R, K/k, N/n) [or (L, ...)]; None if GEMM
    algo: str | None        # LCMA scheme name; None => standard GEMM
    k: int                  # logical K of the matrix (trailing dims)
    n: int                  # logical N
    bq: Any = None          # quantized B̃q int8 (R, K/k, N/n); None if fp-only
    b_scales: Any = None    # f32 block scales (R, (K/k)/by, N/n)

    @property
    def lcma(self) -> LCMA | None:
        return algorithms.get(self.algo) if self.algo is not None else None

    @property
    def precombined(self) -> bool:
        return self.bt is not None

    @property
    def quantized(self) -> bool:
        return self.bq is not None

    def __getitem__(self, idx) -> "PlannedWeight":
        return PlannedWeight(
            w=None if self.w is None else self.w[idx],
            bt=None if self.bt is None else self.bt[idx],
            algo=self.algo, k=self.k, n=self.n,
            bq=None if self.bq is None else self.bq[idx],
            b_scales=None if self.b_scales is None else self.b_scales[idx])

    def tree_flatten(self):
        return (self.w, self.bt, self.bq, self.b_scales), \
            (self.algo, self.k, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        w, bt, bq, b_scales = children
        algo, k, n = aux
        return cls(w=w, bt=bt, algo=algo, k=k, n=n, bq=bq, b_scales=b_scales)


def plan_weight(w: jnp.ndarray, cfg: FalconConfig | None = None,
                m_hint: int = 1024, keep_weight: bool = True,
                grouped: bool = False) -> PlannedWeight:
    """Plan a static weight for serving: pick an LCMA and precombine B̃.

    The Decision Module is consulted with ``precombined_b=True`` — the right
    profitability criterion for a weight whose Combine B runs offline — at an
    activation-rows hint ``m_hint`` (use the serving prefill M). The decision
    goes through the plan cache like every other ``plan()`` call. Weights of
    rank 3 are treated as stacked (leading layer/codebook dim) and combined
    per slice; the per-matrix shape is the trailing (K, N).

    ``grouped=True`` marks a rank-3 stack whose slices execute *together* as
    one grouped contraction (MoE experts via ``grouped_matmul``) rather than
    sequentially (scan-stacked layers): profitability is then judged by
    ``plan_batched`` on the grouped problem — ``m_hint`` still counts total
    activation rows, split evenly across the G slices — matching how
    ``_apply_planned_grouped`` will re-price it at serve time.

    ``keep_weight=False`` drops the raw weight (halves serving memory for the
    planned layers); the precombined path is then always taken.
    """
    cfg = _resolve(cfg)
    if w.ndim not in (2, 3):
        return PlannedWeight(w=w, bt=None, algo=None,
                             k=int(w.shape[-2]) if w.ndim >= 2 else 0,
                             n=int(w.shape[-1]))
    K, N = int(w.shape[-2]), int(w.shape[-1])
    if grouped and w.ndim == 3:
        G = int(w.shape[0])
        d = plan_batched(G, max(m_hint // G, 8), K, N, cfg, str(w.dtype),
                         precombined_b=True)
    else:
        d = plan(m_hint, K, N, cfg, str(w.dtype), precombined_b=True)
    if not d.use_lcma:
        return PlannedWeight(w=w, bt=None, algo=None, k=K, n=N)
    l = d.algo
    bt = precombine_weights(w, l) if w.ndim == 2 else \
        jax.vmap(lambda wi: precombine_weights(wi, l))(w)
    # Under cfg.quantize, also bake the int8 quant buffers — regardless of
    # which precision won at m_hint: the serve-time re-decision picks fp vs
    # int8 at the *actual* M, and both executions must be available from the
    # same PlannedWeight. Stacked (scan-layer) weights quantize per slice;
    # ``pw[i]`` slices the quant buffers alongside w/B̃.
    bq = b_scales = None
    if cfg.quantize \
            and backends.get_backend(cfg.backend).apply_quant is not None:
        interp = cfg.backend != "pallas"
        if w.ndim == 2:
            bq, b_scales = _quantize_weight(w, l, interpret=interp)
        else:
            per = [_quantize_weight(w[i], l, interpret=interp)
                   for i in range(w.shape[0])]
            bq = jnp.stack([q for q, _ in per])
            b_scales = jnp.stack([s for _, s in per])
    return PlannedWeight(w=w if keep_weight else None, bt=bt,
                         algo=l.name, k=K, n=N, bq=bq, b_scales=b_scales)


def _quantize_weight(w: jnp.ndarray, l: LCMA, by: int | None = None,
                     interpret: bool = True):
    """Offline Combine-B + blockwise int8 quantization of a 2-D weight.

    Returns ``(B̃q int8 (R, K/k, N/n), f32 scales (R, (K/k)/by, N/n))`` —
    the PlannedWeight quant buffers consumed by the backends' ``apply_quant``
    pipeline. ``by`` defaults to the largest divisor of the combined K
    (<= 128) so the fused int8 kernel's accumulator blocks divide exactly;
    128 << the int32 safe accumulation depth (analysis.stability).
    """
    from repro.kernels.quant_combine import quantize_b_blockwise
    wp = _pad2(w, l.k, l.n)
    Y = wp.shape[0] // l.k
    if by is None:
        by = next(d for d in range(min(128, Y), 0, -1) if Y % d == 0)
    return quantize_b_blockwise(wp, l.V, by=by, interpret=interpret)


_DEFAULT_PRECOMBINE_PATTERNS = (
    "w_q", "w_k", "w_v", "w_o", "mlp_gate", "mlp_up", "mlp_down",
    "lm_head", "ssm_in", "ssm_out",
    # MoE expert stacks lift to stacked PlannedWeights; the grouped dispatch
    # (engine.grouped_matmul) applies them per expert against stacked B̃.
    "moe_gate", "moe_up", "moe_down",
)

# Stacks matching these execute as ONE grouped contraction (not per-slice),
# so plan_weight judges them with the grouped decision (plan_batched).
_GROUPED_PRECOMBINE_PATTERNS = ("moe_gate", "moe_up", "moe_down")


def precombine_params(params, cfg: FalconConfig | None = None,
                      m_hint: int = 1024, keep_weight: bool = True,
                      patterns: tuple[str, ...] = _DEFAULT_PRECOMBINE_PATTERNS):
    """Lift a model param pytree into PlannedWeights for serving.

    Dense projection leaves whose path matches ``patterns`` are planned
    (and precombined where the Decision Module picks an LCMA); everything
    else — including leaves that are already ``PlannedWeight``s, so the
    lift is idempotent — passes through untouched.
    Returns (new_params, n_planned).
    """
    cfg = _resolve(cfg)
    n_planned = 0

    def maybe_plan(path, leaf):
        nonlocal n_planned
        if isinstance(leaf, PlannedWeight):
            return leaf
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if leaf.ndim not in (2, 3) or not any(pat in keys for pat in patterns):
            return leaf
        grouped = leaf.ndim == 3 and any(
            pat in keys for pat in _GROUPED_PRECOMBINE_PATTERNS)
        pw = plan_weight(leaf, cfg, m_hint=m_hint, keep_weight=keep_weight,
                         grouped=grouped)
        if pw.precombined:
            n_planned += 1
            return pw
        return leaf  # GEMM-bound weight: keep the raw array

    out = jax.tree_util.tree_map_with_path(
        maybe_plan, params, is_leaf=lambda x: isinstance(x, PlannedWeight))
    return out, n_planned


def _apply_planned(x: jnp.ndarray, pw: PlannedWeight,
                   cfg: FalconConfig) -> jnp.ndarray:
    """x (..., K) @ PlannedWeight -> (..., N); serving fast path."""
    *lead, K = x.shape
    if pw.algo is None:
        return jnp.matmul(x, pw.w)
    be = backends.get_backend(cfg.backend)
    if be.dense_hook is not None and pw.w is not None:
        # Layer-level placements (e.g. shard_map_local's per-device local
        # matmul) take precedence: running the precombined combines on a
        # GSPMD-sharded global array is exactly the resharding pathology
        # that hook exists to avoid.
        out = be.dense_hook(x, pw.w, cfg)
        if out is not None:
            return out
    x2 = x.reshape(-1, K)
    use_quant = False
    if cfg.mode == pw.algo or pw.w is None:
        use_pre = True           # forced scheme, or raw weight dropped
        use_quant = pw.quantized and cfg.quantize
    elif not cfg.enabled or cfg.mode == "gemm":
        use_pre = False
    else:
        # Re-decide for the *actual* M (decode M is tiny, prefill M is large)
        # with Combine B free; restrict candidates to the precombined scheme.
        # cfg.quantize rides through the replace, so the decision also picks
        # the precision tier — int8 routes to the baked quant buffers below.
        d = plan(x2.shape[0], K, pw.n,
                 dataclasses.replace(cfg, mode="auto", candidates=(pw.algo,)),
                 str(x.dtype), precombined_b=True)
        use_pre = d.use_lcma
        use_quant = pw.quantized and d.quantized
    if not use_pre:
        return jnp.matmul(x, pw.w)
    if use_quant and be.apply_quant is not None:
        if cfg.planned_vjp and pw.w is not None:
            out2 = _pw_quant_core(cfg, pw.algo, pw.n)(
                x2, pw.w, pw.bq, pw.b_scales)
        else:
            out2 = be.apply_quant(x2, pw.bq, pw.b_scales, pw.lcma, pw.n, cfg)
        return out2.reshape(*lead, pw.n)
    if cfg.planned_vjp:
        # Trainable precombined apply: the custom-VJP core routes the
        # gradient to the raw weight (planned dW = x2ᵀ g) when it is kept,
        # or to B̃ itself via the rotated rank-R scheme when it was dropped.
        if pw.w is not None:
            out2 = _pw_core(cfg, pw.algo, pw.n, True)(x2, pw.w, pw.bt)
        else:
            out2 = _pw_core(cfg, pw.algo, pw.n, False)(x2, pw.bt)
    elif be.apply_precombined is not None:
        out2 = be.apply_precombined(x2, pw.bt, pw.lcma, pw.n, cfg)
    else:  # backend has no native precombined path: generated jnp combines
        out2 = matmul_with_precombined(x2, pw.bt, pw.lcma, pw.n, cfg)
    return out2.reshape(*lead, pw.n)


# ---------------------------------------------------------------------------
# Bucket pre-planning (continuous-batching serve path)
# ---------------------------------------------------------------------------

def projection_shapes(arch) -> list[tuple[int, int]]:
    """Deprecated shim: the (K, N) dense-projection shapes of ``arch``.

    The workload registry (``core.workloads``) is the one source of an
    architecture's contraction inventory now; use
    ``workloads.dense_projection_shapes(arch)`` (or the full
    ``contraction_set``/``resolve_contractions``) instead.
    """
    warnings.warn(
        "falcon.projection_shapes is deprecated; use "
        "repro.core.workloads.dense_projection_shapes / contraction_set "
        "(the workload registry) instead", DeprecationWarning, stacklevel=2)
    return workloads.dense_projection_shapes(arch)


def grouped_expert_shapes(arch, m_tokens: int,
                          mesh_shape: dict | None = None,
                          ) -> list[tuple[int, int, int, int]]:
    """Deprecated shim: grouped (E, C, K, N) MoE contractions of ``arch``.

    Use ``workloads.grouped_moe_shapes(arch, m_tokens, mesh_shape)`` (the
    workload registry) instead.
    """
    warnings.warn(
        "falcon.grouped_expert_shapes is deprecated; use "
        "repro.core.workloads.grouped_moe_shapes (the workload registry) "
        "instead", DeprecationWarning, stacklevel=2)
    return workloads.grouped_moe_shapes(arch, m_tokens, mesh_shape)


def _warm_contraction(c, cfg: FalconConfig, dtype: str,
                      pre_algos: dict, pre_algos_grouped: dict) -> int:
    """Plan one resolved registry contraction (+ precombined variant)."""
    n = 0
    if c.group == 1:
        plan(c.m, c.k, c.n, cfg, dtype)
        n += 1
        if c.weight_static:
            d_pre = plan(c.m, c.k, c.n, cfg, dtype, precombined_b=True)
            if d_pre.use_lcma:
                pre_algos.setdefault((c.k, c.n), set()).add(d_pre.algo.name)
            n += 1
    else:
        plan_batched(c.group, c.m, c.k, c.n, cfg, dtype, shared_b=c.shared_b)
        n += 1
        if c.weight_static:
            d_pre = plan_batched(c.group, c.m, c.k, c.n, cfg, dtype,
                                 precombined_b=True, shared_b=c.shared_b)
            if d_pre.use_lcma:
                pre_algos_grouped.setdefault(
                    (c.group, c.k, c.n), set()).add(d_pre.algo.name)
            n += 1
    return n


def warm_buckets(cfg: FalconConfig | None, arch, buckets,
                 dtype: str | None = None, train: bool = False,
                 mesh_shape: dict | None = None,
                 kv_len: int | None = None,
                 spec_gamma: int | None = None) -> int:
    """Pre-plan the registry contraction set of ``arch`` at every bucket.

    The continuous-batching scheduler only ever launches bucket shapes, so
    running the Decision Module once per bucket x registry contraction —
    both the plain and the precombined-B profitability variants for
    static-weight contractions — means serve-time traces are pure plan-cache
    hits. Returns the number of ``plan()``/``plan_batched()`` calls issued.
    Every shape comes from ``core.workloads`` (the workload registry), the
    one source of an architecture's contraction inventory.

    ``buckets`` entries are either

      * ``int`` — a flat activation-row count (batch x padded-seq for
        prefill buckets, batch for decode buckets): warms the dense
        projections and grouped MoE expert shapes at that M (the batch/seq
        split being unknown, the activation-side attention/SSD groups are
        left to the engine's jit warm loop), or
      * ``(batch, seq)`` — a full call context: resolves the complete
        registry inventory including attention einsums and SSD scan/decode
        contractions (``seq == 1`` with ``kv_len`` set is treated as a
        decode step against a length-``kv_len`` cache).

    ``train=True`` additionally pre-plans both *backward* contractions of
    each forward one (``decision.backward_shapes`` / the grouped grad
    rules), so one warm pass at ``buckets=[(batch, seq)]`` makes a whole
    jitted train step — forward and planned custom-VJP backward — trace
    against a hot plan cache.

    ``mesh_shape`` warms the PER-SHARD grouped MoE shapes a multi-device
    engine dispatches (experts over "model", tokens over "data") instead of
    the global ones no device ever runs.

    ``spec_gamma`` (with ``kv_len``) additionally warms the speculative-
    decoding contexts for every decode batch bucket ``(b, 1)`` in
    ``buckets``: the ``(b, γ+1)`` verify forward (lm head on every row —
    ``spec_verify`` in the workload registry) and the ``(b, 2)`` draft
    catch-up forward, so a speculating engine's rounds are plan-cache hits
    too. The draft model shares these keys: a layer-sliced self-draft has
    identical per-layer contraction shapes.
    """
    cfg = _resolve(cfg)
    dtype = dtype or str(getattr(arch, "dtype", "bfloat16"))
    n = 0
    flat = sorted({int(b) for b in buckets if not isinstance(b, tuple)})
    pairs = sorted({(int(b), int(s)) for (b, s) in
                    (b for b in buckets if isinstance(b, tuple))})
    pre_algos: dict[tuple[int, int], set[str]] = {}
    pre_algos_grouped: dict[tuple[int, int, int], set[str]] = {}

    contractions: list = []
    for M in flat:
        # flat M = batch-of-1 token count: the dense/grouped-MoE inventory
        # (legacy bucket semantics; attention/SSD groups need a batch/seq
        # split, which (batch, seq) buckets provide)
        contractions += [
            c for c in workloads.resolve_contractions(
                arch, 1, M, train=train, mesh_shape=mesh_shape)
            if c.kind in ("dense", "grouped_moe")]
    for (b, s) in pairs:
        decode = kv_len is not None and s == 1
        contractions += workloads.resolve_contractions(
            arch, b, s, train=train, mesh_shape=mesh_shape,
            kv_len=kv_len, decode=decode)
        if spec_gamma and decode:
            # speculative rounds at decode batch b: the (b, γ+1) verify
            # forward and the (b, 2) draft catch-up forward
            contractions += workloads.resolve_contractions(
                arch, b, spec_gamma + 1, train=train, mesh_shape=mesh_shape,
                kv_len=kv_len, spec_verify=True)
            contractions += workloads.resolve_contractions(
                arch, b, 2, train=train, mesh_shape=mesh_shape, kv_len=kv_len)

    # static-weight contractions first, so a shape shared between a weight
    # contraction and an activation one keeps its precombined variant
    contractions.sort(key=lambda c: not c.weight_static)
    seen: set[str] = set()
    for c in contractions:
        tok = c.key_shape()
        if tok in seen:
            continue
        seen.add(tok)
        n += _warm_contraction(c, cfg, dtype, pre_algos, pre_algos_grouped)

    # The PlannedWeight apply path re-decides at the actual M with candidates
    # restricted to the weight's own scheme — a differently-keyed plan (the
    # candidate set is part of the key). Pre-plan those restricted variants
    # for every scheme any bucket's precombined decision picked, so the
    # serve-time re-decision is a cache hit too, at every bucket M.
    if cfg.mode == "auto":
        planned: set[str] = set()
        for c in contractions:
            tok = c.key_shape()
            if not c.weight_static or tok in planned:
                continue
            planned.add(tok)
            if c.group == 1:
                for a in sorted(pre_algos.get((c.k, c.n), ())):
                    plan(c.m, c.k, c.n,
                         dataclasses.replace(cfg, candidates=(a,)),
                         dtype, precombined_b=True)
                    n += 1
            else:
                for a in sorted(pre_algos_grouped.get(
                        (c.group, c.k, c.n), ())):
                    plan_batched(c.group, c.m, c.k, c.n,
                                 dataclasses.replace(cfg, candidates=(a,)),
                                 dtype, precombined_b=True, shared_b=c.shared_b)
                    n += 1
    return n


# ---------------------------------------------------------------------------
# Planned autodiff: the custom-VJP dispatch core
#
# ``jax.value_and_grad`` through the raw combine/R-GEMM/combine graph
# differentiates the *implementation*: the autodiff transpose of the combine
# pipeline is strictly worse than either a planned LCMA or a clean GEMM, and
# the two backward GEMMs (dA = g Bᵀ, dW = Aᵀ g — two-thirds of training
# FLOPs) never meet the Decision Module. The custom VJP below differentiates
# the *contraction*: forward runs the planned dispatch, backward computes dA
# and dB as two independently planned falcon contractions — each backward
# shape runs through plan(), the plan cache and the backend registry exactly
# like a forward call. Side effect: every backend becomes trainable (the
# Pallas kernel pipeline has no autodiff transpose of its own).
# ---------------------------------------------------------------------------

def _dispatch2d(a2: jnp.ndarray, b2: jnp.ndarray,
                cfg: FalconConfig) -> jnp.ndarray:
    """Forward-only planned 2-D contraction: plan(), then LCMA or GEMM."""
    M, K = a2.shape
    N = b2.shape[1]
    d = plan(M, K, N, cfg, str(a2.dtype))
    if d.use_lcma:
        return _lcma_apply(a2, b2, d.algo, cfg)
    return jnp.matmul(a2, b2)


@functools.lru_cache(maxsize=None)
def _planned_core(cfg: FalconConfig):
    """The custom-VJP planned matmul core for ``cfg`` (2-D operands).

    Cached per (frozen, hashable) config so repeated traces reuse one
    ``custom_vjp`` instance — jit caches then key on a stable callable.
    Serves the *unbatched* contractions only: batched ``dot_general``
    lowers through :func:`_grouped_core` (one grouped ``plan_batched``
    decision for the whole group), not a ``vmap`` of this core.
    """

    @jax.custom_vjp
    def core(a2, b2):
        return _dispatch2d(a2, b2, cfg)

    def fwd(a2, b2):
        # This rule only runs under differentiation, so backward-shape
        # pricing happens exactly when a backward pass will exist — a
        # pure-inference trace never pays it (and never pollutes a warmed
        # serving plan cache with dA/dB entries).
        plan_training(a2.shape[0], a2.shape[1], b2.shape[1], cfg,
                      str(a2.dtype))
        return _dispatch2d(a2, b2, cfg), (a2, b2)

    def bwd(res, g):
        a2, b2 = res
        # dA: (M, N) @ (N, K) and dB: (K, M) @ (M, N) — both re-enter the
        # planned dispatch; their shapes were pre-priced by plan_training at
        # trace time, so these plan() calls are cache hits.
        da = _dispatch2d(g, b2.T, cfg).astype(a2.dtype)
        db = _dispatch2d(a2.T, g, cfg).astype(b2.dtype)
        return da, db

    core.defvjp(fwd, bwd)
    return core


def _route_planned(M: int, K: int, N: int, cfg: FalconConfig, dtype: str):
    """Routing decision for one contraction: (use_custom_vjp_core, d_fwd).

    The core is engaged when the forward picks an LCMA; backward shapes are
    priced lazily, inside the custom VJP's fwd rule, which jax invokes only
    under differentiation — a pure-inference trace (the serve engine's
    warmed hot path) never prices dA/dB and keeps its zero-cold-miss
    guarantee. When the forward is plain GEMM the caller keeps its
    bitwise-identical jnp/lax lowering, whose autodiff transpose is plain
    GEMM anyway — and forward-mode jvp keeps working there.
    """
    d = plan(M, K, N, cfg, dtype)
    return (cfg.planned_vjp and d.use_lcma), d


# ---------------------------------------------------------------------------
# Grouped batched dispatch (paper §III-B Group-Parallel Optimizations)
#
# A grouped contraction — B independent (M, K) @ (K, N) products — used to
# lower as ``jax.vmap`` over the independently-combined 2-D core: the
# Decision Module priced ONE group element (so small-M groups like MoE
# expert blocks always declined), and nothing was hoisted. The grouped core
# below plans the whole group at once (``plan_batched``, one plan-cache key
# per grouped shape), hoists Combine B when the B operand is shared across
# the group, and executes the R*B intermediate products as a single grouped
# GEMM through the backend's ``apply_grouped`` path.
# ---------------------------------------------------------------------------

def _dispatch_grouped(a3: jnp.ndarray, b: jnp.ndarray,
                      cfg: FalconConfig) -> jnp.ndarray:
    """Forward-only planned grouped contraction: plan_batched, LCMA or GEMM."""
    G, M, K = a3.shape
    d = plan_batched(G, M, K, b.shape[-1], cfg, str(a3.dtype),
                     shared_b=b.ndim == 2)
    if d.use_lcma:
        return _lcma_apply_grouped(a3, b, d.algo, cfg)
    return jnp.matmul(a3, b)     # broadcasts the shared-b case


@functools.lru_cache(maxsize=None)
def _grouped_core(cfg: FalconConfig, shared_b: bool):
    """The custom-VJP grouped matmul core for ``cfg``.

    Operands: a3 (G, M, K) and b (K, N) when ``shared_b`` else (G, K, N).
    Backward mirrors the 2-D core: both gradients are independently planned
    falcon contractions — grouped ones, except the shared-weight cotangent
    ``dB = Σ_g a3[g]ᵀ g[g]``, which is exactly the flattened 2-D problem
    ``(K, G·M) @ (G·M, N)`` and is planned as such.
    """

    @jax.custom_vjp
    def core(a3, b):
        return _dispatch_grouped(a3, b, cfg)

    def fwd(a3, b):
        # Runs only under differentiation: price the grouped backward shapes
        # here so inference traces (serve) never pay for or cache them.
        G, M, K = a3.shape
        N = b.shape[-1]
        dtype = str(a3.dtype)
        plan_batched(G, M, N, K, cfg, dtype, shared_b=shared_b)      # dA
        if shared_b:
            plan(K, G * M, N, cfg, dtype)                            # dB (2-D)
        else:
            plan_batched(G, K, M, N, cfg, dtype)                     # dB
        return _dispatch_grouped(a3, b, cfg), (a3, b)

    def bwd(res, g3):
        a3, b = res
        if shared_b:
            da = _dispatch_grouped(g3, b.T, cfg).astype(a3.dtype)
            G, M, K = a3.shape
            db = _dispatch2d(a3.reshape(G * M, K).T,
                             g3.reshape(G * M, b.shape[-1]),
                             cfg).astype(b.dtype)
        else:
            da = _dispatch_grouped(g3, jnp.swapaxes(b, 1, 2),
                                   cfg).astype(a3.dtype)
            db = _dispatch_grouped(jnp.swapaxes(a3, 1, 2), g3,
                                   cfg).astype(b.dtype)
        return da, db

    core.defvjp(fwd, bwd)
    return core


def _route_grouped(G: int, M: int, K: int, N: int, cfg: FalconConfig,
                   dtype: str, shared_b: bool):
    """Routing decision for a grouped contraction: (use_custom_vjp_core, d)."""
    d = plan_batched(G, M, K, N, cfg, dtype, shared_b=shared_b)
    return (cfg.planned_vjp and d.use_lcma), d


def _pw_grouped_primal(a3: jnp.ndarray, bt: jnp.ndarray, l: LCMA,
                       n_logical: int, cfg: FalconConfig) -> jnp.ndarray:
    """The grouped precombined-B̃ apply (backend native path or generated)."""
    be = backends.get_backend(cfg.backend)
    if be.apply_grouped_precombined is not None:
        return be.apply_grouped_precombined(a3, bt, l, n_logical, cfg)
    return grouped_matmul_with_precombined(a3, bt, l, n_logical, cfg)


@functools.lru_cache(maxsize=None)
def _pw_grouped_core(cfg: FalconConfig, algo: str, n_logical: int,
                     stacked: bool, trainable: bool):
    """custom-VJP core for a grouped PlannedWeight apply.

    ``trainable=True`` (raw weight kept) — the grouped analogue of the
    trainable branch of :func:`_pw_core`: the primal reads only B̃ (the
    serving fast path), the backward routes the cotangent to the RAW weight
    — ``dw`` as a planned contraction (grouped per expert for a stacked
    weight; the flattened 2-D problem for a shared one, since
    ``dw = Σ_g a3[g]ᵀ g[g]``) — plus a planned grouped ``dx``. The B̃ leaf
    gets a zero cotangent; :func:`refresh_planned_params` re-derives B̃ from
    the updated weight. Without this, training a model with precombined
    (stacked PlannedWeight) experts would silently produce zero gradients
    for the expert weights: the primal never touches ``w``, and the B̃
    cotangent is discarded by the refresh.

    ``trainable=False`` (``keep_weight=False``): B̃ *is* the parameter; both
    cotangents come from the rotated rank-R scheme (:func:`_pw_bwd_rotated`,
    exact — the output is linear in B̃) applied per group element, summed
    over the group for a shared B̃. This also keeps the dropped-weight
    regime trainable on the Pallas backends, whose precombined kernels have
    no autodiff rule of their own.
    """
    l = algorithms.get(algo)

    if trainable:
        @jax.custom_vjp
        def core(a3, w, bt):
            return _pw_grouped_primal(a3, bt, l, n_logical, cfg)

        def fwd(a3, w, bt):
            # runs only under differentiation: price the backward shapes
            # here so inference traces never pay for (or cache) dA/dB plans
            G, M, K = a3.shape
            dtype = str(a3.dtype)
            plan_batched(G, M, n_logical, K, cfg, dtype,
                         shared_b=not stacked)
            if stacked:
                plan_batched(G, K, M, n_logical, cfg, dtype)
            else:
                plan(K, G * M, n_logical, cfg, dtype)
            return _pw_grouped_primal(a3, bt, l, n_logical, cfg), (a3, w, bt)

        def bwd(res, g3):
            a3, w, bt = res
            G, M, K = a3.shape
            if stacked:
                dx = _dispatch_grouped(g3, jnp.swapaxes(w, 1, 2),
                                       cfg).astype(a3.dtype)
                dw = _dispatch_grouped(jnp.swapaxes(a3, 1, 2), g3,
                                       cfg).astype(w.dtype)
            else:
                dx = _dispatch_grouped(g3, w.T, cfg).astype(a3.dtype)
                dw = _dispatch2d(a3.reshape(G * M, K).T,
                                 g3.reshape(G * M, n_logical),
                                 cfg).astype(w.dtype)
            return dx, dw, jnp.zeros_like(bt)

        core.defvjp(fwd, bwd)
        return core

    @jax.custom_vjp
    def core_bt(a3, bt):
        return _pw_grouped_primal(a3, bt, l, n_logical, cfg)

    def fwd_bt(a3, bt):
        return _pw_grouped_primal(a3, bt, l, n_logical, cfg), (a3, bt)

    def bwd_bt(res, g3):
        a3, bt = res
        if stacked:
            dx, dbt = jax.vmap(
                lambda x2, b2, g2: _pw_bwd_rotated(x2, b2, g2, l, cfg))(
                a3, bt, g3)
        else:
            dx, dbt_g = jax.vmap(
                lambda x2, g2: _pw_bwd_rotated(x2, bt, g2, l, cfg))(a3, g3)
            dbt = jnp.sum(dbt_g, axis=0).astype(bt.dtype)
        return dx, dbt

    core_bt.defvjp(fwd_bt, bwd_bt)
    return core_bt


def _apply_planned_grouped(a3: jnp.ndarray, pw: PlannedWeight,
                           cfg: FalconConfig) -> jnp.ndarray:
    """Grouped apply against a PlannedWeight: a3 (G, M, K) -> (G, M, N).

    A 2-D PlannedWeight is the hoisted case — its offline B̃ is shared by the
    whole group. A stacked PlannedWeight (``w (G, K, N)``, MoE experts) is
    applied per group element against its stacked B̃ (G, R, K/k, N/n), still
    as ONE grouped contraction. The Decision Module re-prices the *grouped*
    problem (``precombined_b=True``) at the actual (G, M), restricted to the
    precombined scheme. Trainable under ``cfg.planned_vjp`` via
    :func:`_pw_grouped_core`: with the raw weight kept, gradients route to
    it as planned contractions; with ``keep_weight=False`` B̃ *is* the
    parameter and the rotated rank-R scheme supplies exact cotangents (also
    what keeps the Pallas backends trainable here — their precombined
    kernels have no autodiff rule).
    """
    G, M, K = a3.shape
    if pw.algo is None:
        return jnp.matmul(a3, pw.w)
    stacked = (pw.bt.ndim == 4) if pw.precombined else \
        (pw.w is not None and pw.w.ndim == 3)
    if cfg.mode == pw.algo or pw.w is None:
        use_pre = True
    elif not cfg.enabled or cfg.mode == "gemm":
        use_pre = False
    else:
        d = plan_batched(G, M, K, pw.n,
                         dataclasses.replace(cfg, mode="auto",
                                             candidates=(pw.algo,)),
                         str(a3.dtype), precombined_b=True,
                         shared_b=not stacked)
        use_pre = d.use_lcma
    if not use_pre:
        return jnp.matmul(a3, pw.w)
    if cfg.planned_vjp:
        if pw.w is not None:
            return _pw_grouped_core(cfg, pw.algo, pw.n, stacked,
                                    True)(a3, pw.w, pw.bt)
        return _pw_grouped_core(cfg, pw.algo, pw.n, stacked,
                                False)(a3, pw.bt)
    return _pw_grouped_primal(a3, pw.bt, pw.lcma, pw.n, cfg)


def grouped_matmul(a: jnp.ndarray, b, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Grouped batched matmul: ``out[g] = a[g] @ b[g]`` as one planned unit.

    ``a``: (G, M, K). ``b``: (K, N) — one operand shared (broadcast) across
    the group, Combine B hoisted and run once — or (G, K, N) per-group
    operands (MoE experts, batched attention), or a :class:`PlannedWeight`
    (2-D or stacked; offline Combine B). The Decision Module prices the
    whole group via ``plan_batched`` (one grouped plan-cache key, not G) and
    the chosen backend executes the R*G intermediate products as a single
    grouped GEMM. Differentiable: under ``cfg.planned_vjp`` gradients are
    independently planned grouped contractions.
    """
    cfg = _resolve(cfg)
    if isinstance(b, PlannedWeight):
        if a.ndim != 3:
            raise ValueError(f"grouped_matmul: a must be (G, M, K), "
                             f"got {tuple(a.shape)}")
        return _apply_planned_grouped(a, b, cfg)
    if a.ndim != 3 or b.ndim not in (2, 3):
        raise ValueError(f"grouped_matmul: expected a (G, M, K) and b "
                         f"(K, N) | (G, K, N); got {tuple(a.shape)} @ "
                         f"{tuple(b.shape)}")
    G, M, K = a.shape
    shared = b.ndim == 2
    if b.shape[-2] != K or (not shared and b.shape[0] != G):
        raise ValueError(f"grouped_matmul: shapes do not conform: "
                         f"{tuple(a.shape)} @ {tuple(b.shape)}")
    use_core, d = _route_grouped(G, M, K, b.shape[-1], cfg, str(a.dtype),
                                 shared_b=shared)
    if use_core:
        return _grouped_core(cfg, shared)(a, b)
    if not d.use_lcma:
        return jnp.matmul(a, b)
    return _lcma_apply_grouped(a, b, d.algo, cfg)


# -- trainable PlannedWeight -------------------------------------------------

def _pw_primal(x2: jnp.ndarray, bt: jnp.ndarray, l: LCMA, n_logical: int,
               cfg: FalconConfig) -> jnp.ndarray:
    """The precombined-B̃ serving apply (backend native path or generated)."""
    be = backends.get_backend(cfg.backend)
    if be.apply_precombined is not None:
        return be.apply_precombined(x2, bt, l, n_logical, cfg)
    return matmul_with_precombined(x2, bt, l, n_logical, cfg)


def _pw_bwd_rotated(x2, bt, g, l: LCMA, cfg: FalconConfig):
    """Exact LCMA-structured backward against B̃ alone (raw weight dropped).

    With H_r = Ãt_r B̃t_r and C[i,j] = Σ_r W[r,i,j] H_r, the cotangents are

        G̃t_r  = Σ_ij W[r,i,j] G[i,j]            (Combine with W coefficients)
        dX[i,l] = Σ_r U[r,i,l] (G̃t_r B̃t_rᵀ)     (R batched GEMMs, Combine U)
        dB̃t_r  = Ãt_rᵀ G̃t_r                     (R batched GEMMs)

    — the rank-R scheme rotated onto the gradient, reusing the stored B̃.
    This is exact (the LCMA identity, not an approximation), so training
    directly on B̃ is sound: the output is linear in B̃.
    """
    Mrows, K = x2.shape
    Ks, Ns = int(bt.shape[1]), int(bt.shape[2])
    xp = _pad2(x2, l.m, l.k)
    Ms = xp.shape[0] // l.m
    gp = _pad2(g, l.m, 1)
    if gp.shape[1] != l.n * Ns:
        gp = jnp.pad(gp, ((0, 0), (0, l.n * Ns - gp.shape[1])))
    U = jnp.asarray(l.U, xp.dtype)
    W = jnp.asarray(l.W, gp.dtype)
    G4 = gp.reshape(l.m, Ms, l.n, Ns)
    Gt = jnp.einsum("rij,ixjz->rxz", W, G4)                    # (R, Ms, Ns)
    At = jnp.einsum("ril,ixly->rxy", U,
                    xp.reshape(l.m, Ms, l.k, Ks))              # (R, Ms, Ks)
    Hb = jnp.einsum("rxz,ryz->rxy", Gt, bt.astype(Gt.dtype))   # G̃t_r B̃t_rᵀ
    dx = jnp.einsum("ril,rxy->ixly", U.astype(Hb.dtype), Hb) \
        .reshape(l.m * Ms, l.k * Ks)[:Mrows, :K].astype(x2.dtype)
    dbt = jnp.einsum("rxy,rxz->ryz", At, Gt).astype(bt.dtype)
    return dx, dbt


@functools.lru_cache(maxsize=None)
def _pw_core(cfg: FalconConfig, algo: str, n_logical: int, trainable: bool):
    """custom-VJP core for a PlannedWeight's precombined apply.

    ``trainable=True`` (raw weight kept): the primal consumes ``(x2, w, bt)``
    — the fast serving path still reads only B̃, but the backward returns the
    raw-weight cotangent ``dW = x2ᵀ g`` as an independently planned falcon
    contraction (the Combine-B map is linear, so the B̃ cotangent transposes
    back to exactly this), plus ``dx = g Wᵀ`` planned likewise. The B̃ leaf
    gets a zero cotangent; the optimizer trains ``w`` and
    :func:`refresh_planned_params` re-derives B̃ after each update.

    ``trainable=False`` (``keep_weight=False``): B̃ *is* the parameter; both
    cotangents come from the rotated rank-R scheme (exact), so B̃ can be
    trained directly.
    """
    l = algorithms.get(algo)

    if trainable:
        @jax.custom_vjp
        def core(x2, w, bt):
            return _pw_primal(x2, bt, l, n_logical, cfg)

        def fwd(x2, w, bt):
            # runs only under differentiation: price the backward triple
            # here so inference traces never pay for (or cache) dA/dB plans
            plan_training(x2.shape[0], x2.shape[1], n_logical, cfg,
                          str(x2.dtype))
            return _pw_primal(x2, bt, l, n_logical, cfg), (x2, w, bt)

        def bwd(res, g):
            x2, w, bt = res
            dx = _dispatch2d(g, w.T, cfg).astype(x2.dtype)
            dw = _dispatch2d(x2.T, g, cfg).astype(w.dtype)
            return dx, dw, jnp.zeros_like(bt)

        core.defvjp(fwd, bwd)
        return core

    @jax.custom_vjp
    def core_bt(x2, bt):
        return _pw_primal(x2, bt, l, n_logical, cfg)

    def fwd_bt(x2, bt):
        return _pw_primal(x2, bt, l, n_logical, cfg), (x2, bt)

    def bwd_bt(res, g):
        x2, bt = res
        return _pw_bwd_rotated(x2, bt, g, l, cfg)

    core_bt.defvjp(fwd_bt, bwd_bt)
    return core_bt


@functools.lru_cache(maxsize=None)
def _pw_quant_core(cfg: FalconConfig, algo: str, n_logical: int):
    """custom-VJP core for a quantized PlannedWeight apply.

    The primal runs the backend's int8 pipeline against the offline-baked
    B̃q + block scales (the quantized serving fast path). The backward stays
    fp: ``dx`` and ``dw`` are independently planned falcon contractions
    against the RAW weight — quantization error never enters the gradient —
    and the quant buffers get symbolic-zero cotangents (B̃q is int8, whose
    tangent type is float0); :func:`refresh_planned_params` re-derives them
    after each optimizer update, exactly like B̃.
    """
    l = algorithms.get(algo)

    def primal(x2, bq, b_scales):
        be = backends.get_backend(cfg.backend)
        return be.apply_quant(x2, bq, b_scales, l, n_logical, cfg)

    @jax.custom_vjp
    def core(x2, w, bq, b_scales):
        return primal(x2, bq, b_scales)

    def fwd(x2, w, bq, b_scales):
        # runs only under differentiation: price the backward triple here so
        # inference traces never pay for (or cache) dA/dB plans
        plan_training(x2.shape[0], x2.shape[1], n_logical, cfg,
                      str(x2.dtype))
        return primal(x2, bq, b_scales), (x2, w, bq, b_scales)

    def bwd(res, g):
        x2, w, bq, b_scales = res
        dx = _dispatch2d(g, w.T, cfg).astype(x2.dtype)
        dw = _dispatch2d(x2.T, g, cfg).astype(w.dtype)
        dbq = np.zeros(bq.shape, jax.dtypes.float0)
        return dx, dw, dbq, jnp.zeros_like(b_scales)

    core.defvjp(fwd, bwd)
    return core


def refresh_planned_params(params):
    """Re-derive every PlannedWeight's B̃ from its (just-updated) raw weight.

    Planned gradients land on the raw weight (the B̃ cotangent is zero), so
    after an optimizer step the stored B̃ is stale; Combine B is linear and
    cheap relative to a train step, so the train steps re-run it here each
    update. Weights without a raw copy (``keep_weight=False``) train directly
    on B̃ and pass through. Identity for trees without PlannedWeights.
    """
    def refresh(leaf):
        if not isinstance(leaf, PlannedWeight) or not leaf.precombined \
                or leaf.w is None:
            return leaf
        lc = leaf.lcma
        bt = precombine_weights(leaf.w, lc) if leaf.w.ndim == 2 else \
            jax.vmap(lambda wi: precombine_weights(wi, lc))(leaf.w)
        if leaf.bq is None:
            return dataclasses.replace(leaf, bt=bt)
        # quantized PlannedWeight: re-bake B̃q + scales from the updated
        # weight too (same block size the original buffers were built with)
        by = int(leaf.bq.shape[1]) // int(leaf.b_scales.shape[1])
        bq, b_scales = _quantize_weight(leaf.w, lc, by=by)
        return dataclasses.replace(leaf, bt=bt, bq=bq, b_scales=b_scales)

    return jax.tree_util.tree_map(
        refresh, params, is_leaf=lambda x: isinstance(x, PlannedWeight))


# ---------------------------------------------------------------------------
# Dispatch entry points
# ---------------------------------------------------------------------------

def matmul(a: jnp.ndarray, b, cfg: FalconConfig | None = None,
           dtype_hint: str | None = None) -> jnp.ndarray:
    """``a @ b`` with FalconGEMM dispatch. ``a``: (..., M, K), ``b``: (K, N).

    Differentiable end to end: under ``cfg.planned_vjp`` the contraction runs
    through the custom-VJP core, so ``jax.grad`` computes both backward GEMMs
    as independently planned falcon contractions.
    """
    cfg = _resolve(cfg)
    if isinstance(b, PlannedWeight):
        return _apply_planned(a, b, cfg)
    *lead, M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"matmul: contracting dims differ: "
                         f"{tuple(a.shape)} @ {tuple(b.shape)}")
    Mflat = int(np.prod(lead)) * M if lead else M
    dtype = dtype_hint or str(a.dtype)
    use_core, d = _route_planned(Mflat, K, N, cfg, dtype)
    if use_core:
        c = _planned_core(cfg)(a.reshape(Mflat, K) if lead else a, b)
        return c.reshape(*lead, M, N) if lead else c
    if not d.use_lcma:
        return jnp.matmul(a, b)
    a2 = a.reshape(Mflat, K) if lead else a
    c = _lcma_apply(a2, b, d.algo, cfg)
    return c.reshape(*lead, M, N) if lead else c


def dense(x: jnp.ndarray, w, cfg: FalconConfig | None = None) -> jnp.ndarray:
    """Linear layer contraction: x (..., K) @ w (K, N) [w may be planned]."""
    cfg = _resolve(cfg)
    if isinstance(w, PlannedWeight):
        return _apply_planned(x, w, cfg)
    hook = backends.get_backend(cfg.backend).dense_hook
    if hook is not None:
        out = hook(x, w, cfg)
        if out is not None:
            return out
    *lead, K = x.shape
    return matmul(x.reshape(-1, K), w, cfg).reshape(*lead, w.shape[1])


def dot_general(a: jnp.ndarray, b, dimension_numbers,
                cfg: FalconConfig | None = None, precision=None,
                preferred_element_type=None) -> jnp.ndarray:
    """``jax.lax.dot_general`` with FalconGEMM dispatch.

    Transposed contractions are normalized: free/contracting dims are
    transposed adjacent and flattened to a (M, K) x (K, N) problem. An
    unbatched contraction is priced by ``plan()`` and runs the planned 2-D
    core; a **batched** contraction is priced as a whole group by
    ``plan_batched`` (one grouped decision and ONE grouped plan-cache key
    for the batch — never per-element pricing) and runs the grouped core.
    Under ``cfg.planned_vjp`` an LCMA-routed contraction runs through the
    matching custom-VJP core, so ``jax.grad`` backward contractions are
    independently planned too (backward shapes are priced only under
    differentiation — inference traces never pay for dA/dB plans). When the
    Decision Module declines (or an explicit ``preferred_element_type``
    asks for non-input accumulation semantics the LCMA combines don't
    honor), the call lowers to ``lax.dot_general`` untouched —
    bitwise-identical fallback.
    """
    cfg = _resolve(cfg)
    (ac, bc), (ab, bb) = dimension_numbers
    ac, bc, ab, bb = (tuple(int(i) for i in t) for t in (ac, bc, ab, bb))
    dn = ((ac, bc), (ab, bb))
    if isinstance(b, PlannedWeight):
        if ab or bb or ac != (a.ndim - 1,) or bc != (0,):
            raise ValueError(
                "PlannedWeight only supports the canonical dense contraction "
                f"(((a.ndim-1,), (0,)), ((), ())); got {dn}")
        return _apply_planned(a, b, cfg)
    a_free = tuple(i for i in range(a.ndim) if i not in ac and i not in ab)
    b_free = tuple(i for i in range(b.ndim) if i not in bc and i not in bb)
    M = int(np.prod([a.shape[i] for i in a_free])) if a_free else 1
    K = int(np.prod([a.shape[i] for i in ac])) if ac else 1
    N = int(np.prod([b.shape[i] for i in b_free])) if b_free else 1
    lcma_ok = (M > 0 and N > 0 and K > 0
               and (preferred_element_type is None
                    or jnp.dtype(preferred_element_type) == a.dtype))
    batch_shape = tuple(a.shape[i] for i in ab)
    Bsz = int(np.prod(batch_shape)) if ab else 1
    use_core = d = None
    if lcma_ok and not ab:
        use_core, d = _route_planned(M, K, N, cfg, str(a.dtype))
    elif lcma_ok:
        # Batched contraction: price the whole group (plan_batched — one
        # grouped plan-cache key), not one vmapped element. Both operands
        # carry the batch dims here, so the B operand is per-group.
        use_core, d = _route_grouped(Bsz, M, K, N, cfg, str(a.dtype),
                                     shared_b=False)
    if not use_core and (d is None or not d.use_lcma):
        return jax.lax.dot_general(a, b, dn, precision=precision,
                                   preferred_element_type=preferred_element_type)
    # Normalize: a -> (batch..., free..., contract...), b -> (batch...,
    # contract..., free...), flatten to (B, M, K) x (B, K, N).
    a_perm = ab + a_free + ac
    b_perm = bb + bc + b_free
    at = a if a_perm == tuple(range(a.ndim)) else jnp.transpose(a, a_perm)
    bt = b if b_perm == tuple(range(b.ndim)) else jnp.transpose(b, b_perm)
    out_shape = batch_shape + tuple(a.shape[i] for i in a_free) \
        + tuple(b.shape[i] for i in b_free)
    if not ab:
        core = _planned_core(cfg) if use_core \
            else (lambda x2, y2: _lcma_apply(x2, y2, d.algo, cfg))
        c = core(at.reshape(M, K), bt.reshape(K, N))
        return c.reshape(out_shape)
    a3 = at.reshape(Bsz, M, K)
    b3 = bt.reshape(Bsz, K, N)
    c3 = _grouped_core(cfg, False)(a3, b3) if use_core \
        else _lcma_apply_grouped(a3, b3, d.algo, cfg)
    return c3.reshape(out_shape)


def einsum(subscripts: str, *operands, cfg: FalconConfig | None = None,
           precision=None) -> jnp.ndarray:
    """``jnp.einsum`` with FalconGEMM dispatch for two-operand contractions.

    Two-operand subscripts without ellipsis/repeats/sum-out reduce to
    :func:`dot_general` (and so hit the Decision Module); anything else
    falls back to ``jnp.einsum`` unchanged.
    """
    if len(operands) == 2 and isinstance(subscripts, str):
        a, b = operands
        parsed = _einsum_dimension_numbers(subscripts, a.ndim, b.ndim)
        if parsed is not None:
            dn, perm = parsed
            out = dot_general(a, b, dn, cfg=cfg, precision=precision)
            if perm != tuple(range(len(perm))):
                out = jnp.transpose(out, perm)
            return out
    return jnp.einsum(subscripts, *operands, precision=precision)


def _einsum_dimension_numbers(subscripts: str, a_ndim: int, b_ndim: int):
    """Two-operand einsum -> (dimension_numbers, output transpose) or None.

    None means "not expressible as a single dot_general" (ellipsis, repeated
    labels within an operand, summed-out free labels, rank mismatch) and the
    caller should fall back to ``jnp.einsum``.
    """
    subs = subscripts.replace(" ", "")
    if "." in subs:
        return None
    if "->" in subs:
        lhs, out = subs.split("->")
    else:
        lhs, out = subs, None
    terms = lhs.split(",")
    if len(terms) != 2:
        return None
    ta, tb = terms
    if len(ta) != a_ndim or len(tb) != b_ndim:
        return None
    if len(set(ta)) != len(ta) or len(set(tb)) != len(tb):
        return None
    if out is None:  # implicit mode: alphabetic order of non-shared labels
        out = "".join(sorted(c for c in set(ta + tb)
                             if (ta + tb).count(c) == 1))
    if len(set(out)) != len(out) or any(c not in ta + tb for c in out):
        return None
    shared = [c for c in ta if c in tb]
    batch = tuple(c for c in shared if c in out)
    contract = tuple(c for c in shared if c not in out)
    a_free = [c for c in ta if c not in tb]
    b_free = [c for c in tb if c not in ta]
    if any(c not in out for c in a_free + b_free):
        return None  # summed-out free label: not a plain contraction
    dn = ((tuple(ta.index(c) for c in contract),
           tuple(tb.index(c) for c in contract)),
          (tuple(ta.index(c) for c in batch),
           tuple(tb.index(c) for c in batch)))
    natural = list(batch) + a_free + b_free   # dot_general output order
    perm = tuple(natural.index(c) for c in out)
    return dn, perm


# ---------------------------------------------------------------------------
# The engine object: a bound config + the dispatch surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FalconEngine:
    """A FalconConfig bound to the dispatch surface.

    The module-level functions resolve config from the ambient context; an
    engine pins one explicitly — handy for services juggling several
    hardware/policy profiles at once:

        eng = FalconEngine(FalconConfig(hardware="tpu_v5e", backend="pallas"))
        y = eng.dense(x, w)
        with eng.activate():     # or: make it the ambient config
            y = falcon_dense(x, w)
    """

    config: FalconConfig = dataclasses.field(default_factory=FalconConfig)

    def activate(self):
        return use(self.config)

    def plan(self, M: int, K: int, N: int, dtype: str = "bfloat16",
             precombined_b: bool = False):
        return plan(M, K, N, self.config, dtype, precombined_b=precombined_b)

    def matmul(self, a, b, **kw):
        return matmul(a, b, cfg=self.config, **kw)

    def dense(self, x, w):
        return dense(x, w, cfg=self.config)

    def dot_general(self, a, b, dimension_numbers, **kw):
        return dot_general(a, b, dimension_numbers, cfg=self.config, **kw)

    def grouped_matmul(self, a, b):
        return grouped_matmul(a, b, cfg=self.config)

    def plan_batched(self, B: int, M: int, K: int, N: int,
                     dtype: str = "bfloat16", precombined_b: bool = False,
                     shared_b: bool = False):
        return plan_batched(B, M, K, N, self.config, dtype,
                            precombined_b=precombined_b, shared_b=shared_b)

    def einsum(self, subscripts, *operands, **kw):
        return einsum(subscripts, *operands, cfg=self.config, **kw)

    def plan_weight(self, w, **kw):
        return plan_weight(w, cfg=self.config, **kw)

    def precombine_params(self, params, **kw):
        return precombine_params(params, cfg=self.config, **kw)

    def plan_training(self, M: int, K: int, N: int, dtype: str = "bfloat16"):
        return plan_training(M, K, N, self.config, dtype)

    def warm_buckets(self, arch, buckets, **kw):
        return warm_buckets(self.config, arch, buckets, **kw)
