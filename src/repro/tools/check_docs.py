"""Docs CI: execute every ``python`` code block in the docs and check links.

Documentation rots the moment its snippets stop running. This tool keeps the
guides honest:

  * every fenced ```python block in the given markdown files is executed —
    blocks within one file run *in order in one fresh interpreter* (so later
    blocks may use names defined earlier), against a small prelude namespace
    (``np``/``jnp``/``jax``/``falcon`` plus tiny conforming arrays, see
    ``PRELUDE``). Non-runnable pseudo-code belongs in ```text blocks.
  * every relative markdown link ``[...](path)`` must resolve to an existing
    file (http(s)/mailto/pure-#anchor links are skipped).

Run from the repo root (CI ``docs`` job)::

    PYTHONPATH=src python -m repro.tools.check_docs            # README + docs/
    PYTHONPATH=src python -m repro.tools.check_docs --links-only
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess
import sys
import tempfile
import textwrap

_FENCE = re.compile(r"^```(\w[\w-]*)?\s*$")
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# Names every doc snippet may assume. Kept tiny so the docs job stays fast;
# shapes conform with each other (x @ w, A @ B, attention q/k) and are small
# enough that auto-mode decisions resolve instantly on CPU.
PRELUDE = """
import numpy as np
import jax
import jax.numpy as jnp
import repro.api as falcon

_rng = np.random.default_rng(0)
A = jnp.asarray(_rng.standard_normal((64, 48)), jnp.float32)
B = jnp.asarray(_rng.standard_normal((48, 32)), jnp.float32)
x = jnp.asarray(_rng.standard_normal((2, 16, 32)), jnp.float32)
w = jnp.asarray(_rng.standard_normal((32, 64)), jnp.float32)
W = w
q = jnp.asarray(_rng.standard_normal((2, 16, 4, 8)), jnp.float32)
k = jnp.asarray(_rng.standard_normal((2, 16, 4, 8)), jnp.float32)
a3 = jnp.asarray(_rng.standard_normal((4, 16, 32)), jnp.float32)
b3 = jnp.asarray(_rng.standard_normal((4, 32, 24)), jnp.float32)
batch, prompt_len = 2, 16
a, b = A, B
dimension_numbers = (((1,), (0,)), ((), ()))      # plain a (M,K) @ b (K,N)
"""


def extract_python_blocks(text: str) -> list[tuple[int, str]]:
    """-> [(first_line_number, source), ...] for ```python fences."""
    blocks: list[tuple[int, str]] = []
    lines = text.splitlines()
    in_block = False
    lang = None
    start = 0
    buf: list[str] = []
    for i, line in enumerate(lines, 1):
        m = _FENCE.match(line.strip()) if line.strip().startswith("```") else None
        if not in_block and m:
            in_block, lang, start, buf = True, (m.group(1) or ""), i + 1, []
        elif in_block and line.strip() == "```":
            if lang.lower() == "python":
                blocks.append((start, "\n".join(buf)))
            in_block = False
        elif in_block:
            buf.append(line)
    return blocks


def run_file_blocks(path: str, timeout: int = 600) -> list[str]:
    """Execute the file's python blocks in one fresh interpreter; -> errors."""
    with open(path) as f:
        blocks = extract_python_blocks(f.read())
    if not blocks:
        return []
    # One driver script per file: prelude, then each block exec'd with its
    # doc line number attached so a failure points back into the markdown.
    parts = [PRELUDE, "import traceback as _tb", "_failures = []"]
    for lineno, src in blocks:
        parts.append(
            "try:\n"
            + textwrap.indent(f"exec(compile({src!r}, "
                              f"{f'{path}:{lineno}'!r}, 'exec'))", "    ")
            + "\nexcept Exception:\n"
            f"    _failures.append(({lineno}, _tb.format_exc()))\n")
    parts.append(
        "import sys\n"
        "for _ln, _err in _failures:\n"
        f"    print(f'{path}:{{_ln}}: python block failed\\n{{_err}}')\n"
        "sys.exit(1 if _failures else 0)\n")
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as tf:
        tf.write("\n".join(parts))
        script = tf.name
    try:
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        out = subprocess.run([sys.executable, script], capture_output=True,
                             text=True, timeout=timeout, env=env)
        if out.returncode != 0:
            msg = out.stdout.strip() or out.stderr.strip()
            return [f"{path}: {msg}"]
        return []
    finally:
        os.unlink(script)


def check_links(path: str) -> list[str]:
    """Relative markdown links must resolve to existing files."""
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path) as f:
        text = f.read()
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*",
                    help="markdown files (default: README.md + docs/*.md)")
    ap.add_argument("--links-only", action="store_true",
                    help="skip code-block execution (fast local check)")
    args = ap.parse_args(argv)

    paths = args.paths or (["README.md"] + sorted(glob.glob("docs/*.md")))
    errors: list[str] = []
    for path in paths:
        if not os.path.exists(path):
            errors.append(f"{path}: file not found")
            continue
        errors.extend(check_links(path))
        if not args.links_only:
            n = len(extract_python_blocks(open(path).read()))
            errs = run_file_blocks(path)
            errors.extend(errs)
            print(f"{path}: {n} python block(s) "
                  f"{'FAILED' if errs else 'ok'}, links "
                  f"{'ok' if not any(path in e for e in errors) else 'checked'}")
    if errors:
        print(f"\n{len(errors)} docs problem(s):")
        for e in errors:
            print("  -", e.splitlines()[0] if "\n" in e else e)
            if "\n" in e:
                print(textwrap.indent(e, "      "))
        return 1
    print(f"\ndocs ok: {len(paths)} files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
