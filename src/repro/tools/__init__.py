"""Operational CLIs: calibration / cache warming (``python -m repro.tools.tune``)."""
