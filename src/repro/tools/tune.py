"""Calibrate a hardware target and warm the persistent plan cache.

  PYTHONPATH=src python -m repro.tools.tune --hardware cpu_host --backend jnp

does three things:

  1. runs the empirical autotuner (``core.autotune``) — times the backend's
     GEMM, Group Combine A and the R-batched LCMA GEMM stage on a probe grid
     and fits effective ``(FLOPS_x, FLOPS_+, beta, lcma_gemm_efficiency)``;
  2. writes the calibrated :class:`HardwareProfile` as JSON (default:
     ``~/.cache/falcon_gemm/profiles/<name>.json``, override with
     ``FALCON_PROFILE_DIR`` or ``--out``) together with probe measurements
     and per-scheme Pallas block plans as metadata;
  3. warms the plan cache for a grid of serving shapes — derived from the
     workload registry (``core.workloads.warm_shapes``, projection pairs of
     ``--warm-workload``'s contraction set x token buckets) — under the
     calibrated profile and persists it next to the profile, so a serving
     process (``repro.launch.serve --plan-cache ...``) starts with zero
     cold misses.

``--train`` extends both steps to the backward pass: probe shapes gain their
transposed (dA/dB) variants and the warm grid covers full fwd+bwd shape
triples, so a planned custom-VJP train step traces against a hot cache.

After tuning, both of these resolve the calibrated numbers:

  FalconConfig(hardware="<base>_autotuned")
  decision.decide(M, N, K, "<base>_autotuned")
"""
from __future__ import annotations

import argparse
import os

def _parse_shape(s: str) -> tuple[int, int, int]:
    parts = [int(x) for x in s.replace("x", ",").split(",") if x]
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(f"shape must be M,K,N — got {s!r}")
    return tuple(parts)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tools.tune",
        description="Empirical autotune + plan-cache warmup for FalconGEMM.")
    ap.add_argument("--hardware", default="cpu_host",
                    help="base profile name to calibrate (default: cpu_host)")
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_interpret"])
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--scheme", default="strassen",
                    help="probe LCMA used for combine/batched measurements")
    ap.add_argument("--shape", action="append", type=_parse_shape, default=None,
                    metavar="M,K,N", help="probe shape (repeatable)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--name", default=None,
                    help="name for the calibrated profile "
                         "(default: <hardware>_autotuned)")
    ap.add_argument("--out", default=None,
                    help="profile JSON path (default: profile dir / <name>.json)")
    ap.add_argument("--no-warm", dest="warm", action="store_false",
                    help="skip plan-cache warmup")
    ap.add_argument("--warm-dtype", default="bfloat16",
                    help="dtype for plan-cache warmup decisions")
    ap.add_argument("--warm-workload", default="deepseek_r1",
                    help="workload whose registry contraction set seeds the "
                         "warm grid: a paper workload (deepseek_r1/qwen3_5/"
                         "hunyuan_video) or a configs.registry arch id "
                         "(default: deepseek_r1)")
    ap.add_argument("--quant", action="store_true",
                    help="probe the int8 stage too (raw int8 GEMM + fused "
                         "Combine-A+quantize) and persist the measured "
                         "FLOPS_int8 as the profile's dtype_flops['int8'] — "
                         "what the quantized decision tier is priced with")
    ap.add_argument("--collectives", action="store_true",
                    help="probe effective all-gather/reduce-scatter bandwidth "
                         "across local devices and record it on the profile "
                         "(collective_bw — priced by the sharded decision "
                         "tier); skipped silently on single-device hosts")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny probe shapes, one rep, reduced "
                         "plan-cache warm grid")
    ap.add_argument("--train", action="store_true",
                    help="calibrate + warm for training: probe shapes gain "
                         "their backward (transposed) variants and the plan "
                         "cache is warmed with full fwd+dA+dB shape triples")
    args = ap.parse_args(argv)
    if args.quick:
        args.reps, args.warmup = 1, 0
        if args.shape is None:
            args.shape = [(192, 192, 192), (384, 384, 384)]
    if args.train:
        # Backward-stage calibration: the bwd GEMMs run the same kernels at
        # transposed aspect ratios (M,N,K)/(K,M,N), so the fit must see those
        # shapes too — including when no explicit --shape was given (the
        # documented invocation), where the probe grid starts from the
        # autotuner's defaults. Dedup keeps the grid small.
        from repro.core.autotune import default_probe_shapes
        from repro.core.decision import backward_shapes
        if args.shape is None:
            args.shape = default_probe_shapes(args.backend)
        seen = set(args.shape)
        for s in list(args.shape):
            for sb in backward_shapes(*s):
                if sb not in seen:
                    seen.add(sb)
                    args.shape.append(sb)

    from repro.core import autotune, plan_cache
    from repro.core.falcon_gemm import FalconConfig, plan, plan_training
    from repro.core.hardware import get_profile
    from repro.core.workloads import warm_shapes

    base = get_profile(args.hardware)
    print(f"calibrating {base.name!r} via backend={args.backend} "
          f"dtype={args.dtype} scheme={args.scheme} ...")
    report, path = autotune.calibrate(
        path=args.out, base=args.hardware, backend=args.backend,
        shapes=args.shape, dtype=args.dtype, scheme=args.scheme,
        reps=args.reps, warmup=args.warmup, name=args.name,
        collectives=args.collectives, quant=args.quant)
    prof = report.profile

    def tera(x):
        return f"{x / 1e12:8.3f}T"

    print(f"wrote {path}")
    print(f"  {'quantity':24s} {'static':>10s} {'calibrated':>10s}")
    print(f"  {'FLOPS_x (matmul)':24s} {tera(base.flops_for(args.dtype))} "
          f"{tera(prof.flops_mul)}")
    print(f"  {'FLOPS_+ (elementwise)':24s} {tera(base.flops_add)} "
          f"{tera(prof.flops_add)}")
    print(f"  {'beta (bytes/s)':24s} {tera(base.beta)} {tera(prof.beta)}")
    print(f"  {'lcma_gemm_efficiency':24s} {base.lcma_gemm_efficiency:10.3f} "
          f"{prof.lcma_gemm_efficiency:10.3f}")
    if args.collectives:
        if prof.collective_bw > 0:
            print(f"  {'collective_bw (bytes/s)':24s} {tera(base.coll_bw())} "
                  f"{tera(prof.collective_bw)}")
        else:
            print(f"  collective probe skipped: single local device "
                  f"(link_bw fallback {tera(base.coll_bw())})")
    if args.quant and report.flops_int8 is not None:
        print(f"  {'FLOPS_int8 (quant GEMM)':24s} "
              f"{tera(base.flops_for('int8'))} {tera(report.flops_int8)}")
        print(f"  {'beta_quant (bytes/s)':24s} {'':>10s} "
              f"{tera(report.beta_quant)}")
    if report.max_rel_err is not None:
        print(f"  model-vs-measured pipeline rel.err: "
              f"max {report.max_rel_err:.1%} over {len(report.model_rel_err)} probes")
    if report.eff_grouped is not None:
        print(f"  grouped GEMM stage eff: measured {report.eff_grouped:.3f} "
              f"vs eff_B model {report.eff_grouped_predicted:.3f}")

    if args.warm:
        # next to the profile JSON, wherever --out put it
        cache_path = os.path.join(os.path.dirname(os.path.abspath(path)),
                                  f"{prof.name}.plans.json")
        cache = plan_cache.configure(path=cache_path, autoload=False)
        cfg = FalconConfig(hardware=prof.name)
        n_lcma = 0
        shapes = warm_shapes(args.warm_workload)
        if args.quick:
            shapes = shapes[:8]
        for (m, k, n) in shapes:
            if args.train:
                for d in plan_training(m, k, n, cfg, dtype=args.warm_dtype):
                    n_lcma += int(d.use_lcma)
            else:
                d = plan(m, k, n, cfg, dtype=args.warm_dtype)
                n_lcma += int(d.use_lcma)
        cache.save()
        kind = "fwd+bwd triples" if args.train else "plans"
        print(f"warmed plan cache: {len(cache)} {kind} "
              f"({n_lcma} pick an LCMA) -> {cache_path}")
        print(f"serve with: python -m repro.launch.serve --arch <arch> "
              f"--plan-cache {cache_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
