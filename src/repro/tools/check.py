"""falcon-check: static verification & lint CLI for FalconGEMM artifacts.

  PYTHONPATH=src python -m repro.tools.check --all

runs every pass of ``repro.analysis`` against the shipped artifacts and exits
non-zero iff any pass reports an *error* (warnings and info pass):

  * ``brent``        — exact integer verification of every library scheme's
    Brent equations (elementary schemes AND composition-operator outputs);
  * ``stability``    — Higham-style error-growth bounds per scheme (ERROR
    only when ``--budget`` is given and exceeded) plus int8 accumulator
    overflow bounds (``--quant-accum``);
  * ``plan-lint``    — ``kernels/tuning.block_plans`` output for each
    candidate scheme on the probe shapes, checked against the hardware
    profile (divisibility, grid bounds, VMEM vs the profile's ``vmem_bytes``);
    ``--workload <arch>`` runs the same lint over an architecture's FULL
    contraction set as enumerated by the workload registry
    (``core.workloads.contraction_set``) — every projection, expert FFN,
    attention and SSD contraction the model will plan, without launching a
    single kernel;
  * ``codegen-lint`` — the Deployment Module's generated source re-derived
    at the AST level against the scheme's coefficient tensors;
  * ``cache-audit``  — invariants of a persisted plan-cache JSON
    (``--cache PATH``; ``--all`` audits a freshly round-tripped cache).

Individual passes are selectable (``--library``, ``--plans``,
``--quant-plans``, ``--cache``, ``--scheme``, ``--scheme-file``,
``--quant-accum``); everything is static — no kernel is compiled or launched
by any code path in this tool.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import numpy as np

DEFAULT_SHAPES = ((1024, 1024, 1024), (2048, 2048, 2048), (512, 2048, 1024))


def _parse_shape(s: str) -> tuple[int, int, int]:
    parts = [int(x) for x in s.replace("x", ",").split(",") if x]
    if len(parts) != 3:
        raise argparse.ArgumentTypeError(f"shape must be M,K,N — got {s!r}")
    return tuple(parts)


def _parse_quant(s: str) -> tuple[int, int]:
    parts = [int(x) for x in s.split(",") if x]
    if len(parts) == 1:
        return parts[0], 32
    if len(parts) == 2:
        return parts[0], parts[1]
    raise argparse.ArgumentTypeError(
        f"quant-accum must be DEPTH or DEPTH,ACC_BITS — got {s!r}")


def _load_scheme_file(path: str):
    """Construct an (unregistered) LCMA from a JSON listing.

    The file format is the obvious one: ``{"name", "m", "k", "n", "R",
    "U", "V", "W"}`` with the coefficient tensors as nested lists — the same
    shape discipline as ``LCMA`` itself. Used to vet third-party or
    machine-generated listings *before* ``algorithms.register()`` (which
    would reject an invalid one by raising).
    """
    from repro.core.lcma import LCMA

    with open(path) as f:
        doc = json.load(f)
    return LCMA(str(doc.get("name", os.path.basename(path))),
                int(doc["m"]), int(doc["k"]), int(doc["n"]), int(doc["R"]),
                np.asarray(doc["U"]), np.asarray(doc["V"]),
                np.asarray(doc["W"]))


def _check_scheme_full(l, *, budget, dtype, findings):
    """All scheme-local passes for one LCMA: brent, stability, codegen."""
    from repro import analysis

    findings.extend(analysis.check_scheme(l))
    findings.extend(analysis.check_scheme_stability(l, budget=budget,
                                                    dtype=dtype))
    findings.extend(analysis.lint_codegen(l))


def _roundtrip_cache_audit(hw, dtype: str, findings) -> None:
    """Persist a freshly-decided plan cache to a temp file and audit it.

    Exercises the full encode -> JSON -> audit path (including scheme
    fingerprints) without touching any user cache file.
    """
    from repro import analysis
    from repro.core import decision as dec, plan_cache

    cache = plan_cache.PlanCache(capacity=16)
    for (M, K, N) in DEFAULT_SHAPES:
        d = dec.decide(M, N, K, hw, dtype)
        cache.insert(plan_cache.plan_key(M, K, N, hw, dtype), d)
    with tempfile.TemporaryDirectory() as td:
        path = cache.save(os.path.join(td, "plan_cache.json"))
        findings.extend(analysis.audit_cache_file(path, hw=hw))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="falcon-check",
        description="Static verification & lint for FalconGEMM schemes, "
                    "kernel plans and plan caches.")
    ap.add_argument("--all", action="store_true",
                    help="run every pass on the shipped artifacts")
    ap.add_argument("--library", action="store_true",
                    help="brent + stability over the scheme library")
    ap.add_argument("--plans", action="store_true",
                    help="lint candidate schemes' block plans on the probe "
                         "shapes against --hardware")
    ap.add_argument("--workload", action="append", default=[],
                    metavar="ARCH",
                    help="lint the full registry contraction set of an "
                         "architecture (configs.registry id or paper "
                         "workload name) against --hardware (repeatable; "
                         "--all lints every registry arch)")
    ap.add_argument("--workload-batch", type=int, default=8,
                    help="batch for --workload shape resolution (default 8)")
    ap.add_argument("--workload-seq", type=int, default=512,
                    help="seq for --workload shape resolution (default 512)")
    ap.add_argument("--quant-plans", action="store_true",
                    help="lint the int8-quantized pipeline each candidate "
                         "would run on the probe shapes: backend legality, "
                         "accumulator overflow, scale-block divisibility")
    ap.add_argument("--codegen", action="store_true",
                    help="AST-lint the generated source of every candidate")
    ap.add_argument("--cache", metavar="PATH",
                    help="audit a persisted plan-cache JSON file")
    ap.add_argument("--plan-file", action="append", default=[],
                    metavar="JSON",
                    help="lint a serialized block-plan dict (e.g. from a "
                         "calibrated profile's metadata) against --hardware")
    ap.add_argument("--scheme", action="append", default=[], metavar="NAME",
                    help="check one registered scheme (repeatable)")
    ap.add_argument("--scheme-file", action="append", default=[],
                    metavar="JSON",
                    help="check an unregistered scheme listing from a JSON "
                         "file (name/m/k/n/R/U/V/W)")
    ap.add_argument("--quant-accum", type=_parse_quant, metavar="DEPTH[,BITS]",
                    help="check an int8 reduction depth against the "
                         "accumulator width (default 32 bits)")
    ap.add_argument("--shape", action="append", type=_parse_shape,
                    default=None, metavar="M,K,N",
                    help="probe shape for --plans (repeatable; default "
                         f"{', '.join('x'.join(map(str, s)) for s in DEFAULT_SHAPES)})")
    ap.add_argument("--hardware", default="tpu_v5e",
                    help="hardware profile name for --plans/--all "
                         "(default: tpu_v5e)")
    ap.add_argument("--backend", default="pallas",
                    help="execution backend for dtype-legality lint "
                         "(default: pallas)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--budget", type=float, default=None,
                    help="accuracy budget: schemes whose error bound exceeds "
                         "it become stability ERRORs")
    ap.add_argument("--show-info", action="store_true",
                    help="include info-level findings in the report")
    args = ap.parse_args(argv)

    if not any((args.all, args.library, args.plans, args.quant_plans,
                args.codegen, args.cache, args.plan_file, args.scheme,
                args.scheme_file, args.quant_accum, args.workload)):
        ap.error("nothing to check: pass --all or a specific pass "
                 "(--library/--plans/--quant-plans/--workload/--codegen/"
                 "--cache/--plan-file/--scheme/--scheme-file/--quant-accum)")

    # Heavy imports after arg parsing so `--help` stays instant.
    from repro import analysis
    from repro.core import algorithms
    from repro.core.hardware import get_profile

    findings: list = []
    shapes = tuple(args.shape) if args.shape else DEFAULT_SHAPES
    hw = get_profile(args.hardware)

    if args.all or args.library:
        findings.extend(analysis.check_library())
        findings.extend(analysis.check_library_stability(
            budget=args.budget, dtype="bfloat16"))

    if args.all or args.codegen:
        for l in algorithms.candidates():
            findings.extend(analysis.lint_codegen(l))

    if args.all or args.plans:
        for l in algorithms.candidates():
            findings.extend(analysis.lint_scheme_plans(
                l, shapes, hw, dtype=args.dtype, backend=args.backend))

    if args.all or args.quant_plans:
        for l in algorithms.candidates():
            findings.extend(analysis.lint_quant_plans(
                l, shapes, hw, backend=args.backend))

    workloads = list(args.workload)
    if args.all and not workloads:
        from repro.configs import registry
        workloads = registry.list_archs()
    for arch in workloads:
        try:
            findings.extend(analysis.lint_workload(
                arch, hw, batch=args.workload_batch, seq=args.workload_seq,
                dtype=args.dtype, backend=args.backend))
        except (KeyError, ModuleNotFoundError) as e:
            print(f"falcon-check: unknown workload {arch!r}: {e}",
                  file=sys.stderr)
            return 2

    if args.all:
        _roundtrip_cache_audit(hw, "bfloat16", findings)

    if args.cache:
        findings.extend(analysis.audit_cache_file(args.cache, hw=hw))

    for path in args.plan_file:
        try:
            with open(path) as f:
                plan = json.load(f)
        except (OSError, ValueError) as e:
            print(f"falcon-check: cannot load plan file {path}: {e}",
                  file=sys.stderr)
            return 2
        findings.extend(analysis.lint_block_plan(
            plan, hw, dtype=args.dtype, backend=args.backend,
            subject=os.path.basename(path)))

    for name in args.scheme:
        try:
            l = algorithms.get(name)
        except KeyError as e:
            print(f"falcon-check: {e}", file=sys.stderr)
            return 2
        _check_scheme_full(l, budget=args.budget, dtype="bfloat16",
                           findings=findings)

    for path in args.scheme_file:
        try:
            l = _load_scheme_file(path)
        except (OSError, KeyError, ValueError) as e:
            print(f"falcon-check: cannot load scheme file {path}: {e}",
                  file=sys.stderr)
            return 2
        # No codegen lint here: the listing may be arbitrarily broken and the
        # point is to report brent/stability findings, not to generate code.
        findings.extend(analysis.check_scheme(l))
        findings.extend(analysis.check_scheme_stability(
            l, budget=args.budget, dtype="bfloat16"))

    if args.quant_accum:
        depth, bits = args.quant_accum
        findings.extend(analysis.check_quant_accumulator(depth, bits))

    print(analysis.format_findings(findings, show_info=args.show_info))
    n_err = sum(f.is_error for f in findings)
    n_warn = sum(f.severity == "warning" for f in findings)
    print(f"falcon-check: {len(findings)} finding(s), {n_err} error(s), "
          f"{n_warn} warning(s)")
    return 1 if analysis.has_errors(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
