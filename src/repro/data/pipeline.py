"""Deterministic, checkpointable, shardable synthetic LM data pipeline.

Tokens are a pure function of (seed, step, position) via a counter-based hash,
so any worker can regenerate any batch — restarts and elastic re-sharding need
no data-state beyond the integer ``step``. Batches are placed with the mesh's
batch sharding via ``jax.device_put``; under multi-host each process would
feed its addressable shards (``make_array_from_process_local_data``), which
this single-process container reduces to a plain device_put.

The synthetic stream is Zipfian with a Markov backbone so the LM loss actually
decreases during the example runs (pure uniform noise would pin loss at
log V).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DataConfig", "SyntheticLMData"]


def _hash_u64(x: np.ndarray) -> np.ndarray:
    """splitmix64 — vectorized counter-based PRNG."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_codebooks: int = 0       # musicgen-style parallel streams
    zipf_alpha: float = 1.1


class SyntheticLMData:
    """Stateless-per-step iterator: ``batch(step)`` is pure and deterministic."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None,
                 batch_spec: P | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.batch_spec = batch_spec
        # Zipf-ish stationary distribution over a small alphabet mapped into V.
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._cdf = np.cumsum(p / p.sum())

    def _tokens(self, step: int) -> np.ndarray:
        c = self.cfg
        shape = (c.global_batch, c.seq_len + 1)
        if c.num_codebooks:
            shape = shape + (c.num_codebooks,)
        n = int(np.prod(shape))
        ctr = (np.uint64(c.seed) << np.uint64(40)) + (np.uint64(step) << np.uint64(20))
        raw = _hash_u64(np.arange(n, dtype=np.uint64) + ctr)
        u = (raw >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        base = np.searchsorted(self._cdf, u).astype(np.int64)
        # Markov backbone: token_t depends on token_{t-1} for learnability
        flat = base.reshape(shape)
        if not c.num_codebooks:
            prev = np.roll(flat, 1, axis=1)
            flat = (flat + 7 * prev) % self.cfg.vocab_size
        return np.clip(flat, 0, c.vocab_size - 1).astype(np.int32)

    def batch(self, step: int) -> dict:
        toks = self._tokens(step)
        inputs, labels = toks[:, :-1], toks[:, 1:]
        out = {"tokens": inputs, "labels": labels}
        if self.mesh is not None:
            spec = self.batch_spec if self.batch_spec is not None else P()
            sh = NamedSharding(self.mesh, spec)
            out = {k: jax.device_put(v, sh) for k, v in out.items()}
        return out
