"""jax version compatibility shims.

The repo targets the modern mesh/shard_map surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.set_mesh`` / ``get_abstract_mesh`` /
``AxisType``), but must also run on older releases (the CI container pins
jax 0.4.37) where those live under ``jax.experimental.shard_map`` /
``check_rep`` and the active mesh is the legacy ``with mesh:`` thread
resource. Every mesh/shard_map touchpoint in ``core/``, ``parallel/``,
``launch/``, ``models/`` and the tests goes through this module so the rest
of the codebase is written once, against one API.
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["make_mesh", "set_mesh", "get_abstract_mesh", "shard_map",
           "axis_size", "HAS_NEW_SHARD_MAP", "HAS_MESH_CONTEXT_API"]

HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
HAS_MESH_CONTEXT_API = (hasattr(jax.sharding, "set_mesh")
                        and hasattr(jax.sharding, "get_abstract_mesh"))
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates the ``axis_types`` API gap.

    New jax wants explicit axis types for the context-mesh machinery; old jax
    does not know the keyword (and has no ``AxisType`` at all). Defaulting to
    ``AxisType.Auto`` everywhere preserves GSPMD auto-partitioning semantics.
    """
    kwargs = {"devices": devices} if devices is not None else {}
    if not hasattr(jax, "make_mesh"):  # pragma: no cover - very old jax
        from jax.experimental import mesh_utils
        devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
        return jax.sharding.Mesh(devs, tuple(axis_names))
    if _AXIS_TYPE is not None:
        if axis_types is None:
            axis_types = (_AXIS_TYPE.Auto,) * len(tuple(axis_names))
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kwargs)
        except TypeError:
            pass  # jax has AxisType but make_mesh predates the keyword
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding-by-PartitionSpec.

    Maps to ``jax.sharding.set_mesh`` where present; otherwise to the legacy
    ``with mesh:`` thread-resource context (which is what pre-context-API jax
    uses to resolve bare PartitionSpecs in ``with_sharding_constraint`` and to
    supply the mesh for ``shard_map``).
    """
    if HAS_MESH_CONTEXT_API:
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def get_abstract_mesh():
    """The active mesh, normalized: returns ``None`` when no mesh is active.

    (New jax returns an *empty* AbstractMesh rather than ``None``; callers
    here always want "is there a mesh with axes to shard over?" so the empty
    mesh is folded into ``None``.)
    """
    if HAS_MESH_CONTEXT_API:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new) / ``psum(1, axis)`` (old) inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, in_specs, out_specs, mesh=None, check_vma=True):
    """``jax.shard_map`` across the ``check_vma``/``check_rep`` rename.

    ``mesh=None`` uses the ambient mesh (``set_mesh`` above); old jax requires
    an explicit mesh argument, so the ambient one is resolved eagerly there.
    """
    if HAS_NEW_SHARD_MAP:
        kwargs = dict(in_specs=in_specs, out_specs=out_specs)
        if mesh is not None:
            kwargs["mesh"] = mesh
        try:
            return jax.shard_map(f, check_vma=check_vma, **kwargs)
        except TypeError:
            return jax.shard_map(f, check_rep=check_vma, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        mesh = get_abstract_mesh()
        if mesh is None:
            raise ValueError(
                "compat.shard_map: no mesh passed and no mesh active; "
                "wrap the call in `with compat.set_mesh(mesh):`")
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
