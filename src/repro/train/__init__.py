from .steps import make_train_step, make_eval_step, make_prefill_step, make_decode_step
from .loop import TrainLoop, TrainLoopConfig, FaultInjector

__all__ = ["make_train_step", "make_eval_step", "make_prefill_step",
           "make_decode_step", "TrainLoop", "TrainLoopConfig", "FaultInjector"]
