"""Step functions: train (fwd+bwd+AdamW), eval, prefill, decode.

All steps are pure functions of (params, opt_state, batch, step) so they jit
and pjit cleanly; the launch layer attaches in/out shardings. FalconGEMM
policy resolves from the ambient context (``falcon.use``) at trace time; the
``fcfg`` factory kwarg survives as a deprecated override. The compressed-DP
variant computes gradients inside ``shard_map`` and replaces the implicit
GSPMD gradient all-reduce with the int8 collective from
``repro.parallel.compression``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core import engine
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_update, cosine_schedule
from repro.parallel.compression import compressed_psum_mean

__all__ = ["make_train_step", "make_eval_step", "make_prefill_step",
           "make_serve_prefill_step", "make_chunk_prefill_step",
           "make_decode_step", "make_verify_step",
           "make_compressed_dp_train_step", "warm_train"]


def warm_train(cfg: ModelConfig, batch: int, seq: int) -> int:
    """Pre-plan the forward AND backward shapes of every contraction in
    ``cfg`` at (batch, seq) — dense projections, grouped MoE expert FFNs,
    attention score/value contractions and SSD chunk contractions, all
    enumerated by the workload registry (``core.workloads.contraction_set``).

    Run once before jitting a train step: tracing then resolves every
    Decision-Module query — the forward contractions and the custom-VJP
    backward pair of each layer — from a hot plan cache, so the whole step
    compiles without a single cold candidate enumeration. Returns the number
    of ``plan()`` calls issued.
    """
    fc = engine.active_config() or M.falcon_config_for(cfg)
    return engine.warm_buckets(fc, cfg, [(batch, seq)],
                               dtype=str(cfg.dtype), train=True)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    total_steps: int = 10_000, warmup: int = 100,
                    fcfg=None, microbatches: int = 1):
    """fwd+bwd+AdamW step. ``microbatches > 1`` enables gradient accumulation:
    the global batch is scanned in chunks with an f32 grad accumulator —
    activation memory scales with the microbatch while the optimizer sees the
    full batch (how large global batches ride on fixed per-device memory)."""
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_train_step")

    def grad_of(params, batch):
        def loss_fn(p):
            return M.lm_loss(p, cfg, batch)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        with engine.maybe_use(fcfg):
            if microbatches == 1:
                (loss, metrics), grads = grad_of(params, batch)
            else:
                def split(x):
                    n = microbatches
                    assert x.shape[0] % n == 0, (x.shape, n)
                    return x.reshape((n, x.shape[0] // n) + x.shape[1:])

                mbatch = {k: split(v) for k, v in batch.items()}
                gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    gacc, lacc = carry
                    (l, _), g = grad_of(params, mb)
                    gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + l), None

                (gacc, lsum), _ = jax.lax.scan(
                    body, (gacc0, jnp.zeros((), jnp.float32)), mbatch)
                grads = jax.tree.map(lambda g: g / microbatches, gacc)
                loss = lsum / microbatches
                metrics = {}
            lr_scale = cosine_schedule(step, warmup, total_steps)
            params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg,
                                                 lr_scale=lr_scale)
            # Planned params: the optimizer stepped the raw weight (planned
            # grads land there, the B̃ cotangent is zero) — re-derive B̃ so
            # the next forward reads a consistent precombined weight.
            # Identity (and free) for trees without PlannedWeights.
            params = engine.refresh_planned_params(params)
            out = {"loss": loss, "lr_scale": lr_scale, **metrics, **om}
            return params, opt_state, out

    return train_step


def make_eval_step(cfg: ModelConfig, fcfg=None):
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_eval_step")

    def eval_step(params, batch):
        with engine.maybe_use(fcfg):
            loss, metrics = M.lm_loss(params, cfg, batch)
            return {"loss": loss, **metrics}

    return eval_step


def make_prefill_step(cfg: ModelConfig, max_len: int, fcfg=None):
    """Single-pass prefill: fills the KV cache AND returns last-token logits."""
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_prefill_step")

    def prefill_step(params, tokens, patch_embeds=None):
        with engine.maybe_use(fcfg):
            B = tokens.shape[0]
            cache = M.init_cache(cfg, B, max_len)
            logits, cache, _ = M.forward(params, cfg, tokens,
                                         patch_embeds=patch_embeds, cache=cache,
                                         cache_index=0, logits_mode="last")
            return logits, cache

    return prefill_step


def make_serve_prefill_step(cfg: ModelConfig, max_len: int, fcfg=None):
    """Prefill for bucketed serving: right-padded prompts, per-row last index.

    The continuous-batching engine pads every prompt in a micro-batch up to
    the bucket length, so "last token" differs per row: ``last_index`` (B,)
    selects each request's true final prompt position before the LM head
    runs (on (B, 1, d) — the padded tail never reaches the vocab matmul).
    A per-row length mask derived from ``last_index`` makes SSM/hybrid
    recurrent state exact under the right padding (dt=0 on pad positions).
    Returns (logits (B, 1, V), cache) with the cache sized to ``max_len`` so
    its rows slot directly into the engine's slot cache.
    """
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_serve_prefill_step")

    def prefill_step(params, tokens, last_index):
        with engine.maybe_use(fcfg):
            B, S = tokens.shape[0], tokens.shape[1]
            cache = M.init_cache(cfg, B, max_len)
            mask = (jnp.arange(S)[None, :]
                    <= last_index[:, None]).astype(jnp.float32)
            hidden, cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                         cache_index=0, logits_mode="none",
                                         length_mask=mask)
            h_last = jnp.take_along_axis(
                hidden, last_index[:, None, None].astype(jnp.int32), axis=1)
            logits = M.compute_logits(params, cfg, h_last)
            return logits, cache

    return prefill_step


def make_chunk_prefill_step(cfg: ModelConfig, fcfg=None):
    """One prefill *chunk* against existing slot-cache rows.

    Chunked prefill splits a long prompt into bucket-sized pieces the
    scheduler interleaves with decode work. Unlike ``make_serve_prefill_step``
    (which creates a fresh cache), a chunk resumes at per-row offset
    ``start`` (B,) into ``cache`` rows gathered from the engine's slot cache:
    positions ``[start, start+S)`` are written this chunk, attention validity
    admits exactly ``kpos < start + S`` (earlier chunks plus this one — any
    stale K/V from a slot's previous occupant above that is masked until
    overwritten), and SSM/hybrid recurrent state carries chunk-to-chunk
    through the cache (zeroed here for first-chunk rows, since a reused slot
    may still hold the previous occupant's state). ``start > 0`` with a
    fresh request also covers prefix-cache reuse: the reused snapshot is
    copied into the slot first and only the suffix runs. Intermediate chunks
    are full buckets (``last_index = S-1``); the final chunk is right-padded
    and ``last_index`` picks each row's true last position for the LM head.
    Returns (logits (B, 1, V), cache rows).
    """
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_chunk_prefill_step")

    def chunk_step(params, cache, tokens, start, last_index):
        with engine.maybe_use(fcfg):
            B, S = tokens.shape[0], tokens.shape[1]
            if "state" in cache:
                st = cache["state"]
                fresh = (start > 0).astype(st.dtype)
                cache = {**cache,
                         "state": st * fresh.reshape((1, B) + (1,) * (st.ndim - 2))}
            mask = (jnp.arange(S)[None, :]
                    <= last_index[:, None]).astype(jnp.float32)
            hidden, cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                         cache_index=start, logits_mode="none",
                                         length_mask=mask)
            h_last = jnp.take_along_axis(
                hidden, last_index[:, None, None].astype(jnp.int32), axis=1)
            logits = M.compute_logits(params, cfg, h_last)
            return logits, cache

    return chunk_step


def make_decode_step(cfg: ModelConfig, fcfg=None):
    """One-token decode against a KV cache at position ``index``.

    ``index`` is a scalar (uniform batch) or an int vector (B,) of per-row
    positions — the continuous-batching case where every slot in the decode
    micro-batch sits at its own generation offset.
    """
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_decode_step")

    def decode_step(params, cache, tokens, index):
        with engine.maybe_use(fcfg):
            logits, new_cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                             cache_index=index,
                                             logits_mode="last")
            return logits, new_cache

    return decode_step


def make_verify_step(cfg: ModelConfig, fcfg=None):
    """Speculative verify: score γ+1 tokens in one forward, logits per row.

    ``tokens`` (B, γ+1) is ``[t_last, d_1 .. d_γ]`` per row — the pending
    committed token followed by the draft proposals — decoded against the KV
    cache at per-row ``index``. Causal masking makes row j's logits exactly
    the sequential next-token distribution after ``t_last, d_1..d_j``, so
    the greedy accept rule (accept ``d_j`` while it equals ``argmax`` of row
    ``j-1``; always emit one bonus token from the first non-matching row)
    reproduces non-speculative greedy decoding token-for-token regardless of
    draft quality. Returns (logits (B, γ+1, V), cache rows); rejected draft
    positions stay in the cache but are overwritten before attention
    validity ever admits them (same argument as right-pad prefill).
    """
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_verify_step")

    def verify_step(params, cache, tokens, index):
        with engine.maybe_use(fcfg):
            logits, new_cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                             cache_index=index,
                                             logits_mode="all")
            return logits, new_cache

    return verify_step


def make_compressed_dp_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
                                  bits: int = 8, fcfg=None,
                                  total_steps: int = 10_000, warmup: int = 100):
    """Pure-DP train step with int8-compressed gradient all-reduce.

    Params replicated, batch sharded over the DP axes; grads are computed
    per-shard inside shard_map and synced with the compressed collective.
    """
    if fcfg is not None:
        engine.warn_deprecated_fcfg("make_compressed_dp_train_step")

    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    batch_spec = P(dp_axes)

    def sharded_grads(params, batch):
        def loss_fn(p):
            return M.lm_loss(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = compressed_psum_mean(grads, dp_axes, bits=bits)
        loss = jax.lax.pmean(loss, dp_axes)
        return loss, metrics, grads

    smapped = compat.shard_map(
        sharded_grads, mesh=mesh,
        in_specs=(P(), {"tokens": batch_spec, "labels": batch_spec}),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )

    def train_step(params, opt_state, batch, step):
        with engine.maybe_use(fcfg):
            loss, metrics, grads = smapped(params, batch)
            lr_scale = cosine_schedule(step, warmup, total_steps)
            params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg,
                                                 lr_scale=lr_scale)
            params = engine.refresh_planned_params(params)
            return params, opt_state, {"loss": loss, **om}

    return train_step
