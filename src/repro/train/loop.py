"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested with injected faults):
  * checkpoint/restart — periodic async checkpoints via CheckpointManager;
    on a step failure the loop restores the last checkpoint and replays
    (the data pipeline is a pure function of step, so replay is exact),
  * bounded retry — repeated failures at the same step abort with a clear
    error instead of looping forever,
  * preemption — SIGTERM/flag triggers a final synchronous checkpoint and a
    clean exit (the restart picks up at the same step),
  * straggler detection — per-step wall time vs. a running EMA; slow steps
    are counted and surfaced in metrics so an orchestrator can re-schedule
    (on real fleets this hooks the health-monitor; here it is a log + metric),
  * elastic restart — checkpoints are full logical arrays, so a resumed run
    may use a different mesh (see checkpoint.restore_checkpoint).
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger(__name__)

__all__ = ["TrainLoopConfig", "TrainLoop", "FaultInjector"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    max_retries_per_step: int = 2
    straggler_factor: float = 3.0     # step slower than factor*EMA => straggler
    ema_decay: float = 0.9
    log_every: int = 10
    handle_sigterm: bool = False      # opt-in: don't hijack signals in tests


class FaultInjector:
    """Deterministic fault injection for tests: raise at given steps."""

    def __init__(self, fail_at: dict[int, int] | None = None):
        self.fail_at = dict(fail_at or {})  # step -> remaining failures

    def maybe_fail(self, step: int):
        if self.fail_at.get(step, 0) > 0:
            self.fail_at[step] -= 1
            raise RuntimeError(f"injected fault at step {step}")


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, train_step: Callable, data,
                 params, opt_state, fault_injector: FaultInjector | None = None,
                 shardings=None, warm_fn: Callable | None = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data
        self.params = params
        self.opt_state = opt_state
        self.faults = fault_injector
        self.shardings = shardings  # (param_sh, opt_sh) for elastic restore
        # Optional warm pass (e.g. ``lambda: steps.warm_train(cfg, B, S)``):
        # pre-plans the fwd+bwd shape triples so the first step's trace —
        # which compiles the whole planned custom-VJP graph — hits a hot
        # plan cache instead of enumerating LCMA candidates per contraction.
        self.warm_fn = warm_fn
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        self.metrics_history: list[dict] = []
        self.stragglers = 0
        self.restarts = 0
        self._preempted = False
        if cfg.handle_sigterm:  # pragma: no cover - signal path
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, *_):  # pragma: no cover
        self._preempted = True

    def preempt(self):
        """Programmatic preemption (tests / orchestrator hook)."""
        self._preempted = True

    # -- checkpoint plumbing ------------------------------------------------
    def _state(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def _save(self, step: int, sync: bool = False):
        self.ckpt.async_save = not sync
        self.ckpt.save(step, self._state(), extra={"step": step})
        if sync:
            self.ckpt.wait()

    def _restore(self) -> int:
        state, step, _ = self.ckpt.restore_latest(
            jax.tree.map(lambda x: x, self._state()), shardings=self.shardings)
        self.params, self.opt_state = state["params"], state["opt_state"]
        return step

    # -- main loop ----------------------------------------------------------
    def run(self, start_step: int = 0) -> dict:
        if self.warm_fn is not None:
            n_plans = self.warm_fn()
            log.info("warm pass: %s plans pre-computed before first trace",
                     n_plans)
        step = start_step
        ema = None
        retries = 0
        while step < self.cfg.total_steps:
            if self._preempted:
                log.warning("preemption: checkpointing at step %d and exiting", step)
                self._save(step, sync=True)
                break
            t0 = time.perf_counter()
            try:
                if self.faults:
                    self.faults.maybe_fail(step)
                batch = self.data.batch(step)
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch, step)
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # noqa: BLE001 - any step failure is retryable
                retries += 1
                self.restarts += 1
                if retries > self.cfg.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {retries} times; aborting") from e
                try:
                    restored = self._restore()
                    log.warning("step %d failed (%s); restored checkpoint@%d",
                                step, e, restored)
                    step = restored
                except FileNotFoundError:
                    log.warning("step %d failed (%s); no checkpoint, retrying",
                                step, e)
                continue
            retries = 0
            dt = time.perf_counter() - t0
            if ema is not None and dt > self.cfg.straggler_factor * ema:
                self.stragglers += 1
                log.warning("straggler step %d: %.3fs vs EMA %.3fs", step, dt, ema)
            ema = dt if ema is None else self.cfg.ema_decay * ema + (1 - self.cfg.ema_decay) * dt
            rec = {"step": step, "time": dt,
                   "loss": float(np.asarray(metrics["loss"]))}
            self.metrics_history.append(rec)
            if step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", step, rec["loss"], dt)
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self._save(step)
        self.ckpt.wait()
        return {
            "final_step": step,
            "stragglers": self.stragglers,
            "restarts": self.restarts,
            "history": self.metrics_history,
        }
