"""FalconGEMM on TPU — LCMA GEMM backend + multi-pod training/serving framework."""
