"""Finding: the common currency of every ``falcon-check`` pass.

A static-analysis pass never raises on a defect in the *artifact* it audits
(a scheme, a block plan, a cache file) — it returns :class:`Finding` objects
so one run can report every problem at once, the CLI can exit non-zero on
errors while letting warnings through, and tests can assert on exactly which
pass flagged what.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Finding", "ERROR", "WARNING", "INFO", "has_errors", "format_findings"]

ERROR = "error"        # artifact is wrong: must not be promoted / executed
WARNING = "warning"    # suspicious but executable (e.g. high error growth)
INFO = "info"          # measurement surfaced for the record (bounds, stats)

_SEVERITIES = (ERROR, WARNING, INFO)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect or observation from a static-analysis pass.

    ``pass_name`` is the stable identifier tests and CI grep for:
    ``brent`` | ``stability`` | ``plan-lint`` | ``codegen-lint`` |
    ``cache-audit``.
    """

    pass_name: str
    severity: str
    subject: str          # scheme name / plan id / cache key
    message: str

    def __post_init__(self):
        if self.severity not in _SEVERITIES:
            raise ValueError(f"Finding severity {self.severity!r} not in "
                             f"{_SEVERITIES}")

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.severity}: {self.subject}: {self.message}"


def has_errors(findings) -> bool:
    return any(f.is_error for f in findings)


def format_findings(findings, *, show_info: bool = False) -> str:
    """Human-readable report, grouped by pass, errors first within a pass."""
    shown = [f for f in findings if show_info or f.severity != INFO]
    if not shown:
        hidden = len(list(findings)) - len(shown)
        if hidden:
            return (f"no errors or warnings "
                    f"({hidden} info finding(s) hidden; use --show-info)")
        return "no findings"
    order = {ERROR: 0, WARNING: 1, INFO: 2}
    by_pass: dict[str, list[Finding]] = {}
    for f in shown:
        by_pass.setdefault(f.pass_name, []).append(f)
    lines = []
    for name in sorted(by_pass):
        group = sorted(by_pass[name], key=lambda f: order[f.severity])
        n_err = sum(f.is_error for f in group)
        lines.append(f"{name}: {len(group)} finding(s), {n_err} error(s)")
        for f in group:
            lines.append(f"  {f.severity:7s} {f.subject}: {f.message}")
    return "\n".join(lines)
