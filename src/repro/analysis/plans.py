"""Pass 3 — kernel-plan lint: block plans and generated source, statically.

``kernels/tuning.block_plans`` is the export surface the autotuner embeds in
calibrated-profile JSON; serving trusts those numbers when it launches Pallas
kernels. This pass re-derives every claim a plan makes — block divisibility,
grid bounds, VMEM footprints — against a :class:`HardwareProfile`, so a plan
that would OOM VMEM or mis-tile is rejected *offline*, without compiling a
kernel.

The same pass lints the Deployment Module's generated source
(``core/codegen._emit_source``) at the AST level: the emitted combines are
machine-written Python, and the historical failure mode (PR 4: coefficient
magnitudes silently dropped) is a *generator* bug — so the lint independently
re-checks the emitted linear combinations against the scheme's coefficient
tensors instead of trusting the emitter.
"""
from __future__ import annotations

import ast
import builtins

import numpy as np

from repro.core.lcma import LCMA
from repro.core.hardware import HardwareProfile
from .findings import ERROR, WARNING, Finding

__all__ = ["lint_block_plan", "lint_scheme_plans", "lint_quant_plans",
           "lint_workload", "lint_codegen", "BACKEND_DTYPES",
           "MAX_GRID_PROGRAMS"]

PASS = "plan-lint"
CODEGEN_PASS = "codegen-lint"

# Legal element dtypes per execution backend. The Pallas TPU pipeline has no
# float64 path (MXU is bf16/int8; VPU f32), and the quantized kernels only
# accept int8 operands with f32 scales.
BACKEND_DTYPES = {
    "jnp": {"float64", "float32", "bfloat16", "float16", "int8"},
    "pallas": {"float32", "bfloat16", "int8"},
    "pallas_interpret": {"float32", "bfloat16", "int8"},
    "shard_map_local": {"float32", "bfloat16"},
}

# Pallas grids are int32-indexed; stay far below the wrap-around point.
MAX_GRID_PROGRAMS = 2 ** 31 - 1


def _check_div(findings, subject, what, num, den):
    if den <= 0 or num % den != 0:
        findings.append(Finding(
            PASS, ERROR, subject,
            f"{what}: block {den} does not divide dimension {num}"))
        return False
    return True


def lint_block_plan(plan: dict, hw: HardwareProfile, *,
                    dtype: str = "float32", backend: str = "pallas",
                    subject: str | None = None) -> list[Finding]:
    """Statically check one ``block_plans`` dict against a hardware profile."""
    import jax.numpy as jnp
    from repro.kernels import tuning

    findings: list[Finding] = []
    subject = subject or f"plan<{plan.get('grid')};R={plan.get('R')}>"

    required = ("grid", "R", "padded_shape", "combine_a", "combine_b",
                "fused_gemm", "combine_a_vmem_bytes", "combine_b_vmem_bytes",
                "fused_gemm_vmem_bytes", "vmem_budget_bytes")
    missing = [k for k in required if k not in plan]
    if missing:
        return [Finding(PASS, ERROR, subject,
                        f"malformed plan: missing keys {missing}")]

    m, k, n = (int(x) for x in plan["grid"])
    R = int(plan["R"])
    Mp, Kp, Np = (int(x) for x in plan["padded_shape"])

    # dtype legality per backend
    allowed = BACKEND_DTYPES.get(backend)
    if allowed is None:
        findings.append(Finding(PASS, WARNING, subject,
                                f"unknown backend {backend!r}: dtype legality "
                                f"not checked"))
    elif str(dtype) not in allowed:
        findings.append(Finding(
            PASS, ERROR, subject,
            f"dtype {dtype} is not executable on backend {backend!r} "
            f"(legal: {sorted(allowed)})"))

    # grid divisibility of the padded problem
    for name, dim, g in (("M", Mp, m), ("K", Kp, k), ("N", Np, n)):
        if g < 1 or dim % g != 0:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"padded {name}={dim} is not divisible by grid {g}"))
    if any(f.is_error for f in findings):
        return findings   # partition sizes below would be meaningless

    X, Ks, Z = Mp // m, Kp // k, Np // n
    bax, bay = (int(x) for x in plan["combine_a"])
    bbx, bby = (int(x) for x in plan["combine_b"])
    fx, fz, fy = (int(x) for x in plan["fused_gemm"])

    ok = True
    ok &= _check_div(findings, subject, "combine_a.x over M/m", X, bax)
    ok &= _check_div(findings, subject, "combine_a.y over K/k", Ks, bay)
    ok &= _check_div(findings, subject, "combine_b.x over K/k", Ks, bbx)
    ok &= _check_div(findings, subject, "combine_b.y over N/n", Z, bby)
    ok &= _check_div(findings, subject, "fused_gemm.x over M/m", X, fx)
    ok &= _check_div(findings, subject, "fused_gemm.z over N/n", Z, fz)
    ok &= _check_div(findings, subject, "fused_gemm.y over K/k", Ks, fy)

    # grid bounds (programs are int32-indexed)
    if ok:
        n_prog = max((X // fx) * (Z // fz) * (Ks // fy),
                     (X // bax) * (Ks // bay), (Ks // bbx) * (Z // bby))
        if n_prog > MAX_GRID_PROGRAMS:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"kernel grid has {n_prog} programs > int32 bound "
                f"{MAX_GRID_PROGRAMS}"))

    # VMEM: recompute from the blocks (don't trust the reported numbers),
    # cross-check the report, then compare against budget AND profile.
    it = jnp.dtype(dtype).itemsize
    recomputed = {
        "combine_a_vmem_bytes": tuning.combine_vmem(bax, bay, R, m * k, it),
        "combine_b_vmem_bytes": tuning.combine_vmem(bbx, bby, R, k * n, it),
        "fused_gemm_vmem_bytes": tuning.fused_gemm_vmem(fx, fz, fy, R, m, n, it),
    }
    budget = int(plan["vmem_budget_bytes"])
    for key, want in recomputed.items():
        got = int(plan[key])
        if got != want:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"{key} reports {got} but the blocks imply {want} "
                f"(stale or hand-edited plan)"))
        stage_budget = min(budget, hw.vmem_bytes)
        if want > stage_budget:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"{key.removesuffix('_vmem_bytes')} VMEM footprint {want} B "
                f"exceeds the {'profile' if want > hw.vmem_bytes else 'plan'} "
                f"limit {stage_budget} B ({hw.name}: {hw.vmem_bytes} B)"))

    # MXU alignment: advisory — misaligned tiles run, at reduced utilization.
    # Only flagged when an aligned divisor actually exists: a block must tile
    # the dimension exactly, and a multiple of mxu_align divides dim only if
    # mxu_align itself does.
    if ok:
        for name, b, dim in (("fused_gemm.x", fx, X), ("fused_gemm.z", fz, Z)):
            if dim % hw.mxu_align == 0 and b % hw.mxu_align != 0:
                findings.append(Finding(
                    PASS, WARNING, subject,
                    f"{name} block {b} is not a multiple of the MXU dimension "
                    f"{hw.mxu_align} (dim {dim} allows an aligned tile)"))
    return findings


def lint_scheme_plans(l: LCMA, shapes, hw: HardwareProfile, *,
                      dtype: str = "float32",
                      backend: str = "pallas") -> list[Finding]:
    """Generate and lint the block plans scheme ``l`` would use on ``shapes``."""
    from repro.kernels import tuning
    findings: list[Finding] = []
    for (M, K, N) in shapes:
        plan = tuning.block_plans(l, M, K, N, dtype=dtype, hw=hw)
        findings.extend(lint_block_plan(
            plan, hw, dtype=dtype, backend=backend,
            subject=f"{l.name}@{M}x{K}x{N}/{dtype}"))
    return findings


def lint_workload(arch, hw: HardwareProfile, *, batch: int = 8,
                  seq: int = 512, dtype: str | None = None,
                  backend: str = "pallas", train: bool = False,
                  quantize: bool = False, mesh_shape=None,
                  all_candidates: bool = False) -> list[Finding]:
    """Statically lint an architecture's full contraction set against ``hw``.

    The workload registry (``core.workloads``) enumerates every planned
    contraction ``arch`` issues at (batch, seq); for each unique contraction
    shape, the Decision Module picks its scheme and that scheme's block plan
    is linted (divisibility, grid bounds, VMEM vs the profile) — the same
    checks serving trusts at launch, run offline without compiling a kernel.
    ``all_candidates=True`` lints EVERY candidate scheme per shape instead
    (a scheme the decision would never pick may legitimately fail there,
    e.g. an int32 grid overflow on a huge lm_head — useful for triage, not
    for CI gating). With ``quantize=True`` the int8 pipeline of each
    weight-static contraction is linted too. ``arch`` is a registry id /
    paper workload name or a ``ModelConfig``.
    """
    from repro.core import algorithms, decision
    from repro.core.workloads import resolve_contractions, _resolve_arch
    from repro.kernels import tuning

    cfg = _resolve_arch(arch)
    name = getattr(cfg, "name", str(arch))
    dtype = str(dtype or getattr(cfg, "dtype", "bfloat16"))
    findings: list[Finding] = []
    for c in resolve_contractions(arch, batch, seq, train=train,
                                  mesh_shape=mesh_shape):
        if quantize and not (c.weight_static and c.kind in
                             ("dense", "grouped_moe")):
            continue
        m, k, n = c.shape
        if all_candidates:
            schemes = list(algorithms.candidates())
        else:
            d = decision.decide(m, n, k, hw, dtype)
            schemes = [d.algo] if d.use_lcma else []
        for l in schemes:
            plan = tuning.block_plans(l, m, k, n, dtype=dtype, hw=hw)
            findings.extend(lint_block_plan(
                plan, hw, dtype=dtype, backend=backend,
                subject=f"{name}:{c.role}:{l.name}@{m}x{k}x{n}/{dtype}"))
            if quantize:
                findings.extend(lint_quant_plans(
                    l, [(m, k, n)], hw, backend=backend))
    return findings


def _snap_block(dim: int, cap: int = 128) -> int:
    """Largest divisor of ``dim`` that is <= ``cap`` (the kernels' snap rule)."""
    return next(d for d in range(min(cap, dim), 0, -1) if dim % d == 0)


def lint_quant_plans(l: LCMA, shapes, hw: HardwareProfile, *,
                     backend: str = "pallas",
                     acc_bits: int = 32) -> list[Finding]:
    """Statically lint the int8-quantized pipeline ``l`` would run on ``shapes``.

    Re-derives, for each serving shape, exactly the choices the quantized
    PlannedWeight path makes — the weight scale-block ``by`` (largest divisor
    of the combined K that is <= 128, per ``engine._quantize_weight``) and the
    fused kernel's divisor-snapped ``(bx, bz)`` — then checks the claims those
    kernels assert at trace time, without compiling anything:

    * **backend legality** — int8 operands must be executable on ``backend``
      (``shard_map_local`` has no quant path);
    * **accumulator safety** — a ``by``-deep int8*int8 reduction must fit the
      int-``acc_bits`` accumulator (``stability.max_safe_accum_depth``);
    * **scale-block / grid divisibility** — ``by | K/k``, ``bx | M/m``,
      ``bz | N/n``: the asserts ``fused_gemm_combine_h_quant`` and
      ``quantize_b_blockwise`` make on every launch;
    * **grid bounds** — the quant GEMM grid stays below the int32 program
      index wrap-around;
    * **degenerate scale blocks** (warning) — a ``by`` far below the 128 cap
      means the shape's combined K is oddly factored and the per-block scale
      arrays bloat the memory traffic the decision tier priced.
    """
    from repro.analysis.stability import int8_accum_bound, max_safe_accum_depth

    findings: list[Finding] = []
    allowed = BACKEND_DTYPES.get(backend)
    safe_depth = max_safe_accum_depth(acc_bits)

    for (M, K, N) in shapes:
        subject = f"{l.name}@{M}x{K}x{N}/int8"

        if allowed is None:
            findings.append(Finding(
                PASS, WARNING, subject,
                f"unknown backend {backend!r}: int8 legality not checked"))
        elif "int8" not in allowed:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"int8 is not executable on backend {backend!r} "
                f"(legal: {sorted(allowed)}); the quantized tier must not "
                f"be selected here"))
            continue

        Mp = M + (-M) % l.m
        Kp = K + (-K) % l.k
        Np = N + (-N) % l.n
        X, Ks, Z = Mp // l.m, Kp // l.k, Np // l.n
        by = _snap_block(Ks)
        bx = _snap_block(X)
        bz = _snap_block(Z)

        ok = True
        ok &= _check_div(findings, subject, "quant scale block over K/k", Ks, by)
        ok &= _check_div(findings, subject, "quant fused_gemm.x over M/m", X, bx)
        ok &= _check_div(findings, subject, "quant fused_gemm.z over N/n", Z, bz)
        if not ok:
            continue

        if by > safe_depth:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"int8 reduction depth {by} can overflow the int{acc_bits} "
                f"accumulator: worst-case |sum| = {int8_accum_bound(by)} > "
                f"{2 ** (acc_bits - 1) - 1} (max safe depth {safe_depth})"))

        n_prog = (X // bx) * (Z // bz) * (Ks // by)
        if n_prog > MAX_GRID_PROGRAMS:
            findings.append(Finding(
                PASS, ERROR, subject,
                f"quant kernel grid has {n_prog} programs > int32 bound "
                f"{MAX_GRID_PROGRAMS}"))

        if Ks >= 32 and by < 32:
            findings.append(Finding(
                PASS, WARNING, subject,
                f"quant scale block snaps to {by} (combined K {Ks} has no "
                f"divisor in [32, 128]): scale arrays are {Ks // by}x larger "
                f"than the 128-block baseline the decision tier prices"))
    return findings


# ---------------------------------------------------------------------------
# Codegen AST lint
# ---------------------------------------------------------------------------

_ALLOWED_GLOBALS = {"jax", "jnp"} | set(dir(builtins))

_REQUIRED_FUNCS = ("combine_a", "combine_b", "gemm_stage", "combine_h",
                   "lcma_matmul")


class _FuncScope(ast.NodeVisitor):
    """Collect assigned and loaded names within one function body."""

    def __init__(self):
        self.stored: set[str] = set()
        self.loaded: list[tuple[str, int]] = []

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Store):
            self.stored.add(node.id)
        elif isinstance(node.ctx, ast.Load):
            self.loaded.append((node.id, node.lineno))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):  # nested defs: opaque
        self.stored.add(node.name)


def _coeff_from_expr(expr: ast.expr, var_coeff: dict) -> None:
    """Accumulate ``{name: coeff}`` from an emitted linear combination.

    The emitter's grammar is tiny: sums/differences of ``name``,
    ``const * name`` and unary minus. Anything outside that grammar raises
    ``ValueError`` — which the caller reports as a lint error.
    """
    def term(e, sign):
        if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
            term(e.operand, -sign)
        elif isinstance(e, ast.BinOp) and isinstance(e.op, ast.Add):
            term(e.left, sign)
            term(e.right, sign)
        elif isinstance(e, ast.BinOp) and isinstance(e.op, ast.Sub):
            term(e.left, sign)
            term(e.right, -sign)
        elif isinstance(e, ast.BinOp) and isinstance(e.op, ast.Mult):
            try:  # literal_eval also accepts a negated constant (-3 * x)
                c = ast.literal_eval(e.left)
            except ValueError:
                raise ValueError(f"non-constant scale {ast.dump(e.left)}") from None
            name = _name_of(e.right)
            var_coeff[name] = var_coeff.get(name, 0) + sign * c
        elif isinstance(e, ast.Constant):
            if e.value != 0.0:
                raise ValueError(f"unexpected constant {e.value!r}")
        else:
            name = _name_of(e)
            var_coeff[name] = var_coeff.get(name, 0) + sign
    term(expr, 1)


def _name_of(e: ast.expr) -> str:
    if isinstance(e, ast.Name):
        return e.id
    if isinstance(e, ast.Subscript) and isinstance(e.value, ast.Name) \
            and isinstance(e.slice, ast.Constant):
        return f"{e.value.id}[{e.slice.value}]"
    raise ValueError(f"unexpected term {ast.dump(e)}")


def _expected_combine(coeff: np.ndarray, part: str, r: int) -> dict:
    d1, d2 = coeff.shape[1], coeff.shape[2]
    return {f"{part}_{i}_{l}": int(coeff[r, i, l])
            for i in range(d1) for l in range(d2) if coeff[r, i, l] != 0}


def lint_codegen(l: LCMA, options=None) -> list[Finding]:
    """AST-level checks on the source ``codegen._emit_source`` emits for ``l``.

    * the source parses and defines the full stage surface;
    * no function loads a name that is neither assigned locally, a parameter,
      a module-level def, nor an allowed global (``jax``/``jnp``/builtins) —
      the "sliced a_0_3 that was never emitted" class of generator bug;
    * every ``at_r = ...`` / ``bt_r = ...`` combine is parsed back into its
      ``{operand: coefficient}`` map and compared EXACTLY against U/V — a
      re-derivation, not a trust of the emitter (PR 4's magnitude-dropping
      bug is invisible to name-scope checks but caught here);
    * Combine-H subscripts ``H[r]`` stay within rank bounds and its
      coefficient map matches W.
    """
    from repro.core import codegen

    o = options or codegen.CodegenOptions()
    src = codegen._emit_source(l, o)
    subject = f"codegen:{l.name}"
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(CODEGEN_PASS, ERROR, subject,
                        f"emitted source does not parse: {e}")]
    findings: list[Finding] = []

    funcs = {node.name: node for node in tree.body
             if isinstance(node, ast.FunctionDef)}
    for name in _REQUIRED_FUNCS:
        if name not in funcs:
            findings.append(Finding(CODEGEN_PASS, ERROR, subject,
                                    f"generated source lacks def {name}()"))
    module_names = set(funcs) | _ALLOWED_GLOBALS

    for fname, node in funcs.items():
        scope = _FuncScope()
        for stmt in node.body:
            scope.visit(stmt)
        params = {a.arg for a in node.args.args}
        known = scope.stored | params | module_names
        for name, lineno in scope.loaded:
            if name not in known:
                findings.append(Finding(
                    CODEGEN_PASS, ERROR, subject,
                    f"{fname}() line {lineno}: loads undefined name {name!r}"))

    # Re-derive the combine coefficient maps from the AST.
    for fname, coeff, part, out in (("combine_a", l.U, "a", "at"),
                                    ("combine_b", l.V, "b", "bt")):
        node = funcs.get(fname)
        if node is None:
            continue
        got: dict[int, dict] = {}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                tgt = stmt.targets[0].id
                if tgt.startswith(out + "_"):
                    r = int(tgt[len(out) + 1:])
                    terms: dict = {}
                    try:
                        _coeff_from_expr(stmt.value, terms)
                    except ValueError as e:
                        findings.append(Finding(
                            CODEGEN_PASS, ERROR, subject,
                            f"{fname}() {tgt}: unparseable combine ({e})"))
                        continue
                    got[r] = {k: v for k, v in terms.items() if v != 0}
        if set(got) != set(range(l.R)):
            findings.append(Finding(
                CODEGEN_PASS, ERROR, subject,
                f"{fname}() emits combines for ranks {sorted(got)}; "
                f"expected 0..{l.R - 1}"))
        for r, terms in got.items():
            want = _expected_combine(coeff, part, r)
            if terms != want:
                findings.append(Finding(
                    CODEGEN_PASS, ERROR, subject,
                    f"{fname}() rank {r}: emitted coefficients {terms} != "
                    f"scheme tensor {want}"))

    # Combine-H: subscript bounds + coefficient map vs W.
    node = funcs.get("combine_h")
    if node is not None:
        for stmt in ast.walk(node):
            if isinstance(stmt, ast.Subscript) \
                    and isinstance(stmt.value, ast.Name) \
                    and stmt.value.id == "H" \
                    and isinstance(stmt.slice, ast.Constant):
                r = stmt.slice.value
                if not (0 <= r < l.R):
                    findings.append(Finding(
                        CODEGEN_PASS, ERROR, subject,
                        f"combine_h() indexes H[{r}] outside rank 0..{l.R - 1}"))
        got_h: dict[tuple[int, int], dict] = {}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id.startswith("c_"):
                _, i, j = stmt.targets[0].id.split("_")
                expr = stmt.value
                # strip the trailing (...).astype(out_dtype) call
                if isinstance(expr, ast.Call) \
                        and isinstance(expr.func, ast.Attribute):
                    expr = expr.func.value
                terms = {}
                try:
                    _coeff_from_expr(expr, terms)
                except ValueError as e:
                    findings.append(Finding(
                        CODEGEN_PASS, ERROR, subject,
                        f"combine_h() c_{i}_{j}: unparseable combine ({e})"))
                    continue
                got_h[(int(i), int(j))] = {k: v for k, v in terms.items()
                                           if v != 0}
        for i in range(l.m):
            for j in range(l.n):
                want = {f"H[{r}]": int(l.W[r, i, j]) for r in range(l.R)
                        if l.W[r, i, j] != 0}
                if got_h.get((i, j), {}) != want:
                    findings.append(Finding(
                        CODEGEN_PASS, ERROR, subject,
                        f"combine_h() C[{i},{j}]: emitted {got_h.get((i, j))} "
                        f"!= scheme W column {want}"))
    return findings
