"""Pass 2 — numerical-stability and integer-overflow analysis.

Two static questions are answered per scheme, before any kernel launches:

**Floating-point error growth (Higham-style).** A one-level bilinear scheme
amplifies rounding error by a factor determined entirely by its coefficient
tensors. With

  * ``alpha_u = max_r sum_{i,l} |U[r,i,l]|``  (worst Combine-A magnification),
  * ``alpha_v = max_r sum_{l,j} |V[r,l,j]|``  (worst Combine-B magnification),
  * ``alpha_w = max_{i,j} sum_r |W[r,i,j]|``  (worst Combine-H magnification),
  * ``q_u/q_v/q_w`` the corresponding worst-case term counts (additions),

the computed block satisfies (Higham, *Accuracy and Stability of Numerical
Algorithms*, §23.2, specialized to one level)

    |C_hat - C| <= growth * terms * u * ||A||_max ||B||_max * K + O(u^2),

with ``growth = alpha_u * alpha_v * alpha_w`` and ``terms = q_u + q_v + q_w
+ 2``. The *relative* per-scheme figure ``error_bound(dtype) = growth *
terms * u(dtype)`` is what the Decision Module compares against a call
site's accuracy budget: standard GEMM has growth 1 per output term, Strassen
~16, and |c|>1 listings (AlphaTensor standard-arithmetic, Smirnov) grow
quadratically in the coefficient magnitude — exactly the schemes a bf16
serving path must be able to reject statically.

**int8 accumulator overflow.** The quantized pipeline
(``kernels/quant_combine.py``) accumulates ``int8 x int8 -> int32`` MXU
products over a reduction block of ``depth`` elements. The worst-case partial
sum is ``depth * 127 * 127``; the accumulator is safe iff that fits the
signed accumulator width. :func:`max_safe_accum_depth` is the exact bound the
kernel-plan lint enforces and ``fused_gemm_combine_h_quant`` guards at call
time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lcma import LCMA
from .findings import ERROR, INFO, WARNING, Finding

__all__ = ["SchemeStability", "analyze", "check_scheme_stability",
           "check_library_stability", "INT8_MAX", "int8_accum_bound",
           "max_safe_accum_depth", "check_quant_accumulator", "dtype_eps"]

PASS = "stability"

# Unit roundoff per dtype. int8 is the quantization step of the symmetric
# 127-level block-scaled scheme (relative, half an LSB at full scale).
_DTYPE_EPS = {
    "float64": 2.0 ** -53,
    "float32": 2.0 ** -24,
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    "int8": 1.0 / (2 * 127),
}


def dtype_eps(dtype: str) -> float:
    try:
        return _DTYPE_EPS[str(dtype)]
    except KeyError:
        raise ValueError(f"stability model: unknown dtype {dtype!r}; known: "
                         f"{sorted(_DTYPE_EPS)}") from None


@dataclasses.dataclass(frozen=True)
class SchemeStability:
    """Static error-growth profile of one LCMA scheme."""

    name: str
    alpha_u: int          # max_r ||U[r]||_1
    alpha_v: int          # max_r ||V[r]||_1
    alpha_w: int          # max_{i,j} sum_r |W[r,i,j]|
    q_u: int              # max_r nnz(U[r])  (terms in the longest Combine-A)
    q_v: int
    q_w: int              # max_{i,j} nnz_r(W[:,i,j])
    max_abs_coeff: int

    @property
    def growth(self) -> int:
        """Magnitude amplification factor alpha_u * alpha_v * alpha_w."""
        return self.alpha_u * self.alpha_v * self.alpha_w

    @property
    def terms(self) -> int:
        """Length of the worst rounding-error accumulation chain."""
        return self.q_u + self.q_v + self.q_w + 2

    def error_bound(self, dtype: str = "bfloat16") -> float:
        """Relative first-order error bound ``growth * terms * u(dtype)``."""
        return float(self.growth) * float(self.terms) * dtype_eps(dtype)

    def within_budget(self, budget: float, dtype: str = "bfloat16") -> bool:
        return self.error_bound(dtype) <= budget


def analyze(l: LCMA) -> SchemeStability:
    """Compute the stability profile from the coefficient tensors alone."""
    aU = np.abs(l.U.astype(np.int64))
    aV = np.abs(l.V.astype(np.int64))
    aW = np.abs(l.W.astype(np.int64))
    return SchemeStability(
        name=l.name,
        alpha_u=int(aU.sum(axis=(1, 2)).max()),
        alpha_v=int(aV.sum(axis=(1, 2)).max()),
        alpha_w=int(aW.sum(axis=0).max()),
        q_u=int((aU > 0).sum(axis=(1, 2)).max()),
        q_v=int((aV > 0).sum(axis=(1, 2)).max()),
        q_w=int((aW > 0).sum(axis=0).max()),
        max_abs_coeff=int(max(aU.max(), aV.max(), aW.max())),
    )


def check_scheme_stability(l: LCMA, *, budget: float | None = None,
                           dtype: str = "bfloat16") -> list[Finding]:
    """Stability findings for one scheme.

    Always reports the bound as INFO; flags |c|>1 schemes as WARNING (their
    error bound exceeds every same-grid ternary scheme's — the class the
    PR 4 combine-magnitude bug hid); flags a budget violation as ERROR when
    the caller supplies an accuracy budget.
    """
    s = l.stability
    findings = [Finding(
        PASS, INFO, l.name,
        f"growth={s.growth} terms={s.terms} "
        f"error_bound({dtype})={s.error_bound(dtype):.3e}")]
    if s.max_abs_coeff > 1:
        findings.append(Finding(
            PASS, WARNING, l.name,
            f"coefficient magnitude {s.max_abs_coeff} > 1: error bound "
            f"{s.error_bound(dtype):.3e} ({dtype}) vs {s.growth}x magnitude "
            f"growth; exclude from low-precision serving unless budgeted"))
    if budget is not None and not s.within_budget(budget, dtype):
        findings.append(Finding(
            PASS, ERROR, l.name,
            f"error bound {s.error_bound(dtype):.3e} exceeds the accuracy "
            f"budget {budget:.3e} for {dtype}"))
    return findings


def check_library_stability(lib: dict[str, LCMA] | None = None, *,
                            budget: float | None = None,
                            dtype: str = "bfloat16") -> list[Finding]:
    if lib is None:
        from repro.core import algorithms
        lib = algorithms.library()
    findings: list[Finding] = []
    for _, l in sorted(lib.items()):
        findings.extend(check_scheme_stability(l, budget=budget, dtype=dtype))
    return findings


# ---------------------------------------------------------------------------
# int8 accumulator overflow bounds (kernels/quant_combine.py)
# ---------------------------------------------------------------------------

INT8_MAX = 127


def int8_accum_bound(depth: int) -> int:
    """Worst-case |partial sum| of ``depth`` int8 x int8 products."""
    return int(depth) * INT8_MAX * INT8_MAX


def max_safe_accum_depth(acc_bits: int = 32) -> int:
    """Largest reduction-block depth that cannot overflow the accumulator.

    ``acc_bits`` is the signed accumulator width (32 for the MXU int32 path).
    Exact: ``floor((2**(acc_bits-1) - 1) / 127**2)`` — 133144 for int32, so
    every MXU-aligned K-block (<= a few thousand) is safe by a wide margin,
    while an int16 accumulator (acc_bits=16) is unsafe beyond depth 2.
    """
    return (2 ** (int(acc_bits) - 1) - 1) // (INT8_MAX * INT8_MAX)


def check_quant_accumulator(depth: int, acc_bits: int = 32,
                            subject: str = "quant-accumulator") -> list[Finding]:
    """Flag a quantized-GEMM reduction block that can overflow its accumulator."""
    depth = int(depth)
    if depth < 1:
        return [Finding(PASS, ERROR, subject,
                        f"reduction depth must be >= 1, got {depth}")]
    safe = max_safe_accum_depth(acc_bits)
    if depth > safe:
        return [Finding(
            PASS, ERROR, subject,
            f"int8 reduction depth {depth} can overflow the int{acc_bits} "
            f"accumulator: worst-case |sum| = {int8_accum_bound(depth)} > "
            f"{2 ** (acc_bits - 1) - 1} (max safe depth {safe})")]
    return [Finding(
        PASS, INFO, subject,
        f"int8 depth {depth} safe for int{acc_bits}: worst-case |sum| "
        f"{int8_accum_bound(depth)} <= {2 ** (acc_bits - 1) - 1}")]
