"""Pass 1 — exact Brent-equation verification of LCMA schemes.

A scheme ``<m,k,n,R,U,V,W>`` multiplies matrices iff the Brent equations

    sum_r U[r,i,l] * V[r,l',j] * W[r,i',j'] = d(i,i') d(j,j') d(l,l')

hold for every index tuple — ``m*k * k*n * m*n`` polynomial identities over
the integers. Because every coefficient is an integer (``LCMA.__post_init__``
guarantees int8), the identities are decidable *exactly*: the residual tensor
is computed in int64 (no overflow: ``|residual| <= R * 127**3``, far below
2**63 for any scheme this library can represent) and compared to zero. No
float tolerance is involved, so a verified scheme is certified, not "close".

This is the promotion gate for machine-generated schemes: ``discovery.py``
candidates and ``algorithms.register()`` inputs both route through
:func:`verify_or_raise` before they can reach the dispatcher.
"""
from __future__ import annotations

import numpy as np

from repro.core.lcma import LCMA, matmul_tensor
from .findings import ERROR, Finding

__all__ = ["brent_residual", "check_scheme", "check_library", "verify_or_raise"]

PASS = "brent"


def brent_residual(l: LCMA) -> np.ndarray:
    """Exact integer residual ``T(U,V,W) - T_<m,k,n>``; zero iff valid.

    Axes are ``(i, l, l', j, i', j')`` — the first pair indexes A's block,
    the second B's, the third C's.
    """
    U = l.U.astype(np.int64)
    V = l.V.astype(np.int64)
    W = l.W.astype(np.int64)
    T = np.einsum("ria,rbj,rcd->iabjcd", U, V, W)
    return T - matmul_tensor(l.m, l.k, l.n)


def check_scheme(l: LCMA) -> list[Finding]:
    """Verify one scheme; findings name the violated Brent equations."""
    res = brent_residual(l)
    bad = np.argwhere(res != 0)
    if bad.size == 0:
        return []
    i, a, b, j, c, d = bad[0]
    worst = int(np.max(np.abs(res)))
    return [Finding(
        PASS, ERROR, l.name,
        f"{l.key}: {len(bad)}/{res.size} Brent equations violated "
        f"(first at A[{i},{a}] B[{b},{j}] C[{c},{d}]: residual "
        f"{int(res[i, a, b, j, c, d])}, worst |residual| {worst}); "
        f"the scheme does not compute <{l.m},{l.k},{l.n}> matmul")]


def check_library(lib: dict[str, LCMA] | None = None) -> list[Finding]:
    """Verify every scheme in the library (or a given name->LCMA mapping).

    The built-in library includes the output of every composition operator
    (``tensor_product``, ``concat_m/k/n``, ``cyclic``, ``transpose_dual``),
    so a clean run certifies both the elementary schemes and the closure
    constructions actually shipped.
    """
    if lib is None:
        from repro.core import algorithms
        lib = algorithms.library()
    findings: list[Finding] = []
    for name, l in sorted(lib.items()):
        findings.extend(check_scheme(l))
        if l.R >= l.m * l.k * l.n:
            findings.append(Finding(
                PASS, "warning", name,
                f"rank R={l.R} >= m*k*n={l.m * l.k * l.n}: no multiplication "
                f"saving (valid but never profitable)"))
    return findings


def verify_or_raise(l: LCMA, context: str = "") -> LCMA:
    """Exact verification as a gate: raises ``ValueError`` on any violation."""
    findings = check_scheme(l)
    if findings:
        where = f"{context}: " if context else ""
        raise ValueError(where + str(findings[0]))
    return l
