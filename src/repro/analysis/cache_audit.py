"""Pass 4 — plan-cache invariant audit.

A persisted plan cache (``core/plan_cache.py``) is a promise: "this Decision
was computed for exactly this (shape, dtype, hardware, policy) key and these
scheme definitions". The serving loader (`PlanCache.load` / `_decode`) is
deliberately permissive — a broken entry is *dropped*, never fatal — which is
right for production but wrong for CI: silently dropped plans are cold-start
regressions waiting to happen. This auditor reads the raw file (NOT through
`_decode`) and reports every invariant violation:

  * format/version and entry structure;
  * decisions naming schemes absent from the current library (dangling refs);
  * scheme-definition drift: entries carry the scheme's content fingerprint
    (``LCMA.fingerprint``, hashed over the coefficient tensors) and an entry
    whose fingerprint no longer matches the registered definition is stale —
    the plan priced a different algorithm than the one that would now run;
  * key/payload consistency: the shape token embedded in the key must match
    the decision's recorded shape, grouped keys must match ``B``/``shared_b``,
    sharded keys must name a known layout and the same device count;
  * hardware-fingerprint staleness against a given profile;
  * duplicate keys and non-finite / negative timings.
"""
from __future__ import annotations

import json
import math
import re

from repro.core.hardware import HardwareProfile

from .findings import ERROR, INFO, WARNING, Finding

__all__ = ["audit_cache_file", "audit_entries"]

PASS = "cache-audit"

_FORMAT_VERSION = 1
_HW_TOKEN = re.compile(r"^[^|@]+@[0-9a-f]{12}$")


def _shape_token(payload: dict) -> str:
    """Reconstruct the key's shape token from a decoded payload.

    ``plan_key`` is called as (M, K, N) and formats ``{M}x{K}x{N}``; the
    payload stores the Decision fields (M, N, K).
    """
    M, N, K = payload["M"], payload["N"], payload["K"]
    if "B" in payload:
        return f"g{payload['B']}x{M}x{K}x{N}|sb={int(bool(payload.get('shared_b')))}"
    return f"{M}x{K}x{N}"


def audit_entries(entries, *, hw: HardwareProfile | None = None,
                  subject: str = "plan-cache") -> list[Finding]:
    """Audit decoded ``[key, payload]`` pairs; see module docstring."""
    from repro.core import algorithms, decision as dec, plan_cache

    findings: list[Finding] = []
    lib = algorithms.library()
    seen: set[str] = set()
    for idx, item in enumerate(entries):
        if not (isinstance(item, (list, tuple)) and len(item) == 2
                and isinstance(item[0], str) and isinstance(item[1], dict)):
            findings.append(Finding(PASS, ERROR, subject,
                                    f"entry #{idx} is not a [key, payload] pair"))
            continue
        key, payload = item
        ksub = f"{subject}[{key}]"
        if key in seen:
            findings.append(Finding(PASS, ERROR, ksub, "duplicate cache key"))
        seen.add(key)

        # structural payload checks
        try:
            M, N, K = (int(payload[f]) for f in ("M", "N", "K"))
        except (KeyError, TypeError, ValueError):
            findings.append(Finding(PASS, ERROR, ksub,
                                    "payload lacks integer M/N/K fields"))
            continue
        if min(M, N, K) < 1:
            findings.append(Finding(PASS, ERROR, ksub,
                                    f"non-positive shape ({M}, {N}, {K})"))
        for f in ("gemm_seconds", "lcma_seconds", "coll_seconds"):
            v = payload.get(f)
            if v is not None and (not isinstance(v, (int, float))
                                  or not math.isfinite(v) or v < 0):
                findings.append(Finding(
                    PASS, ERROR, ksub, f"{f} = {v!r} is not a finite "
                    f"non-negative number"))

        # hardware token: first |-separated part is name@fingerprint
        parts = key.split("|")
        if not _HW_TOKEN.match(parts[0]):
            findings.append(Finding(
                PASS, ERROR, ksub,
                f"key does not start with a hardware token "
                f"(name@fingerprint12): {parts[0]!r}"))
        elif hw is not None:
            name, fp = parts[0].rsplit("@", 1)
            if name == hw.name and fp != plan_cache._profile_fingerprint(hw):
                findings.append(Finding(
                    PASS, WARNING, ksub,
                    f"hardware fingerprint {fp} is stale for profile "
                    f"{hw.name!r} (current "
                    f"{plan_cache._profile_fingerprint(hw)}); the machine "
                    f"was re-calibrated since this plan was priced"))

        # key shape token vs payload shape
        token = _shape_token(payload)
        if token not in parts:
            findings.append(Finding(
                PASS, ERROR, ksub,
                f"key shape token does not match payload: expected "
                f"{token!r} for (M={M}, N={N}, K={K})"))

        # scheme reference + definition drift
        algo = payload.get("algo")
        if algo is not None:
            l = lib.get(algo)
            if l is None:
                findings.append(Finding(
                    PASS, ERROR, ksub,
                    f"decision names scheme {algo!r} which is not in the "
                    f"current library (dangling reference; entry would be "
                    f"silently dropped at load)"))
            else:
                fp = payload.get("algo_fp")
                if fp is None:
                    findings.append(Finding(
                        PASS, INFO, ksub,
                        f"entry predates scheme fingerprinting; cannot prove "
                        f"{algo!r} is unchanged"))
                elif fp != l.fingerprint:
                    findings.append(Finding(
                        PASS, ERROR, ksub,
                        f"scheme {algo!r} definition changed since this plan "
                        f"was priced (entry fingerprint {fp}, current "
                        f"{l.fingerprint}); the plan is stale"))

        # sharded entries: known layout, device count consistent with key
        ly = payload.get("ly")
        if ly is not None:
            try:
                dec.layout_by_name(str(ly))
            except KeyError:
                findings.append(Finding(
                    PASS, ERROR, ksub,
                    f"decision records unknown shard layout {ly!r}"))
            m = re.search(r"\|ly=.*xD(\d+)@cb=", key)
            if m is None:
                findings.append(Finding(
                    PASS, ERROR, ksub,
                    "sharded decision but key has no ly=...xD<devices>@cb= "
                    "layout token"))
            elif int(m.group(1)) != int(payload.get("D", -1)):
                findings.append(Finding(
                    PASS, ERROR, ksub,
                    f"key was priced for D={m.group(1)} devices but the "
                    f"decision records D={payload.get('D')}"))
    return findings


def audit_cache_file(path: str, *, hw: HardwareProfile | None = None) -> list[Finding]:
    """Audit one persisted plan-cache JSON file."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        return [Finding(PASS, ERROR, path, f"unreadable: {e}")]
    except ValueError as e:
        return [Finding(PASS, ERROR, path, f"not valid JSON: {e}")]
    if not isinstance(doc, dict) or doc.get("version") != _FORMAT_VERSION:
        return [Finding(PASS, ERROR, path,
                        f"unknown cache format version "
                        f"{doc.get('version') if isinstance(doc, dict) else doc!r} "
                        f"(expected {_FORMAT_VERSION})")]
    entries = doc.get("entries", [])
    findings = audit_entries(entries, hw=hw, subject=path)
    findings.append(Finding(PASS, INFO, path,
                            f"audited {len(entries)} cache entries"))
    return findings
