"""falcon-check: static verification & lint for schemes, plans and caches.

Four passes, one currency (:class:`~repro.analysis.findings.Finding`):

  * ``brent``        — exact (integer, tolerance-free) verification of a
    scheme's Brent equations against the <m,k,n> matmul tensor;
  * ``stability``    — Higham-style floating-point error-growth bounds and
    int8 accumulator overflow bounds, computed from coefficients alone;
  * ``plan-lint`` / ``codegen-lint`` — kernel block plans checked against a
    hardware profile, and the Deployment Module's generated source re-derived
    at the AST level;
  * ``cache-audit``  — persisted plan-cache invariants (dangling schemes,
    definition drift, key/payload consistency).

CLI: ``python -m repro.tools.check`` (console script ``falcon-check``).
"""
from .findings import ERROR, INFO, WARNING, Finding, format_findings, has_errors
from .brent import brent_residual, check_library, check_scheme, verify_or_raise
from .stability import (SchemeStability, analyze, check_library_stability,
                        check_quant_accumulator, check_scheme_stability,
                        dtype_eps, int8_accum_bound, max_safe_accum_depth)
from .plans import (BACKEND_DTYPES, lint_block_plan, lint_codegen,
                    lint_quant_plans, lint_scheme_plans, lint_workload)
from .cache_audit import audit_cache_file, audit_entries

__all__ = [
    "Finding", "ERROR", "WARNING", "INFO", "has_errors", "format_findings",
    "brent_residual", "check_scheme", "check_library", "verify_or_raise",
    "SchemeStability", "analyze", "check_scheme_stability",
    "check_library_stability", "dtype_eps", "int8_accum_bound",
    "max_safe_accum_depth", "check_quant_accumulator",
    "lint_block_plan", "lint_scheme_plans", "lint_quant_plans",
    "lint_workload", "lint_codegen", "BACKEND_DTYPES",
    "audit_cache_file", "audit_entries",
]
