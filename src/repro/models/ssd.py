"""Mamba-2 SSD (state-space duality) mixer — chunked scan + decode recurrence.

Implements the SSD algorithm of Dao & Gu (2024): within-chunk quadratic
attention-like form + inter-chunk state recurrence. The in/out projections
are FalconGEMM-backed, and the chunk contractions themselves route through
``falcon.einsum`` — scores, diagonal-block output, chunk-end states and the
carried-state contribution are each ONE 2-operand grouped contraction over
``B * n_chunks * H`` (decay factors are folded into an operand elementwise
first), so the Decision Module prices the SSD scan like it prices attention.
The decode recurrence routes its two per-step contractions the same way.
Registry entries: ``kind="ssd_scan"`` / ``"ssd_decode"`` in
``core.workloads.contraction_set``.

Shapes: x (B, L, H, P) values; dt (B, L, H) step sizes; A (H,) decay rates;
B_, C_ (B, L, G, N) input/output projections with H % G == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.api as falcon
from repro.core import engine
from repro.parallel.sharding import BATCH, shard_act
from .layers import dense_init

__all__ = ["ssd_init", "ssd_apply", "ssd_decode_step", "ssd_scan"]


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < s <= i} a[..., s]."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x, dt, A, B_, C_, chunk: int, init_state=None):
    """Chunked SSD. Returns (y, final_state).

    x: (B, L, H, P); dt: (B, L, H); A: (H,); B_, C_: (B, L, G, N).
    state: (B, H, N, P).
    """
    Bb, L, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Lp = -(-L // chunk) * chunk
    if Lp != L:
        # zero-pad the tail: dt=0 => decay 1 and no state contribution, so
        # the final state equals the unpadded one; padded outputs are sliced.
        pad = Lp - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = Lp // chunk

    # fold dt into x (discretization) and build log-decay per step
    xdt = x * dt[..., None]
    a = (dt * (-jnp.exp(A))[None, None, :]).astype(jnp.float32)  # (B, L, H), negative

    def r(t, d):  # reshape into chunks
        return t.reshape((Bb, nc, chunk) + t.shape[2:])

    xc, ac = r(xdt, 3), r(a, 3)
    Bc, Cc = r(B_, 4), r(C_, 4)
    Bh = jnp.repeat(Bc, rep, axis=3).astype(jnp.float32)  # (B, nc, c, H, N)
    Ch = jnp.repeat(Cc, rep, axis=3).astype(jnp.float32)
    xc = xc.astype(jnp.float32)

    ac_t = ac.transpose(0, 1, 3, 2)              # (B, nc, H, c)
    Lmat = jnp.exp(_segsum(ac_t))                # (B, nc, H, c, c)
    # intra-chunk (diagonal block) output: each einsum below is a planned
    # grouped contraction over B*nc*H (registry kind "ssd_scan")
    scores = falcon.einsum("bnihs,bnjhs->bnhij", Ch, Bh)  # (B, nc, H, c, c)
    y_diag = falcon.einsum("bnhij,bnjhp->bnihp", scores * Lmat, xc)

    # chunk-end states: decay from position j to the end of its chunk,
    # folded into B elementwise so states is one 2-operand contraction
    decay_to_end = jnp.exp(jnp.sum(ac_t, -1, keepdims=True) - jnp.cumsum(ac_t, -1))
    Bw = Bh * decay_to_end.transpose(0, 1, 3, 2)[..., None]   # (B, nc, c, H, N)
    # states[n] = sum_j decay_to_end[j] * B[j] x[j]   -> (B, nc, H, N, P)
    states = falcon.einsum("bnjhs,bnjhp->bnhsp", Bw, xc)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(jnp.sum(ac_t, axis=-1))  # (B, nc, H)
    s0 = (jnp.zeros((Bb, H, N, Pd), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(s, inp):
        st, dk = inp  # (B, H, N, P), (B, H)
        s_new = s * dk[..., None, None] + st
        return s_new, s

    (s_final, prev_states) = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, N, P)

    # contribution of the carried-in state to each position: fold the
    # from-chunk-start decay into C elementwise, then one contraction
    decay_from_start = jnp.exp(jnp.cumsum(ac_t, -1))    # (B, nc, H, c)
    Cw = Ch * decay_from_start.transpose(0, 1, 3, 2)[..., None]
    y_off = falcon.einsum("bnihs,bnhsp->bnihp", Cw, prev_states)

    y = (y_diag + y_off).reshape(Bb, Lp, H, Pd)[:, :L].astype(x.dtype)
    return y, s_final.astype(x.dtype)


def ssd_decode_step(x, dt, A, B_, C_, state):
    """Single-token recurrence. x: (B,1,H,P); state: (B,H,N,P)."""
    a = jnp.exp(dt[:, 0] * (-jnp.exp(A))[None, :])        # (B, H)
    G = B_.shape[2]
    rep = x.shape[2] // G
    Bh = jnp.repeat(B_[:, 0], rep, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(C_[:, 0], rep, axis=1).astype(jnp.float32)
    xdt = (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)
    # the state update (outer product) and readout are planned grouped
    # contractions over B*H (registry kind "ssd_decode")
    new_state = (state.astype(jnp.float32) * a[..., None, None]
                 + falcon.einsum("bhs,bhp->bhsp", Bh, xdt))
    y = falcon.einsum("bhs,bhsp->bhp", Ch, new_state)
    return y[:, None].astype(x.dtype), new_state.astype(x.dtype)


def ssd_init(key, d_model: int, ssm_state: int, n_heads: int, head_dim: int,
             n_groups: int, dtype) -> dict:
    d_inner = n_heads * head_dim
    ki, ko, kd = jax.random.split(key, 3)
    # in_proj packs [z (d_inner gate) | x (d_inner) | B (G*N) | C (G*N) | dt (H)]
    d_in_proj = 2 * d_inner + 2 * n_groups * ssm_state + n_heads
    return {
        "ssm_in": dense_init(ki, d_model, d_in_proj, dtype),
        "ssm_out": dense_init(ko, d_inner, d_model, dtype),
        "ssm_A": jnp.zeros((n_heads,), jnp.float32),       # log decay init ~ 1
        "ssm_D": jnp.ones((n_heads,), jnp.float32),
        "ssm_dt_bias": jnp.zeros((n_heads,), jnp.float32),
    }


def ssd_apply(p: dict, x: jnp.ndarray, cfg,
              fcfg: falcon.FalconConfig | None = None,
              state=None, decode: bool = False, length_mask=None):
    """x: (B, L, d_model) -> (y, new_state).

    Dispatch policy comes from the context config; ``fcfg`` is a deprecated
    per-call override. ``length_mask`` (B, L) zeroes dt on padded positions
    (dt=0 => decay 1, no state contribution — the same trick the chunked
    scan uses for its tail padding), so right-padded serve prefill produces
    the exact unpadded final state.
    """
    with engine.deprecated_fcfg(fcfg, "ssd_apply"):
        return _ssd_apply(p, x, cfg, state=state, decode=decode,
                          length_mask=length_mask)


def _ssd_apply(p: dict, x: jnp.ndarray, cfg, state=None, decode: bool = False,
               length_mask=None):
    B, L, _ = x.shape
    H, Pd, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    proj = falcon.dense(x, p["ssm_in"])
    d_inner = H * Pd
    z = shard_act(proj[..., :d_inner], BATCH, None, "model")   # gate branch
    off = d_inner
    xs = shard_act(proj[..., off:off + d_inner].reshape(B, L, H, Pd),
                   BATCH, None, "model", None)
    off += d_inner
    B_ = proj[..., off:off + G * N].reshape(B, L, G, N)
    off += G * N
    C_ = proj[..., off:off + G * N].reshape(B, L, G, N)
    off += G * N
    dt = jax.nn.softplus(proj[..., off:].astype(jnp.float32)
                         + p["ssm_dt_bias"][None, None])       # (B, L, H)
    if length_mask is not None:
        dt = dt * length_mask.astype(jnp.float32)[..., None]
    if decode:
        y, new_state = ssd_decode_step(xs, dt, p["ssm_A"], B_, C_, state)
    else:
        y, new_state = ssd_scan(xs, dt, p["ssm_A"], B_, C_, cfg.ssm_chunk,
                                init_state=state)
    y = y + xs * p["ssm_D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, L, d_inner) * jax.nn.silu(z)  # mamba2 output gate
    y = falcon.dense(y, p["ssm_out"])
    return shard_act(y, BATCH, None, None), new_state
