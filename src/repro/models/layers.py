"""Core transformer layers (functional, pytree params, FalconGEMM-backed).

Every dense projection routes through ``falcon_dense`` and the attention
contractions through ``falcon.einsum``, so the paper's technique is a
first-class backend of the whole model zoo. Dispatch policy is the
context-scoped config (``repro.api.use``); the legacy per-call ``fcfg``
argument survives as a deprecated override. ``shards`` in the active config
reflects each matmul's sharding so the Decision Module prices the
*per-device* problem.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.falcon_gemm import FalconConfig, falcon_dense
from repro.parallel.sharding import BATCH, shard_act

# ---------------------------------------------------------------------------
# Param helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, flash-style chunking)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask(qpos, kpos, window):
    """Causal + optional sliding-window mask. window: traced scalar (0 = off)."""
    causal = kpos[None, :] <= qpos[:, None]
    in_window = jnp.where(window > 0, kpos[None, :] > qpos[:, None] - window, True)
    return causal & in_window


def attention_scores(q, k, v, qpos, kpos, window, kv_valid=None,
                     ragged: bool = False):
    """Direct attention. q: (B,Sq,H,hd) k,v: (B,Sk,Hkv,hd) -> (B,Sq,H,hd).

    GQA is realized by repeating K/V up to H heads rather than grouping Q
    down to Hkv: the full H dim stays intact so its "model"-axis sharding
    survives (grouping H -> (Hkv, rep) with Hkv < model-parallelism would
    force XLA to replicate the (B,H,Sq,Sk) score tensor — catastrophic at
    32k context).

    ``ragged=True`` builds the mask per batch row (positions differ across
    the batch — continuous-batching decode where every slot sits at its own
    offset). The uniform path keeps the (Sq, Sk) mask so 32k-context cells
    never materialize a per-batch mask they don't need.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = engine.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(hd)
    if ragged:
        m = jax.vmap(lambda qp, kp: _mask(qp, kp, window))(qpos, kpos)
        if kv_valid is not None:
            m = m & kv_valid[:, None, :]
        logits = jnp.where(m[:, None], logits, NEG_INF)
    else:
        m = _mask(qpos[0], kpos[0], window)  # positions identical across batch
        if kv_valid is not None:
            m = m & kv_valid[0][None, :]
        logits = jnp.where(m[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return engine.einsum("bhqk,bkhd->bqhd", p, v)


def flash_attention(q, k, v, qpos, kpos, window, kv_valid=None,
                    q_chunk: int = 512, ragged: bool = False):
    """Memory-bounded attention: scan over query chunks.

    Keeps the score tensor at (B, H, q_chunk, Sk) — required to compile the
    32k/500k cells without materializing S^2 scores.
    """
    B, Sq, H, hd = q.shape
    if Sq <= q_chunk:
        return attention_scores(q, k, v, qpos, kpos, window, kv_valid=kv_valid,
                                ragged=ragged)
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    nc = Sq // q_chunk
    qc = q.reshape(B, nc, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = qpos.reshape(B, nc, q_chunk).transpose(1, 0, 2)

    @jax.checkpoint  # never store the (B,H,qc,Sk) score tensor for bwd
    def chunk_attn(qi, pi, kk, vv):
        return attention_scores(qi, kk, vv, pi, kpos, window, kv_valid=kv_valid,
                                ragged=ragged)

    def body(carry, xs):
        qi, pi = xs
        return carry, chunk_attn(qi, pi, k, v)

    _, outs = jax.lax.scan(body, None, (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int


def attn_init(key, dims: AttnDims, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    H, Hkv, hd, d = dims.num_heads, dims.num_kv_heads, dims.head_dim, dims.d_model
    return {
        "w_q": dense_init(kq, d, H * hd, dtype),
        "w_k": dense_init(kk, d, Hkv * hd, dtype),
        "w_v": dense_init(kv, d, Hkv * hd, dtype),
        "w_o": dense_init(ko, H * hd, d, dtype),
    }


def attn_apply(p: dict, x: jnp.ndarray, dims: AttnDims, positions, theta: float,
               window, fcfg: FalconConfig | None = None,
               cache: dict | None = None, cache_index=None):
    """Attention with optional KV cache.

    prefill/train: cache=None -> self-attention over x.
    decode: cache={'k','v'} (B, S_max, Hkv, hd); x is (B, 1, d) at
    ``cache_index``; returns (out, new_cache).

    ``cache_index`` may be a scalar (all rows at the same offset — the
    one-shot serve path) or a (B,) vector of per-row offsets (continuous
    batching: each slot decodes at its own position; K/V writes, validity
    and the causal mask are then applied per row).

    Dispatch policy comes from the context config; ``fcfg`` is a deprecated
    per-call override.
    """
    with engine.deprecated_fcfg(fcfg, "attn_apply"):
        B, S, d = x.shape
        H, Hkv, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
        q = shard_act(falcon_dense(x, p["w_q"]).reshape(B, S, H, hd),
                      BATCH, None, "model")
        k = shard_act(falcon_dense(x, p["w_k"]).reshape(B, S, Hkv, hd),
                      BATCH, None, "model")
        v = shard_act(falcon_dense(x, p["w_v"]).reshape(B, S, Hkv, hd),
                      BATCH, None, "model")
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
        if cache is None:
            out = flash_attention(q, k, v, positions, positions, window)
            new_cache = None
        else:
            idx = jnp.asarray(cache_index)
            ragged = idx.ndim == 1
            if ragged:
                def upd(c, u, i):
                    return jax.vmap(
                        lambda cr, ur, ir: jax.lax.dynamic_update_slice(
                            cr, ur.astype(cr.dtype), (ir, 0, 0)))(c, u, i)
                ck = upd(cache["k"], k, idx)
                cv = upd(cache["v"], v, idx)
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
            S_max = ck.shape[1]
            kpos = jnp.broadcast_to(jnp.arange(S_max)[None], (B, S_max))
            # everything written so far (prompt prefill writes S tokens at once)
            kv_valid = kpos < (idx[:, None] if ragged else idx) + S
            out = flash_attention(q, ck, cv, positions, kpos, window,
                                  kv_valid=kv_valid, ragged=ragged)
            new_cache = {"k": ck, "v": cv}
        out = falcon_dense(out.reshape(B, S, H * hd), p["w_o"])
        return shard_act(out, BATCH, None, None), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype, mlp_type: str = "swiglu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type == "gelu":  # classic 2-matrix MLP (starcoder2, musicgen)
        return {
            "mlp_up": dense_init(k2, d, d_ff, dtype),
            "mlp_down": dense_init(k3, d_ff, d, dtype),
        }
    return {
        "mlp_gate": dense_init(k1, d, d_ff, dtype),
        "mlp_up": dense_init(k2, d, d_ff, dtype),
        "mlp_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray,
              fcfg: FalconConfig | None = None) -> jnp.ndarray:
    with engine.deprecated_fcfg(fcfg, "mlp_apply"):
        u = shard_act(falcon_dense(x, p["mlp_up"]), BATCH, None, "model")
        if "mlp_gate" in p:
            g = shard_act(falcon_dense(x, p["mlp_gate"]), BATCH, None, "model")
            h = jax.nn.silu(g) * u
        else:
            h = jax.nn.gelu(u)
        out = falcon_dense(h, p["mlp_down"])
        return shard_act(out, BATCH, None, None)
