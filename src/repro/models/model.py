"""Unified decoder LM covering all assigned families.

Families: dense (granite/starcoder2/mistral-nemo/gemma3 local-global),
moe (kimi-k2/dbrx), ssm (mamba2 SSD), hybrid (hymba: parallel attn+SSM),
audio (musicgen codebook streams), vlm (pixtral stub patch prefix).

Layers are scanned (``jax.lax.scan`` over stacked params) so the HLO stays
compact for 1T-parameter dry-runs; per-layer attention windows (gemma3's 5:1
local:global pattern) ride along as scan xs so the traced graph is uniform.
All projections are FalconGEMM-dispatched.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.falcon_gemm import FalconConfig, falcon_dense
from repro.parallel.sharding import BATCH, shard_act
from repro.configs.base import ModelConfig
from . import layers as L
from . import moe as MOE
from . import ssd as SSD

__all__ = ["init_params", "forward", "init_cache", "falcon_config_for",
           "chunked_xent", "lm_loss"]


def falcon_config_for(cfg: ModelConfig, mesh_shape: dict | None = None) -> FalconConfig:
    """Build the FalconGEMM policy for this model; per-device decision scaling
    comes from the model-parallel degree (activations sharded on batch=M,
    weights on N or K)."""
    model_par = (mesh_shape or {}).get("model", 1)
    data_par = (mesh_shape or {}).get("data", 1) * (mesh_shape or {}).get("pod", 1)
    if cfg.parallel_style == "fsdp_only":
        # no TP: weights are gathered for compute; only batch (M) is sharded
        data_par, model_par = data_par * model_par, 1
    return FalconConfig(
        enabled=cfg.use_falcon,
        mode=cfg.falcon_mode,
        backend=cfg.falcon_backend,
        shards=(data_par, 1, model_par),
    )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)
    p: dict = {"ln1": L.rmsnorm_init(cfg.d_model, dt)}
    if cfg.family == "ssm":
        p["ssm"] = SSD.ssd_init(keys[0], cfg.d_model, cfg.ssm_state,
                                cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, dt)
        return p
    dims = L.AttnDims(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.resolved_head_dim)
    if cfg.family == "hybrid":
        p["attn"] = L.attn_init(keys[0], dims, dt)
        p["ssm"] = SSD.ssd_init(keys[1], cfg.d_model, cfg.ssm_state,
                                cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, dt)
        p["attn_norm"] = L.rmsnorm_init(cfg.d_model, dt)
        p["ssm_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    else:
        p["attn"] = L.attn_init(keys[0], dims, dt)
    p["ln2"] = L.rmsnorm_init(cfg.d_model, dt)
    if cfg.family == "moe":
        p["moe"] = MOE.moe_init(keys[2], cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
    elif cfg.d_ff > 0:
        p["mlp"] = L.mlp_init(keys[2], cfg.d_model, cfg.d_ff, dt, cfg.mlp_type)
    return p


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so logits shard over any TP degree
    (non-divisible vocabs like granite's 49155 would otherwise replicate the
    whole logits computation across the model axis — measured 16x waste)."""
    return -(-cfg.vocab_size // 256) * 256


def init_params(cfg: ModelConfig, key) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    Vp = padded_vocab(cfg)
    params: dict = {}
    if cfg.frontend == "audio_codebooks":
        params["embed"] = (jax.random.normal(
            ke, (cfg.num_codebooks, Vp, cfg.d_model), jnp.float32)
            * 0.02).astype(dt)
    else:
        params["embed"] = (jax.random.normal(
            ke, (Vp, cfg.d_model), jnp.float32) * 0.02).astype(dt)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    per_layer = [_layer_init(k, cfg) for k in layer_keys]
    params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    if cfg.frontend == "audio_codebooks":
        params["lm_head"] = (jax.random.normal(
            kh, (cfg.num_codebooks, cfg.d_model, Vp), jnp.float32)
            / np.sqrt(cfg.d_model)).astype(dt)
    elif not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, Vp, dt)
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    Lc = cfg.num_layers
    cache: dict = {}
    if cfg.family in ("dense", "moe", "hybrid", "audio", "vlm"):
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((Lc, batch, max_len, hkv, hd), dt)
        cache["v"] = jnp.zeros((Lc, batch, max_len, hkv, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        cache["state"] = jnp.zeros(
            (Lc, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), dt)
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens, patch_embeds=None):
    if cfg.frontend == "audio_codebooks":
        # params["embed"]: (CB, V, d); tokens: (B, S, CB) — sum codebook embeds
        x = 0.0
        for c in range(cfg.num_codebooks):
            x = x + jnp.take(params["embed"][c], tokens[..., c], axis=0)
        return x
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, S, d)
    if cfg.frontend == "vision_patches" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def _layer_body(x, lp, window, cfg: ModelConfig, positions, theta,
                cache_layer=None, cache_index=None, length_mask=None):
    """One decoder layer. Returns (x, new_cache_layer, aux).

    ``length_mask`` (B, S) marks real (1) vs right-pad (0) positions; SSD
    mixers zero dt on pad so the recurrent state ignores the padded tail
    (attention is already exact under causal masking + decode validity).
    """
    dims = None if cfg.family == "ssm" else L.AttnDims(
        cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim)
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    # SSD runs its recurrence only for true single-token decode; multi-token
    # prefill with a cache uses the chunked scan and stores the final state.
    is_decode = cache_layer is not None and h.shape[1] == 1
    if cfg.family == "ssm":
        st = None if cache_layer is None else cache_layer.get("state")
        y, new_state = SSD.ssd_apply(lp["ssm"], h, cfg, state=st,
                                     decode=is_decode, length_mask=length_mask)
        if cache_layer is not None:
            new_cache["state"] = new_state
        return x + y, new_cache, aux
    if cfg.family == "hybrid":
        kv = None if cache_layer is None else {"k": cache_layer["k"], "v": cache_layer["v"]}
        ya, kv_new = L.attn_apply(lp["attn"], h, dims, positions, theta, window,
                                  cache=kv, cache_index=cache_index)
        st = None if cache_layer is None else cache_layer.get("state")
        ys, new_state = SSD.ssd_apply(lp["ssm"], h, cfg, state=st,
                                      decode=is_decode,
                                      length_mask=length_mask)
        y = 0.5 * (L.rmsnorm(ya, lp["attn_norm"], cfg.norm_eps)
                   + L.rmsnorm(ys, lp["ssm_norm"], cfg.norm_eps))
        x = x + y
        if cache_layer is not None:
            new_cache = {"k": kv_new["k"], "v": kv_new["v"], "state": new_state}
    else:
        kv = None if cache_layer is None else {"k": cache_layer["k"], "v": cache_layer["v"]}
        y, kv_new = L.attn_apply(lp["attn"], h, dims, positions, theta, window,
                                 cache=kv, cache_index=cache_index)
        x = x + y
        if cache_layer is not None:
            new_cache = {"k": kv_new["k"], "v": kv_new["v"]}
    if cfg.parallel_block:
        # PaLM-style parallel block: the FFN reads ln1(x) like attention, and
        # the residual x + y_attn + y_ffn lets XLA's AllReduceReassociate
        # merge the two TP all-reduces into one (AR(a)+AR(b) -> AR(a+b)).
        h2 = h
    else:
        h2 = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from repro.core.workloads import moe_capacity
        T = int(np.prod(h2.shape[:-1]))
        cap = moe_capacity(T, cfg.experts_per_token, cfg.num_experts,
                           cfg.capacity_factor, shard_round=True)
        y2, aux = MOE.moe_apply(lp["moe"], h2, cfg.experts_per_token,
                                cfg.capacity_factor,
                                deterministic_capacity=cap)
    elif cfg.d_ff > 0:
        y2 = L.mlp_apply(lp["mlp"], h2)
    else:
        y2 = jnp.zeros_like(x)
    return x + y2, new_cache, aux


def forward(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
            cache=None, cache_index=None, fcfg: FalconConfig | None = None,
            logits_mode: str = "none", length_mask=None):
    """Run the decoder stack.

    logits_mode: "none" (return hidden), "last" (logits of final position),
    "all" (full logits — small vocab / smoke only; training uses
    ``lm_loss`` with chunked cross-entropy instead).
    ``length_mask`` (B, S): 1 on real positions, 0 on right pad — makes
    bucketed (right-padded) prefill exact for SSM/hybrid recurrent state.
    Returns (out, new_cache, aux_loss).

    FalconGEMM policy resolves from the ambient context (``falcon.use``),
    falling back to this model's ``falcon_config_for``; ``fcfg`` is a
    deprecated per-call override.
    """
    with engine.config_scope(fcfg, "forward", lambda: falcon_config_for(cfg)):
        return _forward(params, cfg, tokens, patch_embeds=patch_embeds,
                        cache=cache, cache_index=cache_index,
                        logits_mode=logits_mode, length_mask=length_mask)


def _forward(params, cfg: ModelConfig, tokens, *, patch_embeds=None,
             cache=None, cache_index=None, logits_mode: str = "none",
             length_mask=None):
    x = shard_act(_embed_tokens(params, cfg, tokens, patch_embeds),
                  BATCH, None, None)
    B, S = x.shape[0], x.shape[1]
    if cache is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        idx = jnp.asarray(cache_index)
        if idx.ndim == 1:       # per-row offsets (continuous-batching decode)
            positions = idx[:, None] + jnp.arange(S)[None]
        else:
            positions = jnp.broadcast_to(idx[None, None], (B, S)) \
                + jnp.arange(S)[None]
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    theta = cfg.rope_theta

    def body(carry, xs):
        x, aux = carry
        if cache is None:
            lp, w = xs
            cl = None
        else:
            lp, w, cl = xs
        fn = lambda x_: _layer_body(x_, lp, w, cfg, positions, theta,
                                    cache_layer=cl, cache_index=cache_index,
                                    length_mask=length_mask)
        if cfg.remat and cache is None:
            if cfg.remat_policy == "dots":
                # selective: keep matmul outputs, recompute elementwise ops —
                # ~3.1x fwd-flops multiplier instead of 4x at modest memory
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                x, nc, a = jax.checkpoint(fn, policy=policy)(x)
            else:
                x, nc, a = jax.checkpoint(fn)(x)
        else:
            x, nc, a = fn(x)
        return (shard_act(x, BATCH, None, None), aux + a), nc

    xs = (params["layers"], windows) if cache is None else (params["layers"], windows, cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)

    if logits_mode == "none":
        return x, new_cache, aux
    if logits_mode == "last":
        x = x[:, -1:]
    logits = compute_logits(params, cfg, x)
    return logits, new_cache, aux


def compute_logits(params, cfg: ModelConfig, x, fcfg: FalconConfig | None = None):
    with engine.config_scope(fcfg, "compute_logits",
                             lambda: falcon_config_for(cfg)):
        return _compute_logits(params, cfg, x)


def _compute_logits(params, cfg: ModelConfig, x):
    Vp = padded_vocab(cfg)

    def mask_pad(logits):
        if Vp == cfg.vocab_size:
            return logits
        pad_mask = jnp.arange(Vp) < cfg.vocab_size
        return jnp.where(pad_mask, logits, -1e30)

    if cfg.frontend == "audio_codebooks":
        outs = [falcon_dense(x, params["lm_head"][c])
                for c in range(cfg.num_codebooks)]
        return mask_pad(jnp.stack(outs, axis=2))  # (B, S, CB, Vp)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    return mask_pad(falcon_dense(x, w))


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy: never materialize (B, S, V) for big vocabs)
# ---------------------------------------------------------------------------

def chunked_xent(params, cfg: ModelConfig, hidden, labels,
                 fcfg: FalconConfig | None = None, chunk: int = 512):
    """hidden: (B, S, d); labels: (B, S[, CB]) -> mean xent (f32)."""
    with engine.config_scope(fcfg, "chunked_xent",
                             lambda: falcon_config_for(cfg)):
        return _chunked_xent(params, cfg, hidden, labels, chunk=chunk)


def _chunked_xent(params, cfg: ModelConfig, hidden, labels, chunk: int = 512):
    B, S = hidden.shape[0], hidden.shape[1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    hs = hidden.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape((B, nc, chunk) + labels.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, labels.ndim + 1)))

    @jax.checkpoint  # recompute per-chunk logits in bwd: (B,chunk,V) never stored
    def chunk_loss(h, lab):
        logits = _compute_logits(params, cfg, h).astype(jnp.float32)
        logits = shard_act(logits, BATCH, None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, xs):
        h, lab = xs
        return acc + chunk_loss(h, lab), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    denom = np.prod(labels.shape)
    return total / denom


def lm_loss(params, cfg: ModelConfig, batch: dict, fcfg: FalconConfig | None = None):
    """batch: {'tokens', 'labels'[, 'patch_embeds']} -> (loss, metrics)."""
    with engine.config_scope(fcfg, "lm_loss", lambda: falcon_config_for(cfg)):
        hidden, _, aux = forward(params, cfg, batch["tokens"],
                                 patch_embeds=batch.get("patch_embeds"),
                                 logits_mode="none")
        labels = batch["labels"]
        if cfg.frontend == "vision_patches":
            hidden = hidden[:, -labels.shape[1]:]  # loss on the text positions
        xent = chunked_xent(params, cfg, hidden, labels)
        loss = xent + 0.01 * aux
        return loss, {"xent": xent, "aux": aux}
