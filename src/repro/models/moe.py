"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

Two dispatch paths:

  * ``_moe_shardmap`` (production, used whenever a mesh with a "model" axis is
    active): experts are sharded over "model", tokens over ("pod","data").
    Inside ``jax.shard_map`` each device routes its *local* tokens to its
    *local* experts — the dispatch scatter never crosses devices, the only
    collectives are an (T_loc, E) router-logit all-gather and the final psum
    that sums each token's k expert contributions across the EP shards.
    GSPMD is never asked to partition a giant scatter (which it does by
    replication — measured 1.1 TB/device on kimi-k2 before this path).

  * ``_moe_dense`` (fallback without a mesh: CPU smoke tests, examples).

Per-expert projections execute as **grouped batched FalconGEMM**
(``engine.grouped_matmul``): the E experts' capacity-C token blocks are one
planned grouped contraction — the Decision Module prices the whole
``E x (C, K) @ (K, N)`` group (``plan_batched``, one plan-cache key) and the
backend runs the R*E intermediate products as a single grouped GEMM, instead
of E unplanned small GEMMs under ``vmap``. Expert weights may be lifted to
stacked :class:`~repro.core.engine.PlannedWeight`\\ s
(``falcon.precombine_params``) so serving never pays Combine B.
``engine.warm_buckets`` pre-plans the grouped expert shapes per bucket.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import engine
from repro.core.falcon_gemm import FalconConfig
from repro.core.workloads import moe_capacity
from repro.parallel.sharding import resolve_batch_axes
from .layers import dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d: int, d_ff: int, num_experts: int, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d, num_experts, dtype),
        "moe_gate": (jax.random.normal(kg, (num_experts, d, d_ff), jnp.float32)
                     / np.sqrt(d)).astype(dtype),
        "moe_up": (jax.random.normal(ku, (num_experts, d, d_ff), jnp.float32)
                   / np.sqrt(d)).astype(dtype),
        "moe_down": (jax.random.normal(kd, (num_experts, d_ff, d), jnp.float32)
                     / np.sqrt(d_ff)).astype(dtype),
    }


def _expert_ffn(p_gate, p_up, p_down, xb: jnp.ndarray) -> jnp.ndarray:
    """xb: (E, C, d) -> (E, C, d). Grouped per-expert SwiGLU.

    Each projection is ONE planned grouped contraction over all E experts
    (weights may be raw ``(E, K, N)`` arrays or stacked PlannedWeights) —
    the group-parallel replacement for the old ``vmap``'d 2-D core.
    """
    g = engine.grouped_matmul(xb, p_gate)
    u = engine.grouped_matmul(xb, p_up)
    return engine.grouped_matmul(jax.nn.silu(g) * u, p_down)


def _route(xt, router_logits, top_k):
    probs = jax.nn.softmax(router_logits, axis=-1)            # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def _aux_loss(probs, expert_idx, E):
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    return E * jnp.sum(me * ce)


def _dispatch_compute_combine(xt, probs, gate_vals, expert_idx, C, p_gate,
                              p_up, p_down, E_local, e_offset):
    """Token-local dispatch into (E_local, C, d), FFN, weighted combine.

    Per-slot loop (k is small) so no (T*k, d) token replication is ever
    materialized.
    """
    T, d = xt.shape
    top_k = expert_idx.shape[1]
    e_rel = expert_idx - e_offset
    local = (e_rel >= 0) & (e_rel < E_local)
    e_rel = jnp.clip(e_rel, 0, E_local - 1)
    oh = jax.nn.one_hot(e_rel, E_local, dtype=jnp.int32) * local[..., None].astype(jnp.int32)
    flat = oh.reshape(T * top_k, E_local)
    pos_all = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(pos_all * flat, axis=-1).reshape(T, top_k)
    keep = local & (pos < C)

    buf = jnp.zeros((E_local, C, d), xt.dtype)
    for s in range(top_k):
        w = keep[:, s].astype(xt.dtype)[:, None]
        buf = buf.at[e_rel[:, s], jnp.where(keep[:, s], pos[:, s], C - 1)].add(
            xt * w, mode="drop")

    yb = _expert_ffn(p_gate, p_up, p_down, buf)                # (E_local, C, d)

    y = jnp.zeros_like(xt)
    for s in range(top_k):
        contrib = yb[e_rel[:, s], jnp.where(keep[:, s], pos[:, s], C - 1)]
        w = (gate_vals[:, s] * keep[:, s].astype(gate_vals.dtype)).astype(xt.dtype)
        y = y + contrib * w[:, None]
    return y


def _moe_dense(p, x, top_k, C):
    B, S, d = x.shape
    E = p["router"].shape[1]
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs, gate_vals, expert_idx = _route(xt, logits, top_k)
    y = _dispatch_compute_combine(xt, probs, gate_vals, expert_idx, C,
                                  p["moe_gate"], p["moe_up"], p["moe_down"],
                                  E_local=E, e_offset=0)
    return y.reshape(B, S, d), _aux_loss(probs, expert_idx, E)


def _shard_operand(w):
    """(array, in_spec, rebuild) for one stacked expert operand.

    ``shard_map`` in_specs take arrays, so PlannedWeights are unbundled at
    the boundary: the kept raw weight (E, K, N) — or, for keep_weight=False
    precombines, the stacked B̃ (E, R, K/k, N/n) — is what crosses into the
    body, sharded on the leading expert dim. ``rebuild`` re-wraps the local
    B̃ slice back into a PlannedWeight inside the body, so dropping the raw
    weights (the point of keep_weight=False: half the expert HBM) no longer
    forfeits the expert-parallel path.
    """
    if isinstance(w, engine.PlannedWeight):
        if w.w is not None:
            arr = w.w            # body re-plans the local grouped shapes
            rebuild = lambda loc: loc  # noqa: E731
        elif w.bt is not None:
            arr = w.bt           # offline Combine B̃ shards like the weight
            rebuild = lambda loc, _pw=w: engine.PlannedWeight(  # noqa: E731
                w=None, bt=loc, algo=_pw.algo, k=_pw.k, n=_pw.n)
        else:
            raise ValueError(
                "MoE expert-parallel (shard_map) path got a PlannedWeight "
                "with neither raw weights nor a precombined B̃")
        return arr, P("model", *([None] * (arr.ndim - 1))), rebuild
    return w, P("model", None, None), lambda loc: loc


def _moe_shardmap(p, x, top_k, C_global, mesh):
    B, S, d = x.shape
    E = p["router"].shape[1]
    names = set(mesh.axis_names)
    # use all present batch axes only if B divides them
    present = tuple(a for a in resolve_batch_axes() if a in names)
    dp = int(np.prod([dict(mesh.shape)[a] for a in present])) if present else 1
    dp_axes = present if (present and B % dp == 0) else ()
    dp = int(np.prod([dict(mesh.shape)[a] for a in dp_axes])) if dp_axes else 1
    nm = dict(mesh.shape).get("model", 1)
    E_local = E // nm
    C_local = max(int(np.ceil(C_global / dp)), 8)

    xspec = P(dp_axes if dp_axes else None, None, None)
    wg_arr, wg_spec, wg_rb = _shard_operand(p["moe_gate"])
    wu_arr, wu_spec, wu_rb = _shard_operand(p["moe_up"])
    wd_arr, wd_spec, wd_rb = _shard_operand(p["moe_down"])

    def body(x_loc, router_loc, wg, wu, wd):
        Bl, Sl, _ = x_loc.shape
        xt = x_loc.reshape(Bl * Sl, d)
        # local router slice -> all-gather logits over the EP axis
        logits_loc = xt.astype(jnp.float32) @ router_loc.astype(jnp.float32)
        logits = jax.lax.all_gather(logits_loc, "model", axis=1, tiled=True)
        probs, gate_vals, expert_idx = _route(xt, logits, top_k)
        midx = jax.lax.axis_index("model")
        y = _dispatch_compute_combine(
            xt, probs, gate_vals, expert_idx, C_local,
            wg_rb(wg), wu_rb(wu), wd_rb(wd),
            E_local=E_local, e_offset=midx * E_local)
        # sum each token's k expert contributions across EP shards
        y = jax.lax.psum(y, "model")
        aux = _aux_loss(probs, expert_idx, E)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(Bl, Sl, d), aux

    out, aux = compat.shard_map(
        body,
        in_specs=(xspec, P(None, "model"), wg_spec, wu_spec, wd_spec),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, p["router"], wg_arr, wu_arr, wd_arr)
    return out, aux


def moe_apply(p: dict, x: jnp.ndarray, top_k: int, capacity_factor: float,
              fcfg: FalconConfig | None = None,
              deterministic_capacity: int | None = None):
    """x: (B, S, d) -> (y, aux_loss). Token-drop capacity MoE (Switch-style).

    Dispatch policy comes from the context config; ``fcfg`` is a deprecated
    per-call override.
    """
    with engine.deprecated_fcfg(fcfg, "moe_apply"):
        B, S, d = x.shape
        E = p["router"].shape[1]
        T = B * S
        C = deterministic_capacity or moe_capacity(T, top_k, E,
                                                   capacity_factor)
        from repro.parallel.sharding import get_parallel_style
        mesh = compat.get_abstract_mesh()
        nm = dict(mesh.shape).get("model", 1) if mesh is not None else 1
        if nm > 1 and E % nm == 0 and get_parallel_style() == "tp":
            return _moe_shardmap(p, x, top_k, C, mesh)
        return _moe_dense(p, x, top_k, C)
