"""starcoder2-15b [dense]: GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. Full attention =>
long_500k skipped. d_ff=24576 makes its MLP the best LCMA target in the pool.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
)
