"""Model / shape-cell configuration dataclasses."""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    mlp_type: str = "swiglu"        # "swiglu" (3-matrix) | "gelu" (2-matrix)
    # attention pattern
    sliding_window: int = 0         # 0 => full attention
    global_every: int = 0           # gemma3: one global layer every N (rest local)
    rope_theta: float = 1e4
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # modality frontends (stubs: precomputed embeddings per assignment)
    frontend: str = ""              # "" | "audio_codebooks" | "vision_patches"
    num_codebooks: int = 4
    num_patches: int = 1024
    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    remat: bool = True              # activation checkpointing around each layer
    remat_policy: str = "full"      # "full" | "dots" (save matmul outputs only)
    fsdp: bool = True               # shard params/opt-state over "data"
    # parallelism style on the fixed (data, model) mesh:
    #   "tp"        — tensor parallel over "model" (default)
    #   "fsdp_only" — no TP: batch over (data x model), params ZeRO-3 over all
    #                 axes; right for <=15B dense models (kills per-layer ARs)
    parallel_style: str = "tp"
    # PaLM-style parallel attention+FFN block: both branches read ln1(x) and
    # their partial outputs sum BEFORE the TP all-reduce => one AR per layer
    parallel_block: bool = False
    # FalconGEMM integration
    use_falcon: bool = True
    falcon_mode: str = "auto"       # "auto" | "gemm" | scheme name
    falcon_backend: str = "jnp"
    # long-context applicability (sub-quadratic attention path exists)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_windows(self) -> list[int]:
        """Per-layer sliding window (0 = global/full attention)."""
        if self.sliding_window == 0:
            return [0] * self.num_layers
        if self.global_every <= 0:
            return [self.sliding_window] * self.num_layers
        return [
            0 if (i + 1) % self.global_every == 0 else self.sliding_window
            for i in range(self.num_layers)
        ]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
