"""gemma3-27b [dense]: 5:1 local:global attention, 128k ctx [hf:google/gemma-3].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
5 sliding-window layers per 1 global layer => effectively sub-quadratic for
long context (global layers dominate asymptotically but are 1/6 of depth);
the assignment's long_500k cell runs for this arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    rope_theta=1e6,
    supports_long_context=True,
)
