from .base import ModelConfig, ShapeCell, SHAPE_CELLS
from .registry import get_config, list_archs, smoke_config

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "get_config", "list_archs",
           "smoke_config"]
