"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840.
Full attention => long_500k skipped. Params ~1T total / ~32B active.
FSDP + EP sharding is mandatory at this scale (see launch/mesh notes).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi_k2_1t",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
)
