"""hymba-1.5b [hybrid]: parallel attention+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hybrid => sub-quadratic path exists: the attention branch uses a sliding
window (global layers every 8), the SSM branch carries long context.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1_5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    global_every=8,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    ssm_groups=5,
    supports_long_context=True,
)
