"""musicgen-large [audio]: decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (GQA kv=32 => MHA) d_ff=8192 vocab=2048.
Backbone only per assignment; the EnCodec frontend is a stub — inputs are 4
parallel codebook token streams whose embeddings are summed, and the head
predicts 4 codebooks. Full attention => long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    frontend="audio_codebooks",
    num_codebooks=4,
    tie_embeddings=False,
)
