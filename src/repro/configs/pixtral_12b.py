"""pixtral-12b [vlm]: pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. The ViT frontend is
a stub per the assignment: ``input_specs()`` provides precomputed patch
embeddings (B, num_patches, d_model) which are prepended to the text tokens;
seq_len cells count text + patches. Full attention => long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    frontend="vision_patches",
    num_patches=1024,
)
