"""Architecture registry: ``--arch <id>`` lookup + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from .base import ModelConfig

ARCH_IDS = [
    "hymba_1_5b", "gemma3_27b", "granite_3_2b", "starcoder2_15b",
    "mistral_nemo_12b", "kimi_k2_1t", "dbrx_132b", "mamba2_370m",
    "musicgen_large", "pixtral_12b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_config(arch: str) -> ModelConfig:
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small dims, few layers)."""
    full = get_config(arch)
    heads = min(full.num_heads, 4)
    kv = max(1, min(full.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        full,
        num_layers=2,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=min(full.sliding_window, 32) if full.sliding_window else 0,
        global_every=full.global_every and 2,
        num_experts=min(full.num_experts, 4) or 0,
        experts_per_token=min(full.experts_per_token, 2) or 0,
        ssm_state=min(full.ssm_state, 16) or 0,
        ssm_heads=min(full.ssm_heads, 4) or 0,
        ssm_head_dim=16 if full.ssm_heads else 64,
        ssm_groups=1,
        ssm_chunk=8,
        num_patches=8,
        dtype="float32",
        remat=False,
        fsdp=False,
        falcon_mode=full.falcon_mode,
    )


def lcma_smoke_config(arch: str) -> ModelConfig:
    """Smoke config widened past the smallest LCMA tier.

    ``smoke_config`` at d_model=64 sits below every LCMA dimension tier, so
    the Decision Module always picks the classical scheme and quant/scheme
    tests see no LCMA coverage. This variant keeps the family and layer
    count but widens the projections (d_model=256, d_ff=512) so strassen /
    two-level tiers become eligible, and trims the vocab so logits stay
    cheap. Shared by ``tests/test_quant_serve.py`` and
    ``benchmarks/quant_serve.py`` — previously each hand-rolled its own
    widened copy.
    """
    return dataclasses.replace(
        smoke_config(arch), d_model=256, d_ff=512, vocab_size=512)
