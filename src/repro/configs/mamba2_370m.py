"""mamba2-370m [ssm]: SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128. d_inner = 2*d_model =
2048 => 32 SSD heads of dim 64. Attention-free => long_500k RUNS (state is
O(1) in sequence length).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    ssm_groups=1,
    supports_long_context=True,
    tie_embeddings=True,
)
