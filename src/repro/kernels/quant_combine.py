"""Quantization fused into Group Combine (paper §IV-C, TPU int8 adaptation).

The paper fuses FP8 (1x128 block-scaled) quantization into the Combine-A
stage so low-precision serving pays no extra quantization pass. On TPU the
low-precision MXU path is int8, so:

  * ``group_combine_quant`` — one Pallas program per (x, y) tile computes the
    whole R-group combine in f32 VMEM and emits int8 values + per-(row,
    K-block) f32 scales, all in a single HBM pass over A,
  * ``fused_gemm_combine_h_quant`` — the fused GEMM accumulates int8xint8
    MXU products per K-block, applies the a/b block scales while the partial
    product is still in VMEM, and runs Group Combine H on the f32
    accumulators exactly like the bf16 kernel.

Block-scale granularity is (1 row) x (by K-block) — the TPU-aligned analogue
of the paper's 1x128 scheme (by defaults to 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False


def _quant_combine_kernel(*refs, coeff, nin):
    in_refs = refs[:nin]
    q_ref, s_ref = refs[nin], refs[nin + 1]
    R, d1, d2 = coeff.shape[0], coeff.shape[1], coeff.shape[2]
    for r in range(R):
        acc = None
        for i in range(d1):
            for l in range(d2):
                c = int(coeff[r, i, l])
                if c == 0:
                    continue
                t = in_refs[i * d2 + l][...].astype(jnp.float32)
                t = t if c == 1 else (-t if c == -1 else t * c)
                acc = t if acc is None else acc + t
        if acc is None:
            acc = jnp.zeros(q_ref.shape[1:], jnp.float32)
        # per-row scale over this K-block (the (1, by) block-scaling)
        s = jnp.max(jnp.abs(acc), axis=1, keepdims=True) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(acc / s), -127, 127).astype(jnp.int8)
        q_ref[r, :, :] = q
        s_ref[r, :, :] = s


def group_combine_quant(x: jnp.ndarray, coeff: np.ndarray, *,
                        block: tuple[int, int] = (128, 128),
                        interpret: bool = False):
    """x: (d1*X, d2*Y) -> (q int8 (R, X, Y), scales f32 (R, X, Y/by))."""
    R, d1, d2 = coeff.shape
    M, K = x.shape
    assert M % d1 == 0 and K % d2 == 0
    X, Y = M // d1, K // d2
    bx, by = block
    bx = min(bx, X) if X % min(bx, X) == 0 else [d for d in range(min(bx, X), 0, -1) if X % d == 0][0]
    by = min(by, Y) if Y % min(by, Y) == 0 else [d for d in range(min(by, Y), 0, -1) if Y % d == 0][0]
    grid = (X // bx, Y // by)
    in_specs = []
    for i in range(d1):
        for l in range(d2):
            in_specs.append(pl.BlockSpec(
                (bx, by),
                functools.partial(
                    lambda gx, gy, i=i, l=l: (i * (X // bx) + gx, l * (Y // by) + gy))))
    out_specs = [
        pl.BlockSpec((R, bx, by), lambda gx, gy: (0, gx, gy)),
        pl.BlockSpec((R, bx, 1), lambda gx, gy: (0, gx, gy)),
    ]
    kernel = functools.partial(_quant_combine_kernel, coeff=coeff, nin=d1 * d2)
    fn = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((R, X, Y), jnp.int8),
                   jax.ShapeDtypeStruct((R, X, Y // by), jnp.float32)],
        interpret=interpret)
    return fn(*([x] * (d1 * d2)))


def _fused_quant_kernel(aq_ref, as_ref, bq_ref, bs_ref, out_ref, acc_ref, *,
                        w, grid_y):
    R, m, n = w.shape
    y = pl.program_id(2)

    @pl.when(y == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for r in range(R):
        # int8 x int8 -> int32 on the MXU; dequantize the K-block partial
        # product with the (row x block) and (block x col) scales in VMEM
        part = jax.lax.dot_general(
            aq_ref[r], bq_ref[r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        acc_ref[r, :, :] += part * as_ref[r] * bs_ref[r]

    @pl.when(y == grid_y - 1)
    def _combine_h():
        for i in range(m):
            for j in range(n):
                acc = None
                for r in range(R):
                    c = int(w[r, i, j])
                    if c == 0:
                        continue
                    t = acc_ref[r, :, :]
                    t = t if c == 1 else (-t if c == -1 else t * c)
                    acc = t if acc is None else acc + t
                if acc is None:
                    acc = jnp.zeros_like(acc_ref[0])
                out_ref[i, j, :, :] = acc.astype(out_ref.dtype)


def fused_gemm_combine_h_quant(aq, a_scales, bq, b_scales, w: np.ndarray, *,
                               block: tuple[int, int, int] | None = None,
                               out_dtype=jnp.float32, interpret: bool = False):
    """int8 fused LCMA GEMM + Combine H with (1 x K-block) scaling.

    aq: (R, X, Y) int8; a_scales: (R, X, Yb); bq: (R, Y, Z) int8;
    b_scales: (R, Yb, Z). The K-block size is Y // Yb and must equal the
    kernel's reduction block ``by``.
    """
    R, m, n = w.shape
    _, X, Y = aq.shape
    _, _, Z = bq.shape
    Yb = a_scales.shape[2]
    by = Y // Yb
    # Static overflow guard (falcon-check's stability pass): the kernel sums
    # `by` int8*int8 products into an int32 lane before dequantizing, so the
    # K-block depth must keep the worst-case |sum| = by * 127^2 inside int32.
    from repro.analysis.stability import max_safe_accum_depth
    if by > max_safe_accum_depth(32):
        raise ValueError(
            f"fused_gemm_combine_h_quant: K-block depth {by} overflows the "
            f"int32 accumulator (worst |sum| = {by} * 127^2); max safe depth "
            f"is {max_safe_accum_depth(32)} — use a smaller scale block")
    bx, bz = (block[0], block[1]) if block else (min(128, X), min(128, Z))
    assert X % bx == 0 and Z % bz == 0 and Y % by == 0
    grid = (X // bx, Z // bz, Yb)
    kernel = functools.partial(_fused_quant_kernel, w=w, grid_y=Yb)
    fn = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((R, bx, by), lambda x, z, y: (0, x, y)),
            pl.BlockSpec((R, bx, 1), lambda x, z, y: (0, x, y)),
            pl.BlockSpec((R, by, bz), lambda x, z, y: (0, y, z)),
            pl.BlockSpec((R, 1, bz), lambda x, z, y: (0, y, z)),
        ],
        out_specs=pl.BlockSpec((m, n, bx, bz), lambda x, z, y: (0, 0, x, z)),
        out_shape=jax.ShapeDtypeStruct((m, n, X, Z), out_dtype),
        scratch_shapes=[pltpu.VMEM((R, bx, bz), jnp.float32)] if _HAS_PLTPU
        else [],  # pragma: no cover
        interpret=interpret)
    return fn(aq, a_scales, bq, b_scales)


def quantize_b_blockwise(b: jnp.ndarray, coeff: np.ndarray, by: int = 128,
                         interpret: bool = False):
    """Offline Combine-B + quantization for static weights (serving path).

    Returns (bq int8 (R, Y, Z), b_scales (R, Yb, Z)) with per-(K-block, col)
    scales, matching ``fused_gemm_combine_h_quant``.
    """
    from .group_combine import group_combine
    bt = group_combine(b, coeff, interpret=interpret).astype(jnp.float32)
    R, Y, Z = bt.shape
    assert Y % by == 0
    btb = bt.reshape(R, Y // by, by, Z)
    s = jnp.maximum(jnp.max(jnp.abs(btb), axis=2) / 127.0, 1e-12)  # (R, Yb, Z)
    q = jnp.clip(jnp.round(btb / s[:, :, None, :]), -127, 127).astype(jnp.int8)
    return q.reshape(R, Y, Z), s
