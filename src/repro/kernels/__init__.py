from .fused_gemm import fused_gemm_combine_h, tiled_matmul
from .group_combine import group_combine
from .ops import (falcon_matmul_pallas, falcon_matmul_pallas_precombined,
                  matmul_pallas)

__all__ = ["fused_gemm_combine_h", "tiled_matmul", "group_combine",
           "falcon_matmul_pallas", "falcon_matmul_pallas_precombined",
           "matmul_pallas"]
