from .fused_gemm import (batched_fused_gemm_combine_h, fused_gemm_combine_h,
                         tiled_matmul)
from .group_combine import batched_group_combine, group_combine
from .ops import (falcon_grouped_matmul_pallas,
                  falcon_grouped_matmul_pallas_precombined,
                  falcon_matmul_pallas, falcon_matmul_pallas_precombined,
                  matmul_pallas)

__all__ = ["fused_gemm_combine_h", "batched_fused_gemm_combine_h",
           "tiled_matmul", "group_combine", "batched_group_combine",
           "falcon_matmul_pallas", "falcon_matmul_pallas_precombined",
           "falcon_grouped_matmul_pallas",
           "falcon_grouped_matmul_pallas_precombined", "matmul_pallas"]
