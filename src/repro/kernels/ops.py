"""Jitted wrappers around the Pallas kernels (padding, reassembly, dispatch).

``falcon_matmul_pallas`` is the full on-TPU LCMA pipeline:
  Group Combine A  ->  Group Combine B  ->  fused GEMM + Group Combine H
with all padding/unpadding handled here so kernels see exact tiles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.lcma import LCMA
# one padding definition shared with the generated-jnp pipeline — the two
# execution paths must pad identically or their outputs diverge at the edges
from repro.core.falcon_gemm import _pad2, _pad3
from .fused_gemm import (batched_fused_gemm_combine_h, fused_gemm_combine_h,
                         tiled_matmul)
from .group_combine import batched_group_combine, group_combine
from .quant_combine import fused_gemm_combine_h_quant, group_combine_quant


@partial(jax.jit, static_argnames=("l", "block_combine", "block_gemm", "interpret"))
def falcon_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, l: LCMA,
                         block_combine: tuple[int, int] | None = None,
                         block_gemm: tuple[int, int, int] | None = None,
                         interpret: bool = False) -> jnp.ndarray:
    """LCMA matmul via the Pallas kernel pipeline. Handles arbitrary shapes."""
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"falcon_matmul_pallas: contracting dims differ: "
                         f"{a.shape} @ {b.shape}")
    # Pad to grid multiples. The K pads of A and B coincide (both are
    # (-K) % l.k), so the combined operands stay K-consistent. Tile sizes are
    # chosen on the padded submatrix sizes by the resource planner unless
    # pinned by the caller.
    ap = _pad2(a, l.m, l.k)
    bp = _pad2(b, l.k, l.n)
    at = group_combine(ap, l.U, block=block_combine, interpret=interpret)
    bt = group_combine(bp, l.V, block=block_combine, interpret=interpret)
    cp = fused_gemm_combine_h(at, bt, l.W, block=block_gemm,
                              out_dtype=a.dtype, interpret=interpret)
    m, n, X, Z = cp.shape
    c = cp.transpose(0, 2, 1, 3).reshape(m * X, n * Z)
    return c[:M, :N]


@partial(jax.jit, static_argnames=("l", "n_logical", "block_combine",
                                   "block_gemm", "interpret"))
def falcon_matmul_pallas_precombined(
        a: jnp.ndarray, bt: jnp.ndarray, l: LCMA, n_logical: int,
        block_combine: tuple[int, int] | None = None,
        block_gemm: tuple[int, int, int] | None = None,
        interpret: bool = False) -> jnp.ndarray:
    """Serving-path kernel pipeline against pre-combined B̃ (R, K/k, N/n).

    The offline Combine-B (paper §IV-C) variant of ``falcon_matmul_pallas``:
    Combine B never runs — only Group Combine A and the fused GEMM+Combine H.
    ``bt`` layout matches ``codegen``'s ``combine_b`` output (verified
    bitwise-identical to the kernel ``group_combine``), so weights combined
    offline by either path are interchangeable.
    """
    M, K = a.shape
    ap = _pad2(a, l.m, l.k)
    if ap.shape[1] // l.k != bt.shape[1]:
        raise ValueError(
            f"falcon_matmul_pallas_precombined: activation K={K} (padded "
            f"{ap.shape[1]}, grid k={l.k}) does not match precombined "
            f"B̃ {tuple(bt.shape)} for scheme {l.name} {l.key}")
    at = group_combine(ap, l.U, block=block_combine, interpret=interpret)
    cp = fused_gemm_combine_h(at, bt, l.W, block=block_gemm,
                              out_dtype=a.dtype, interpret=interpret)
    m, n, X, Z = cp.shape
    c = cp.transpose(0, 2, 1, 3).reshape(m * X, n * Z)
    return c[:M, :n_logical]


@partial(jax.jit, static_argnames=("l", "n_logical", "block_combine",
                                   "block_gemm", "interpret"))
def falcon_matmul_pallas_quant(
        a: jnp.ndarray, bq: jnp.ndarray, b_scales: jnp.ndarray, l: LCMA,
        n_logical: int, block_combine: tuple[int, int] | None = None,
        block_gemm: tuple[int, int, int] | None = None,
        interpret: bool = False) -> jnp.ndarray:
    """Quantized serving pipeline against offline-quantized B̃q + scales.

    The int8 variant of ``falcon_matmul_pallas_precombined``: Group Combine A
    runs fused with quantization (``group_combine_quant`` — one HBM pass over
    A, int8 Ã plus per-(row, K-block) f32 scales out), then the fused int8
    GEMM + dequantizing Combine H. ``bq``/``b_scales`` come from
    ``quantize_b_blockwise`` (the PlannedWeight quant buffers); the A-side
    scale block is forced to B's so the two block-scale grids line up.
    """
    M, K = a.shape
    ap = _pad2(a, l.m, l.k)
    Y = bq.shape[1]
    if ap.shape[1] // l.k != Y:
        raise ValueError(
            f"falcon_matmul_pallas_quant: activation K={K} (padded "
            f"{ap.shape[1]}, grid k={l.k}) does not match quantized "
            f"B̃q {tuple(bq.shape)} for scheme {l.name} {l.key}")
    by = Y // b_scales.shape[1]
    bcx = block_combine[0] if block_combine else 128
    at, a_scales = group_combine_quant(ap, l.U, block=(bcx, by),
                                       interpret=interpret)
    X = ap.shape[0] // l.m
    Z = bq.shape[2]
    if block_gemm is not None:
        bx, bz = block_gemm[0], block_gemm[1]
    else:
        # the fused kernel asserts exact divisibility; snap its defaults to
        # the largest divisors <= 128 (same idiom as group_combine_quant)
        bx = next(d for d in range(min(128, X), 0, -1) if X % d == 0)
        bz = next(d for d in range(min(128, Z), 0, -1) if Z % d == 0)
    cp = fused_gemm_combine_h_quant(at, a_scales, bq, b_scales, l.W,
                                    block=(bx, bz, by), out_dtype=a.dtype,
                                    interpret=interpret)
    m, n, Xc, Zc = cp.shape
    c = cp.transpose(0, 2, 1, 3).reshape(m * Xc, n * Zc)
    return c[:M, :n_logical]


@partial(jax.jit, static_argnames=("l", "block_combine", "block_gemm", "interpret"))
def falcon_grouped_matmul_pallas(a3: jnp.ndarray, b: jnp.ndarray, l: LCMA,
                                 block_combine: tuple[int, int] | None = None,
                                 block_gemm: tuple[int, int, int] | None = None,
                                 interpret: bool = False) -> jnp.ndarray:
    """Grouped LCMA matmul: a3 (G, M, K) x b [(K, N) | (G, K, N)] -> (G, M, N).

    The Group-Parallel batched pipeline: per-group Combine A (one batched
    kernel launch), Combine B run ONCE when ``b`` is shared across the group
    (2-D) or per group otherwise, then one grouped fused GEMM+Combine-H over
    all G*R intermediate products. Handles arbitrary shapes via padding.
    """
    G, M, K = a3.shape
    shared = b.ndim == 2
    Kb, N = (b.shape if shared else b.shape[1:])
    if K != Kb:
        raise ValueError(f"falcon_grouped_matmul_pallas: contracting dims "
                         f"differ: {a3.shape} @ {b.shape}")
    if not shared and b.shape[0] != G:
        raise ValueError(f"falcon_grouped_matmul_pallas: group sizes differ: "
                         f"{a3.shape} @ {b.shape}")
    ap = _pad3(a3, l.m, l.k)
    at = batched_group_combine(ap, l.U, block=block_combine,
                               interpret=interpret)
    if shared:
        bt = group_combine(_pad2(b, l.k, l.n), l.V, block=block_combine,
                           interpret=interpret)
    else:
        bt = batched_group_combine(_pad3(b, l.k, l.n), l.V,
                                   block=block_combine, interpret=interpret)
    cp = batched_fused_gemm_combine_h(at, bt, l.W, block=block_gemm,
                                      out_dtype=a3.dtype, interpret=interpret)
    g, m, n, X, Z = cp.shape
    c = cp.transpose(0, 1, 3, 2, 4).reshape(G, m * X, n * Z)
    return c[:, :M, :N]


@partial(jax.jit, static_argnames=("l", "n_logical", "block_combine",
                                   "block_gemm", "interpret"))
def falcon_grouped_matmul_pallas_precombined(
        a3: jnp.ndarray, bt: jnp.ndarray, l: LCMA, n_logical: int,
        block_combine: tuple[int, int] | None = None,
        block_gemm: tuple[int, int, int] | None = None,
        interpret: bool = False) -> jnp.ndarray:
    """Grouped serving pipeline against precombined B̃.

    ``bt`` is (R, K/k, N/n) — one weight shared by the group (a PlannedWeight
    under a batched activation) — or (G, R, K/k, N/n) for stacked per-group
    weights (MoE experts precombined offline). Combine B never runs.
    """
    G, M, K = a3.shape
    ap = _pad3(a3, l.m, l.k)
    if ap.shape[2] // l.k != bt.shape[-2]:
        raise ValueError(
            f"falcon_grouped_matmul_pallas_precombined: activation K={K} "
            f"(padded {ap.shape[2]}, grid k={l.k}) does not match precombined "
            f"B̃ {tuple(bt.shape)} for scheme {l.name} {l.key}")
    if bt.ndim == 4 and bt.shape[0] != G:
        raise ValueError(
            f"falcon_grouped_matmul_pallas_precombined: group sizes differ: "
            f"{a3.shape} vs B̃ {tuple(bt.shape)}")
    at = batched_group_combine(ap, l.U, block=block_combine,
                               interpret=interpret)
    cp = batched_fused_gemm_combine_h(at, bt, l.W, block=block_gemm,
                                      out_dtype=a3.dtype, interpret=interpret)
    g, m, n, X, Z = cp.shape
    c = cp.transpose(0, 1, 3, 2, 4).reshape(G, m * X, n * Z)
    return c[:, :M, :n_logical]


@partial(jax.jit, static_argnames=("block", "interpret"))
def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray,
                  block: tuple[int, int, int] | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """Standard tiled-matmul kernel with padding."""
    M, K = a.shape
    _, N = b.shape
    ap = _pad2(a, 8, 128)
    bp = _pad2(b, 128, 128)
    if ap.shape[1] != bp.shape[0]:
        kp = max(ap.shape[1], bp.shape[0])
        ap = jnp.pad(ap, ((0, 0), (0, kp - ap.shape[1])))
        bp = jnp.pad(bp, ((0, kp - bp.shape[0]), (0, 0)))
    c = tiled_matmul(ap, bp, block=block, interpret=interpret)
    return c[:M, :N]
