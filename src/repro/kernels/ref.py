"""Pure-jnp oracles for the Pallas kernels (ground truth in tests)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.lcma import LCMA


def group_combine_ref(parts: jnp.ndarray, coeff: np.ndarray) -> jnp.ndarray:
    """parts: (d1, d2, X, Y); coeff: (R, d1, d2) -> (R, X, Y).

    Oracle for the Group Combine A/B kernels (Eq. 3/4): every rank-r output
    tile is the coefficient-weighted sum of the co-located input tiles.
    """
    c = jnp.asarray(coeff, parts.dtype)
    return jnp.einsum("ril,ilxy->rxy", c, parts)


def fused_gemm_combine_h_ref(at: jnp.ndarray, bt: jnp.ndarray, w: np.ndarray,
                             out_dtype=None) -> jnp.ndarray:
    """at: (R, X, Y); bt: (R, Y, Z); w: (R, m, n) -> C parts (m, n, X, Z).

    Oracle for the fused GEMM + Group Combine H kernel (Eq. 5+6): H is kept
    in float32 and combined into C without materialization.
    """
    out_dtype = out_dtype or at.dtype
    h = jnp.einsum("rxy,ryz->rxz", at.astype(jnp.float32), bt.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    c = jnp.einsum("rmn,rxz->mnxz", jnp.asarray(w, jnp.float32), h)
    return c.astype(out_dtype)


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def lcma_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, l: LCMA, out_dtype=None) -> jnp.ndarray:
    """End-to-end oracle: partition -> combine -> fused gemm+H -> reassemble."""
    out_dtype = out_dtype or a.dtype
    M, K = a.shape
    K2, N = b.shape
    assert M % l.m == 0 and K % l.k == 0 and N % l.n == 0
    X, Y, Z = M // l.m, K // l.k, N // l.n
    ap = a.reshape(l.m, X, l.k, Y).transpose(0, 2, 1, 3)
    bp = b.reshape(l.k, Y, l.n, Z).transpose(0, 2, 1, 3)
    at = group_combine_ref(ap, l.U)
    bt = group_combine_ref(bp, l.V)
    cp = fused_gemm_combine_h_ref(at, bt, l.W, out_dtype=out_dtype)
    return cp.transpose(0, 2, 1, 3).reshape(M, N)


def grouped_lcma_matmul_ref(a3: jnp.ndarray, b, l: LCMA,
                            out_dtype=None) -> jnp.ndarray:
    """Grouped oracle: a3 (G, M, K) x b [(K, N) shared | (G, K, N)] -> (G, M, N).

    Ground truth for the batched kernel pipeline: per-group Combine A, a
    hoisted (shared-b) or per-group Combine B, one grouped GEMM, per-group
    Combine H. Must equal ``vmap(lcma_matmul_ref)`` exactly.
    """
    import jax
    if b.ndim == 2:
        return jax.vmap(lambda ai: lcma_matmul_ref(ai, b, l, out_dtype))(a3)
    return jax.vmap(lambda ai, bi: lcma_matmul_ref(ai, bi, l, out_dtype))(a3, b)
