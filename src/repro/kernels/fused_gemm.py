"""Pallas TPU kernel: fused GEMM + Group Combine H (paper Alg. 2, stages 3-4).

One program instance owns the group ``{H_r[x,z]}_{r=1..R}`` at output tile
coordinate ``(x, z)``: the R accumulators live in a persistent VMEM scratch
``(R, bx, bz) float32`` across the K-reduction grid dimension, and on the last
reduction step the W-combination produces all m*n output tiles
``{C_ij[x,z]}`` on-chip.  Consequences (paper §III-B):

  * H_r is NEVER materialized to HBM — the ``R/mn`` bandwidth term of Eq. 9
    disappears (Eq. 10),
  * there are no write conflicts: each C tile has exactly one producer,
  * C is combined from float32 H on-chip => the §IV-F precision win.

TPU adaptation of Split-Group/Cache-Aware scheduling: the Pallas grid is
executed sequentially per core with pipelined HBM->VMEM copies, so GPU-style
SM load imbalance and L2 thrashing across concurrent CTAs have no analogue;
the corresponding knobs here are the grid iteration order (reduction dimension
innermost, ``dimension_semantics=("parallel","parallel","arbitrary")``) and
the block planner in ``tuning.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU compiler params are a no-op under interpret mode / CPU testing
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False


def _fused_kernel(at_ref, bt_ref, out_ref, acc_ref, *, w, grid_y):
    R, m, n = w.shape
    y = pl.program_id(2)

    @pl.when(y == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Accumulate the whole group on-chip: H_r[x,z] += At_r[x,y] @ Bt_r[y,z].
    # The r-loop is unrolled at trace time (one MXU issue per rank).
    for r in range(R):
        acc_ref[r, :, :] += jnp.dot(
            at_ref[r], bt_ref[r], preferred_element_type=jnp.float32
        )

    @pl.when(y == grid_y - 1)
    def _combine_h():
        # Group Combine H from float32 accumulators; coefficients unrolled.
        for i in range(m):
            for j in range(n):
                acc = None
                for r in range(R):
                    c = int(w[r, i, j])
                    if c == 0:
                        continue
                    t = acc_ref[r, :, :]
                    t = t if c == 1 else (-t if c == -1 else t * c)
                    acc = t if acc is None else acc + t
                if acc is None:
                    acc = jnp.zeros_like(acc_ref[0])
                out_ref[i, j, :, :] = acc.astype(out_ref.dtype)


def fused_gemm_combine_h(at: jnp.ndarray, bt: jnp.ndarray, w: np.ndarray,
                         *, block: tuple[int, int, int] | None = None,
                         out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    """(R, X, Y) x (R, Y, Z) --W--> C parts (m, n, X, Z); H stays in VMEM."""
    from .tuning import plan_fused_gemm_blocks

    R, m, n = w.shape
    R2, X, Y = at.shape
    R3, Y2, Z = bt.shape
    assert R == R2 == R3 and Y == Y2, (at.shape, bt.shape, w.shape)
    out_dtype = out_dtype or at.dtype
    bx, bz, by = block or plan_fused_gemm_blocks(X, Z, Y, R, m, n, at.dtype)
    assert X % bx == 0 and Z % bz == 0 and Y % by == 0, ((X, Z, Y), (bx, bz, by))
    grid = (X // bx, Z // bz, Y // by)

    kernel = functools.partial(_fused_kernel, w=w, grid_y=grid[2])
    kwargs = {}
    if _HAS_PLTPU and not interpret:  # pragma: no cover - TPU-only path
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((R, bx, by), lambda x, z, y: (0, x, y)),
            pl.BlockSpec((R, by, bz), lambda x, z, y: (0, y, z)),
        ],
        out_specs=pl.BlockSpec((m, n, bx, bz), lambda x, z, y: (0, 0, x, z)),
        out_shape=jax.ShapeDtypeStruct((m, n, X, Z), out_dtype),
        scratch_shapes=[pltpu.VMEM((R, bx, bz), jnp.float32)] if _HAS_PLTPU
        else [pl.MemorySpace.ANY((R, bx, bz), jnp.float32)],  # pragma: no cover
        interpret=interpret,
        **kwargs,
    )
    return fn(at, bt)


def _batched_fused_kernel(at_ref, bt_ref, out_ref, acc_ref, *, w, grid_y,
                          bt_batched):
    """Grouped Alg. 2: leading parallel group axis; reduction is grid dim 3.

    ``bt_batched=False`` is the hoisted shared-B case: the bt block carries
    no group axis (its index map ignores ``g``), so one combined B̃ feeds
    every group element — the Combine-B work was done once for the group.
    """
    R, m, n = w.shape
    y = pl.program_id(3)

    @pl.when(y == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for r in range(R):
        bt_r = bt_ref[0, r] if bt_batched else bt_ref[r]
        acc_ref[r, :, :] += jnp.dot(
            at_ref[0, r], bt_r, preferred_element_type=jnp.float32
        )

    @pl.when(y == grid_y - 1)
    def _combine_h():
        for i in range(m):
            for j in range(n):
                acc = None
                for r in range(R):
                    c = int(w[r, i, j])
                    if c == 0:
                        continue
                    t = acc_ref[r, :, :]
                    t = t if c == 1 else (-t if c == -1 else t * c)
                    acc = t if acc is None else acc + t
                if acc is None:
                    acc = jnp.zeros_like(acc_ref[0])
                out_ref[0, i, j, :, :] = acc.astype(out_ref.dtype)


def batched_fused_gemm_combine_h(at: jnp.ndarray, bt: jnp.ndarray,
                                 w: np.ndarray, *,
                                 block: tuple[int, int, int] | None = None,
                                 out_dtype=None,
                                 interpret: bool = False) -> jnp.ndarray:
    """Grouped fused GEMM + Combine H: (G, R, X, Y) x bt --W--> (G, m, n, X, Z).

    ``bt`` is either (G, R, Y, Z) — per-group combined B (MoE experts,
    batched attention operands) — or (R, Y, Z), the *hoisted* shared-B form:
    the same B̃ group is contracted against every at[g] without ever being
    recombined or replicated in HBM. Either way the whole group's R
    accumulators live in one persistent VMEM scratch per (g, x, z) tile and
    H never reaches HBM.
    """
    from .tuning import plan_fused_gemm_blocks

    R, m, n = w.shape
    G, R2, X, Y = at.shape
    bt_batched = bt.ndim == 4
    if bt_batched:
        G3, R3, Y2, Z = bt.shape
        assert G3 == G, (at.shape, bt.shape)
    else:
        R3, Y2, Z = bt.shape
    assert R == R2 == R3 and Y == Y2, (at.shape, bt.shape, w.shape)
    out_dtype = out_dtype or at.dtype
    bx, bz, by = block or plan_fused_gemm_blocks(X, Z, Y, R, m, n, at.dtype)
    assert X % bx == 0 and Z % bz == 0 and Y % by == 0, ((X, Z, Y), (bx, bz, by))
    grid = (G, X // bx, Z // bz, Y // by)

    if bt_batched:
        bt_spec = pl.BlockSpec((1, R, by, bz), lambda g, x, z, y: (g, 0, y, z))
    else:
        bt_spec = pl.BlockSpec((R, by, bz), lambda g, x, z, y: (0, y, z))

    kernel = functools.partial(_batched_fused_kernel, w=w, grid_y=grid[3],
                               bt_batched=bt_batched)
    kwargs = {}
    if _HAS_PLTPU and not interpret:  # pragma: no cover - TPU-only path
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")
        )
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, R, bx, by), lambda g, x, z, y: (g, 0, x, y)),
            bt_spec,
        ],
        out_specs=pl.BlockSpec((1, m, n, bx, bz),
                               lambda g, x, z, y: (g, 0, 0, x, z)),
        out_shape=jax.ShapeDtypeStruct((G, m, n, X, Z), out_dtype),
        scratch_shapes=[pltpu.VMEM((R, bx, bz), jnp.float32)] if _HAS_PLTPU
        else [pl.MemorySpace.ANY((R, bx, bz), jnp.float32)],  # pragma: no cover
        interpret=interpret,
        **kwargs,
    )
    return fn(at, bt)


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, grid_y):
    y = pl.program_id(2)

    @pl.when(y == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(y == grid_y - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def tiled_matmul(a: jnp.ndarray, b: jnp.ndarray, *, block: tuple[int, int, int] | None = None,
                 out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    """Standard tiled MXU matmul — the non-LCMA baseline kernel."""
    from .tuning import plan_fused_gemm_blocks

    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or a.dtype
    bx, bz, by = block or plan_fused_gemm_blocks(M, N, K, 1, 1, 1, a.dtype)
    assert M % bx == 0 and N % bz == 0 and K % by == 0
    grid = (M // bx, N // bz, K // by)
    kernel = functools.partial(_matmul_kernel, grid_y=grid[2])
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bx, by), lambda x, z, y: (x, y)),
            pl.BlockSpec((by, bz), lambda x, z, y: (y, z)),
        ],
        out_specs=pl.BlockSpec((bx, bz), lambda x, z, y: (x, z)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bx, bz), jnp.float32)] if _HAS_PLTPU
        else [pl.MemorySpace.ANY((bx, bz), jnp.float32)],  # pragma: no cover
        interpret=interpret,
    )
    return fn(a, b)
