"""On-chip Resource Planning (paper §III-A micro-optimization 1).

Evaluates the VMEM footprint a kernel configuration will claim and shrinks
block shapes until the plan fits the hardware budget, keeping MXU dimensions
aligned to the systolic array (multiples of 128 where the problem allows).
High-rank schemes (e.g. <4,4,4>;49) hit the budget first through the
``(R, bx, bz)`` float32 accumulator — exactly the failure AlphaTensor's large-R
kernels hit on GPU shared memory (paper §IV-C); the planner degrades block
sizes instead of falling back to Strassen.
"""
from __future__ import annotations

import jax.numpy as jnp

# Conservative per-core VMEM budget (bytes) for kernel working sets; the
# Pallas pipeline double-buffers in/out blocks, which the estimates include.
VMEM_BUDGET = 12 << 20
MXU = 128


def _align_candidates(dim: int, mxu: int = MXU) -> list[int]:
    """Block-size candidates for a dimension: MXU multiples, then divisors."""
    cands = [c for c in (512, 384, 256, 128) if dim % c == 0]
    if not cands:
        cands = [d for d in range(min(dim, 512), 0, -1) if dim % d == 0]
    return cands


def _all_divisors(dim: int) -> list[int]:
    """Every block size that tiles ``dim`` exactly, largest first (<= 512)."""
    return [d for d in range(min(dim, 512), 0, -1) if dim % d == 0]


def combine_vmem(bx: int, by: int, R: int, nparts: int, itemsize: int) -> int:
    # double-buffered: nparts input blocks + R output blocks
    return 2 * (nparts + R) * bx * by * itemsize


def plan_combine_blocks(X: int, Y: int, R: int, nparts: int, dtype,
                        budget: int = VMEM_BUDGET) -> tuple[int, int]:
    it = jnp.dtype(dtype).itemsize
    best = None
    for bx in _align_candidates(X):
        for by in _align_candidates(Y):
            if combine_vmem(bx, by, R, nparts, it) <= budget:
                cand = (bx, by)
                if best is None or bx * by > best[0] * best[1]:
                    best = cand
    if best is None:
        # No MXU-preferred tile fits (high-R schemes, tight budgets): degrade
        # through the full divisor lattice for the largest fitting pair.
        for bx in _all_divisors(X):
            for by in _all_divisors(Y):
                if combine_vmem(bx, by, R, nparts, it) <= budget and \
                        (best is None or bx * by > best[0] * best[1]):
                    best = (bx, by)
    if best is None:
        best = (_all_divisors(X)[-1], _all_divisors(Y)[-1])
    return best


def block_plans(l, M: int, K: int, N: int, dtype="float32",
                budget: int = VMEM_BUDGET, hw=None) -> dict:
    """Full block-plan summary for one LCMA application on a padded problem.

    The export surface for the autotuner (``core.autotune``) and the tune CLI:
    everything the Pallas pipeline would pick for this shape, as plain data
    that can be embedded in a calibrated-profile JSON and inspected offline.

    ``hw`` (a ``HardwareProfile``) clamps the budget to the profile's
    per-core VMEM when that is tighter than ``budget`` — so plans exported
    for a specific part never claim more on-chip memory than it has, and
    falcon-check's plan lint can flag a default-budget plan against a
    smaller device.
    """
    if hw is not None:
        hw_vmem = getattr(hw, "vmem_bytes", None)
        if hw_vmem:
            budget = min(budget, int(hw_vmem))
    it = jnp.dtype(dtype).itemsize
    Mp = ((M + l.m - 1) // l.m) * l.m
    Kp = ((K + l.k - 1) // l.k) * l.k
    Np = ((N + l.n - 1) // l.n) * l.n
    X, Ks, Z = Mp // l.m, Kp // l.k, Np // l.n
    ca = plan_combine_blocks(X, Ks, l.R, l.m * l.k, dtype, budget)
    cb = plan_combine_blocks(Ks, Z, l.R, l.k * l.n, dtype, budget)
    fg = plan_fused_gemm_blocks(X, Z, Ks, l.R, l.m, l.n, dtype, budget)
    return {
        "grid": [l.m, l.k, l.n], "R": l.R,
        "padded_shape": [Mp, Kp, Np],
        "combine_a": list(ca), "combine_b": list(cb),
        "fused_gemm": list(fg),
        "combine_a_vmem_bytes": combine_vmem(*ca, l.R, l.m * l.k, it),
        "combine_b_vmem_bytes": combine_vmem(*cb, l.R, l.k * l.n, it),
        "fused_gemm_vmem_bytes": fused_gemm_vmem(*fg, l.R, l.m, l.n, it),
        "vmem_budget_bytes": budget,
    }


def fused_gemm_vmem(bx: int, bz: int, by: int, R: int, m: int, n: int,
                    itemsize: int, acc_itemsize: int = 4) -> int:
    io = 2 * R * (bx * by + by * bz) * itemsize   # double-buffered At/Bt blocks
    acc = R * bx * bz * acc_itemsize              # persistent accumulator
    out = 2 * m * n * bx * bz * itemsize          # double-buffered C parts
    return io + acc + out


def plan_fused_gemm_blocks(X: int, Z: int, Y: int, R: int, m: int, n: int, dtype,
                           budget: int = VMEM_BUDGET) -> tuple[int, int, int]:
    """Pick (bx, bz, by) fitting the budget, preferring large MXU-aligned tiles."""
    it = jnp.dtype(dtype).itemsize
    best, best_score = None, -1.0
    for bx in _align_candidates(X):
        for bz in _align_candidates(Z):
            for by in _align_candidates(Y):
                if fused_gemm_vmem(bx, bz, by, R, m, n, it) > budget:
                    continue
                # score: MXU utilization proxy — prefer 128-multiples and
                # larger K-blocks (fewer accumulator passes).
                score = bx * bz * min(by, 512)
                if bx % MXU == 0 and bz % MXU == 0:
                    score *= 4
                if score > best_score:
                    best, best_score = (bx, bz, by), score
    if best is None:
        # No MXU-preferred tile fits (the (R, bx, bz) accumulator of a
        # high-R scheme claims the budget first): degrade through the full
        # divisor lattice instead of emitting an over-budget plan.
        for bx in _all_divisors(X):
            for bz in _all_divisors(Z):
                for by in _all_divisors(Y):
                    if fused_gemm_vmem(bx, bz, by, R, m, n, it) > budget:
                        continue
                    score = bx * bz * min(by, 512)
                    if score > best_score:
                        best, best_score = (bx, bz, by), score
    if best is None:
        best = (_all_divisors(X)[-1], _all_divisors(Z)[-1], _all_divisors(Y)[-1])
    return best
