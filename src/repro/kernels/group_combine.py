"""Pallas TPU kernel: Group Combine A/B (paper Alg. 2, stages 1-2).

One program instance owns the *entire group* ``{Ã_r[x,y]}_{r=1..R}`` at tile
coordinate ``(x, y)``: it loads the m*k co-located input tiles from HBM into
VMEM exactly once and produces all R combined tiles on-chip — eliminating the
redundant A/B loads of H_r-parallel implementations (paper §II-B issue 1).

Coefficients are unrolled into the kernel body at trace time (the Deployment
Module's "coefficients in I-cache" on TPU: they live in the program, never in
memory).  The input is consumed directly in ``(M, K)`` layout — each of the
m*k submatrices is a separate ``BlockSpec`` view of the same array, so no
relayout/transpose of A is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .tuning import plan_combine_blocks


def _combine_kernel(*refs, coeff, nin):
    in_refs = refs[:nin]
    out_ref = refs[nin]
    R = coeff.shape[0]
    d1, d2 = coeff.shape[1], coeff.shape[2]
    for r in range(R):
        acc = None
        for i in range(d1):
            for l in range(d2):
                c = int(coeff[r, i, l])
                if c == 0:
                    continue
                t = in_refs[i * d2 + l][...]
                # keep |c|==1 as pure add/sub; scale only true magnitudes
                t = t if c == 1 else (-t if c == -1 else t * c)
                acc = t if acc is None else acc + t
        if acc is None:
            acc = jnp.zeros_like(out_ref[r])
        out_ref[r, :, :] = acc


def group_combine(x: jnp.ndarray, coeff: np.ndarray, *, block: tuple[int, int] | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """Apply Group Combine to ``x`` of shape (d1*X, d2*Y) -> (R, X, Y).

    ``coeff`` is U (R, m, k) for Combine A (x = A, d1=m, d2=k) or V (R, k, n)
    for Combine B (x = B, d1=k, d2=n). Dimensions must divide exactly —
    padding is handled by the caller (`repro.kernels.ops`).
    """
    R, d1, d2 = coeff.shape
    M, K = x.shape
    assert M % d1 == 0 and K % d2 == 0, (x.shape, coeff.shape)
    X, Y = M // d1, K // d2
    bx, by = block or plan_combine_blocks(X, Y, R, d1 * d2, x.dtype)
    assert X % bx == 0 and Y % by == 0, ((X, Y), (bx, by))
    grid = (X // bx, Y // by)

    # One BlockSpec view per submatrix of the SAME input array: block (bx, by)
    # at offset (i*X + x*bx, l*Y + y*by). No relayout of x is materialized.
    in_specs = []
    for i in range(d1):
        for l in range(d2):
            in_specs.append(
                pl.BlockSpec(
                    (bx, by),
                    functools.partial(
                        lambda gx, gy, i=i, l=l: (i * (X // bx) + gx, l * (Y // by) + gy)
                    ),
                )
            )
    out_spec = pl.BlockSpec((R, bx, by), lambda gx, gy: (0, gx, gy))

    kernel = functools.partial(_combine_kernel, coeff=coeff, nin=d1 * d2)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R, X, Y), x.dtype),
        interpret=interpret,
    )
    return fn(*([x] * (d1 * d2)))


def _batched_combine_kernel(*refs, coeff, nin):
    """Leading group axis variant: blocks are (1, bx, by) / (1, R, bx, by)."""
    in_refs = refs[:nin]
    out_ref = refs[nin]
    R = coeff.shape[0]
    d1, d2 = coeff.shape[1], coeff.shape[2]
    for r in range(R):
        acc = None
        for i in range(d1):
            for l in range(d2):
                c = int(coeff[r, i, l])
                if c == 0:
                    continue
                t = in_refs[i * d2 + l][0]
                t = t if c == 1 else (-t if c == -1 else t * c)
                acc = t if acc is None else acc + t
        if acc is None:
            acc = jnp.zeros_like(out_ref[0, r])
        out_ref[0, r, :, :] = acc


def batched_group_combine(x: jnp.ndarray, coeff: np.ndarray, *,
                          block: tuple[int, int] | None = None,
                          interpret: bool = False) -> jnp.ndarray:
    """Group Combine over a batch: (G, d1*X, d2*Y) -> (G, R, X, Y).

    The grouped-execution form of :func:`group_combine`: one extra *parallel*
    grid dimension walks the group, and within each group element the kernel
    is identical — every (x, y) tile's m*k co-located inputs are loaded into
    VMEM once and all R combined tiles are produced on-chip. Coefficients
    stay unrolled in the program; no relayout of ``x`` is materialized.
    Dimensions must divide exactly — padding is handled by the caller
    (`repro.kernels.ops`).
    """
    R, d1, d2 = coeff.shape
    G, M, K = x.shape
    assert M % d1 == 0 and K % d2 == 0, (x.shape, coeff.shape)
    X, Y = M // d1, K // d2
    bx, by = block or plan_combine_blocks(X, Y, R, d1 * d2, x.dtype)
    assert X % bx == 0 and Y % by == 0, ((X, Y), (bx, by))
    grid = (G, X // bx, Y // by)

    in_specs = []
    for i in range(d1):
        for l in range(d2):
            in_specs.append(
                pl.BlockSpec(
                    (1, bx, by),
                    functools.partial(
                        lambda g, gx, gy, i=i, l=l:
                            (g, i * (X // bx) + gx, l * (Y // by) + gy)
                    ),
                )
            )
    out_spec = pl.BlockSpec((1, R, bx, by), lambda g, gx, gy: (g, 0, gx, gy))

    kernel = functools.partial(_batched_combine_kernel, coeff=coeff, nin=d1 * d2)
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((G, R, X, Y), x.dtype),
        interpret=interpret,
    )
    return fn(*([x] * (d1 * d2)))
