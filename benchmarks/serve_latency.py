"""Latency under load: speculative + prefix-reuse serving vs the baseline.

A fixed-arrival-rate load generator submits ragged requests from a frontend
thread while the engine's step loop drains them, the way a deployment
actually sees traffic (no convenient all-at-once batch). The same workload
runs twice — plain engine, then speculation (γ self-draft) + radix prefix
cache — and the report carries the serving SLO surface:

* **TTFT** p50/p99 (submit → first emitted token) and **per-token latency**
  p50/p99 (gaps between consecutive emitted tokens of one request);
* **acceptance rate** and **prefix hit rate** of the tier-2 features;
* **decode tokens/s** for both engines and their ratio (the speculation
  speedup; ≈ 1 on CPU smoke shapes, > 1 when verify amortizes);
* finished-request counts and the bucket/plan reuse counters.

``--check`` self-gates the run: both engines must finish every request with
**identical tokens** (speculation is worthless unless token-exact), accept
at least one draft, and hit only warmed buckets. CI runs the 32-request
smoke this way; ``python -m benchmarks.run`` embeds the same row in the
machine-readable report gated against ``baseline_cpu.json``.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.configs import registry
from repro.core import plan_cache
from repro.serve import ServeEngine


def _percentiles(xs, ps=(50, 99)):
    if not xs:
        return {p: 0.0 for p in ps}
    arr = np.asarray(xs, dtype=np.float64)
    return {p: float(np.percentile(arr, p)) for p in ps}


def _serve_under_load(engine: ServeEngine, prompts, max_new_tokens: int,
                      arrival_rate: float, seed: int):
    """Submit ``prompts`` at a fixed rate while stepping the engine.

    Returns (finished requests in submit order, per-token emit timestamps
    keyed by rid, wall seconds).
    """
    emits: dict[int, list[float]] = {}
    reqs: list = []
    budgets = np.random.default_rng(seed).integers(
        1, max_new_tokens + 1, size=len(prompts))

    def frontend():
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            # fixed arrival schedule: request i is due at t0 + i/rate
            due = t0 + i / arrival_rate
            now = time.perf_counter()
            if due > now:
                time.sleep(due - now)
            r = engine.submit(
                p, max_new_tokens=int(budgets[i]),
                on_token=lambda rq, t: emits.setdefault(
                    rq.rid, []).append(time.perf_counter()))
            reqs.append(r)

    th = threading.Thread(target=frontend)
    t0 = time.perf_counter()
    th.start()
    # drain while the frontend is still injecting: idle just means the next
    # arrival has not happened yet
    while th.is_alive() or not engine.scheduler.idle:
        if not engine.step():
            time.sleep(0.0005)
    th.join()
    wall = time.perf_counter() - t0
    return reqs, emits, wall


def run(requests=32, arrival_rate=200.0, max_slots=4, max_prompt_len=16,
        max_new_tokens=4, speculate=2, seed=0, verbose=True) -> list[dict]:
    cfg = registry.smoke_config("granite_3_2b")
    rng = np.random.default_rng(seed)
    # ~25% duplicated prompts so the prefix cache has something to reuse
    uniq = [list(rng.integers(0, cfg.vocab_size,
                              int(rng.integers(2, max_prompt_len + 1))))
            for _ in range(max(1, (3 * requests) // 4))]
    prompts = [uniq[i % len(uniq)] for i in range(requests)]

    def build(gamma):
        plan_cache.reset()
        eng = ServeEngine(cfg, max_slots=max_slots,
                          max_prompt_len=max_prompt_len,
                          max_new_tokens=max_new_tokens, seed=seed,
                          speculate=gamma, prefix_cache=bool(gamma))
        eng.warm()
        return eng

    base = build(0)
    base_reqs, _, base_wall = _serve_under_load(
        base, prompts, max_new_tokens, arrival_rate, seed)
    spec = build(speculate)
    spec_reqs, emits, spec_wall = _serve_under_load(
        spec, prompts, max_new_tokens, arrival_rate, seed)

    exact = sum(list(b.generated) == list(s.generated)
                for b, s in zip(base_reqs, spec_reqs))
    ttft = [(r.first_token_t - r.submit_t) * 1e3
            for r in spec_reqs if r.first_token_t is not None]
    gaps = [(b - a) * 1e3
            for ts in emits.values() for a, b in zip(ts, ts[1:])]
    ttft_p = _percentiles(ttft)
    gap_p = _percentiles(gaps)
    s, bs = spec.summary(), base.summary()
    row = {
        "requests": requests,
        "arrival_rate": arrival_rate,
        "speculate": speculate,
        "finished_base": sum(r.done for r in base_reqs),
        "finished_spec": sum(r.done for r in spec_reqs),
        "token_exact": exact,
        "ttft_p50_ms": round(ttft_p[50], 3),
        "ttft_p99_ms": round(ttft_p[99], 3),
        "tok_latency_p50_ms": round(gap_p[50], 3),
        "tok_latency_p99_ms": round(gap_p[99], 3),
        "acceptance_rate": s["acceptance_rate"],
        "prefix_hit_rate": s["prefix_hit_rate"],
        "base_decode_tok_s": bs["decode_tokens_per_s"],
        "spec_decode_tok_s": s["decode_tokens_per_s"],
        "spec_speedup": round(s["decode_tokens_per_s"]
                              / max(bs["decode_tokens_per_s"], 1e-9), 3),
        "bucket_misses": s["bucket_misses"] + bs["bucket_misses"],
        "bucket_hit_rate": s["bucket_hit_rate"],
        "base_wall_s": round(base_wall, 3),
        "spec_wall_s": round(spec_wall, 3),
    }
    if verbose:
        print(f"{requests} requests @ {arrival_rate:.0f}/s over "
              f"{max_slots} slots, gamma={speculate}: "
              f"{row['finished_spec']} finished, {exact}/{requests} "
              f"token-exact vs baseline")
        print(f"TTFT p50/p99: {row['ttft_p50_ms']:.1f}/"
              f"{row['ttft_p99_ms']:.1f} ms | per-token p50/p99: "
              f"{row['tok_latency_p50_ms']:.1f}/"
              f"{row['tok_latency_p99_ms']:.1f} ms")
        print(f"acceptance {row['acceptance_rate']:.1%} | prefix hits "
              f"{row['prefix_hit_rate']:.1%} | decode tok/s "
              f"{row['base_decode_tok_s']:.1f} -> "
              f"{row['spec_decode_tok_s']:.1f} "
              f"({row['spec_speedup']:.2f}x) | bucket misses "
              f"{row['bucket_misses']}")
    return [row]


def check(row: dict) -> list[str]:
    """The self-gate: what must hold for ANY speculative serve run."""
    problems = []
    if row["finished_spec"] != row["requests"]:
        problems.append(f"finished {row['finished_spec']}/{row['requests']}")
    if row["finished_base"] != row["requests"]:
        problems.append(
            f"baseline finished {row['finished_base']}/{row['requests']}")
    if row["token_exact"] != row["requests"]:
        problems.append(f"only {row['token_exact']}/{row['requests']} "
                        "requests token-exact vs the baseline engine")
    if not 0.0 < row["acceptance_rate"] <= 1.0:
        problems.append(f"acceptance_rate {row['acceptance_rate']} not in "
                        "(0, 1] — no draft ever survived verify")
    if row["prefix_hit_rate"] <= 0.0:
        problems.append("prefix cache never hit on a duplicated workload")
    if row["bucket_misses"]:
        problems.append(f"{row['bucket_misses']} bucket misses — a serve "
                        "step compiled a shape warm() did not cover")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--arrival-rate", type=float, default=200.0,
                    help="fixed request arrival rate, req/s")
    ap.add_argument("--speculate", type=int, default=2, metavar="GAMMA")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--gen", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless the run is token-exact, "
                         "fully finished, accepting drafts, and bucket-"
                         "miss-free")
    args = ap.parse_args(argv)
    [row] = run(requests=args.requests, arrival_rate=args.arrival_rate,
                speculate=args.speculate, max_slots=args.max_slots,
                max_new_tokens=args.gen, seed=args.seed)
    if args.check:
        problems = check(row)
        if problems:
            print("serve_latency CHECK FAILED:")
            for p in problems:
                print(f"  - {p}")
            return 1
        print("serve_latency check green: token-exact under load, "
              f"{row['requests']} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
