"""Fig. 5: operator-level effective-GFLOPS on LLM linear-layer shapes.

Measured on the real host CPU (the paper also evaluates CPUs) for a reduced
M-sweep, and modeled for TPU v5e from the Decision Module. Reports FalconGEMM
(decision-dispatched LCMA), the forced-GEMM baseline, and an AlphaTensor-style
unfused staged LCMA (the paper's LCMA competitor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, codegen, decision as dec
from repro.core.falcon_gemm import FalconConfig, falcon_matmul
from repro.core.hardware import TPU_V5E, calibrate_cpu
from .common import LLM_SHAPES, effective_gflops, time_fn


def run(ms=(512, 1024, 2048), models=("hunyuan_video",), max_shapes=3,
        verbose=True) -> list[dict]:
    # calibrate out of cache; require a 15% predicted margin before switching
    # (XLA-CPU model error bound — see EXPERIMENTS.md §Perf lesson 1)
    hw = calibrate_cpu(1536)
    rows = []
    rng = np.random.default_rng(0)
    for model in models:
        for (N, K) in LLM_SHAPES[model][:max_shapes]:
            for M in ms:
                A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
                B = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
                d = dec.decide(M, N, K, hw, "float32", min_speedup=1.15)
                cfg = (FalconConfig(mode=d.algo.name, hardware=hw.name)
                       if d.use_lcma else FalconConfig(mode="gemm"))
                f_falcon = jax.jit(lambda a, b: falcon_matmul(a, b, cfg))
                f_gemm = jax.jit(lambda a, b: a @ b)
                t_f = time_fn(f_falcon, A, B)
                t_g = time_fn(f_gemm, A, B)
                # AlphaTensor-style: unfused staged Strassen, fragmented GEMMs
                g_alpha = codegen.generate(alg.get("strassen"),
                                           codegen.CodegenOptions(
                                               fused=False, downcast_h=False,
                                               gemm_backend="loop"))
                Ap = jnp.pad(A, ((0, (-M) % 2), (0, (-K) % 2)))
                Bp = jnp.pad(B, ((0, (-K) % 2), (0, (-N) % 2)))
                t_a = time_fn(jax.jit(g_alpha.fn), Ap, Bp)
                row = {
                    "model": model, "M": M, "N": N, "K": K,
                    "algo": d.algo.name if d.use_lcma else "gemm",
                    "falcon_gflops": effective_gflops(M, N, K, t_f),
                    "gemm_gflops": effective_gflops(M, N, K, t_g),
                    "alphatensor_style_gflops": effective_gflops(M, N, K, t_a),
                    "pred_speedup": d.speedup,
                    "meas_speedup": t_g / t_f,
                    "v5e_pred_eff_tflops": dec.effective_tflops(
                        M, N, K, dec.decide(M, N, K, TPU_V5E).seconds),
                }
                rows.append(row)
                if verbose:
                    print(f"{model} M={M} N={N} K={K}: falcon={row['falcon_gflops']:.1f} "
                          f"gemm={row['gemm_gflops']:.1f} alpha-style={row['alphatensor_style_gflops']:.1f} "
                          f"GF/s ({row['algo']}, meas x{row['meas_speedup']:.3f} "
                          f"pred x{row['pred_speedup']:.3f})")
    return rows


def main():
    rows = run()
    falcon_wins = sum(1 for r in rows if r["meas_speedup"] > 1.0 and r["algo"] != "gemm")
    lcma_rows = [r for r in rows if r["algo"] != "gemm"]
    print(f"\nLCMA selected on {len(lcma_rows)}/{len(rows)} shapes; "
          f"measured speedup on {falcon_wins}/{len(lcma_rows)} of those")
    for r in rows:
        print(f"operator_level,{r['model']},{r['M']}x{r['N']}x{r['K']},"
              f"{r['falcon_gflops']:.1f},{r['gemm_gflops']:.1f},{r['meas_speedup']:.4f}")


if __name__ == "__main__":
    main()
