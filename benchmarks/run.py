"""Benchmark driver — one function per paper table/figure.

Prints ``name,...`` CSV lines per benchmark plus a summary. Run:
    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from . import (e2e_llm, operator_level, plan_cache, precision,
                   roofline_fig8, stepwise)

    t0 = time.time()
    print("=" * 72)
    print("Fig.5 operator-level effective GFLOPS (CPU measured + v5e modeled)")
    print("=" * 72)
    operator_level.run(ms=(512, 1024) if args.quick else (512, 1024, 2048),
                       max_shapes=2 if args.quick else 3)

    print("\n" + "=" * 72)
    print("Fig.6 end-to-end LLM prefill with FalconGEMM backend")
    print("=" * 72)
    e2e_llm.run(seqs=(128, 256) if args.quick else (128, 256, 512))

    print("\n" + "=" * 72)
    print("Fig.7 step-wise Execution Module evaluation")
    print("=" * 72)
    stepwise.run(sizes=(512, 1024) if args.quick else (512, 1024, 2048))

    print("\n" + "=" * 72)
    print("Fig.8 roofline + Decision Module selection (v5e model)")
    print("=" * 72)
    roofline_fig8.run()

    print("\n" + "=" * 72)
    print("Plan cache amortization + autotuned decision quality")
    print("=" * 72)
    plan_cache.run(sizes=(512, 1024) if args.quick else (512, 1024, 2048))

    print("\n" + "=" * 72)
    print("IV-F numerical precision: fused vs downcast-H")
    print("=" * 72)
    precision.run(sizes=(64, 128) if args.quick else (64, 128, 256))

    _dryrun_summary()
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")


def _dryrun_summary(out_dir: str = "artifacts/dryrun", perf_dir: str = "artifacts/perf"):
    """Multi-pod dry-run + roofline headline (full tables: benchmarks.report)."""
    import glob
    import json
    import os
    if not os.path.isdir(out_dir):
        return
    print("\n" + "=" * 72)
    print("Multi-pod dry-run + roofline summary (from artifacts/)")
    print("=" * 72)
    recs = [json.load(open(f)) for f in glob.glob(os.path.join(out_dir, "*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    print(f"cells: {len(ok)} compiled OK, {len(skip)} skipped (justified), "
          f"{len(err)} errors")
    with_frac = [(r["arch"], r["shape"], r["mesh"],
                  r["analytic"]["roofline_fraction"], r["analytic"]["bottleneck"])
                 for r in ok if "analytic" in r]
    for a, s, m, f, b in sorted(with_frac, key=lambda x: -x[3])[:5]:
        print(f"  best: {a} x {s} x {m}: frac={f:.3f} ({b}-bound)")
    if os.path.isdir(perf_dir):
        print("perf-loop records (EXPERIMENTS.md §Perf):")
        for f in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
            r = json.load(open(f))
            if r.get("status") == "ok":
                a = r["analytic"]
                print(f"  {r.get('tag', '?'):26s} {r['arch']} x {r['shape']}: "
                      f"frac={a['roofline_fraction']:.4f} ({a['bottleneck']})")


if __name__ == "__main__":
    main()
