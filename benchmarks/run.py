"""Benchmark driver — one function per paper table/figure.

Prints ``name,...`` CSV lines per benchmark plus a summary. Each section is
failure-isolated: an exception mid-benchmark is reported for that section,
the remaining sections still run, and the process exits non-zero — CI can no
longer go green on a benchmark that silently died mid-run. Run:

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json BENCH.json]

``--json`` writes the machine-readable per-benchmark report (tokens/s,
GFLOPS, rates) via :mod:`benchmarks.report`, the file CI uploads and gates
regressions on.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _sections(quick: bool):
    from . import (distributed, e2e_llm, moe_grouped, operator_level,
                   plan_cache, precision, quant_serve, roofline_fig8,
                   serve_bench, serve_latency, stepwise, train_bwd)

    return [
        ("operator_level",
         "Fig.5 operator-level effective GFLOPS (CPU measured + v5e modeled)",
         lambda: operator_level.run(ms=(512, 1024) if quick else (512, 1024, 2048),
                                    max_shapes=2 if quick else 3)),
        ("e2e_llm",
         "Fig.6 end-to-end LLM prefill with FalconGEMM backend",
         lambda: e2e_llm.run(seqs=(128, 256) if quick else (128, 256, 512))),
        ("stepwise",
         "Fig.7 step-wise Execution Module evaluation",
         lambda: stepwise.run(sizes=(512, 1024) if quick else (512, 1024, 2048))),
        ("roofline_fig8",
         "Fig.8 roofline + Decision Module selection (v5e model)",
         lambda: roofline_fig8.run()),
        ("plan_cache",
         "Plan cache amortization + autotuned decision quality",
         lambda: plan_cache.run(sizes=(512, 1024) if quick else (512, 1024, 2048))),
        ("serve",
         "Continuous-batching serve engine (bucketed plan reuse)",
         lambda: serve_bench.run(requests=8 if quick else 16,
                                 max_prompt_len=16 if quick else 32,
                                 max_new_tokens=4 if quick else 8)),
        ("serve_latency",
         "Speculative + prefix-reuse serving under fixed-rate load "
         "(TTFT, per-token p50/p99, acceptance, token-exactness)",
         lambda: serve_latency.run(requests=12 if quick else 24,
                                   max_new_tokens=4 if quick else 6)),
        ("quant_serve",
         "int8-quantized serving tier: tokens/s + prefix-matched logit "
         "error vs fp32",
         lambda: quant_serve.run(requests=6 if quick else 12,
                                 max_new_tokens=4 if quick else 8)),
        ("train_bwd",
         "Planned custom-VJP backward pass vs differentiate-through",
         lambda: train_bwd.run(sizes=(256, 512) if quick else (512, 1024))),
        ("moe_grouped",
         "Grouped batched LCMA: grouped vs vmap vs eager (MoE expert shapes)",
         lambda: moe_grouped.run(
             shapes=((8, 128, 256, 512),) if quick
             else ((8, 128, 256, 512), (8, 256, 512, 512)))),
        ("distributed",
         "Sharded Decision Module: layout pricing at D=8 (v5e model)",
         lambda: distributed.run(
             shapes=((4096, 4096, 4096), (8192, 8192, 8192)) if quick
             else ((4096, 4096, 4096), (8192, 8192, 8192),
                   (8192, 8192, 32768)))),
        ("precision",
         "IV-F numerical precision: fused vs downcast-H",
         lambda: precision.run(sizes=(64, 128) if quick else (64, 128, 256))),
    ]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable benchmark report "
                         "(benchmarks.report schema) to PATH")
    args = ap.parse_args(argv)

    t0 = time.time()
    results: dict[str, object] = {}
    failures: list[str] = []
    for name, title, fn in _sections(args.quick):
        print(("\n" if results or failures else "") + "=" * 72)
        print(title)
        print("=" * 72)
        try:
            results[name] = fn()
        except Exception:
            failures.append(name)
            print(f"\nFAILED section {name!r}:", file=sys.stderr)
            traceback.print_exc()

    _dryrun_summary()

    if args.json:
        from . import report
        path = report.write_json(results, args.json, quick=args.quick,
                                 failures=failures)
        print(f"\nwrote machine-readable report -> {path}")

    status = "with FAILURES in " + ", ".join(failures) if failures else "OK"
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s [{status}]")
    return 1 if failures else 0


def _dryrun_summary(out_dir: str = "artifacts/dryrun", perf_dir: str = "artifacts/perf"):
    """Multi-pod dry-run + roofline headline (full tables: benchmarks.report)."""
    import glob
    import json
    import os
    if not os.path.isdir(out_dir):
        return
    print("\n" + "=" * 72)
    print("Multi-pod dry-run + roofline summary (from artifacts/)")
    print("=" * 72)
    recs = [json.load(open(f)) for f in glob.glob(os.path.join(out_dir, "*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    print(f"cells: {len(ok)} compiled OK, {len(skip)} skipped (justified), "
          f"{len(err)} errors")
    with_frac = [(r["arch"], r["shape"], r["mesh"],
                  r["analytic"]["roofline_fraction"], r["analytic"]["bottleneck"])
                 for r in ok if "analytic" in r]
    for a, s, m, f, b in sorted(with_frac, key=lambda x: -x[3])[:5]:
        print(f"  best: {a} x {s} x {m}: frac={f:.3f} ({b}-bound)")
    if os.path.isdir(perf_dir):
        print("perf-loop records (EXPERIMENTS.md §Perf):")
        for f in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
            r = json.load(open(f))
            if r.get("status") == "ok":
                a = r["analytic"]
                print(f"  {r.get('tag', '?'):26s} {r['arch']} x {r['shape']}: "
                      f"frac={a['roofline_fraction']:.4f} ({a['bottleneck']})")


if __name__ == "__main__":
    raise SystemExit(main())
