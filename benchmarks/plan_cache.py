"""Plan-cache + autotune benchmarks: amortized decisions, calibrated quality.

Two questions a serving deployment cares about:

  1. *Amortization* — how much trace-time cost does the plan cache remove?
     Times ``falcon_gemm.plan()`` cold (full candidate enumeration) vs warm
     (cache hit) over the paper's §IV-B LLM projection shapes and reports the
     hit count — the acceptance gate that repeated shapes skip enumeration.

  2. *Decision quality* — does the calibrated (autotuned) profile pick better
     than the static table? For square CPU problems we measure standard GEMM
     and the Strassen pipeline wall-clock, then score each profile's decision
     against the measured-faster option.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, autotune, codegen, decision as dec, plan_cache
from repro.core.falcon_gemm import FalconConfig, plan
from repro.core.hardware import CPU_HOST
from repro.core.workloads import paper_projection_shapes
from .common import time_fn


def _time_plan(M, K, N, cfg, reps=5):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        plan(M, K, N, cfg, dtype="bfloat16")
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run_amortization(batch_tokens=(512, 2048), workload="deepseek_r1",
                     verbose=True):
    """Cold vs warm plan() latency + hit rate over LLM serving shapes."""
    cache = plan_cache.configure(path=None)          # fresh in-memory cache
    cfg = FalconConfig(hardware="tpu_v5e")
    shapes = [(m, k, n) for m in batch_tokens
              for k, n in paper_projection_shapes(workload)]
    rows = []
    cold = warm = 0.0
    for (m, k, n) in shapes:
        t0 = time.perf_counter()
        plan(m, k, n, cfg, dtype="bfloat16")
        t_cold = time.perf_counter() - t0
        t_warm = _time_plan(m, k, n, cfg)
        cold += t_cold
        warm += t_warm
        rows.append({"M": m, "K": k, "N": n,
                     "cold_us": t_cold * 1e6, "warm_us": t_warm * 1e6})
    st = cache.stats
    assert st.hits > 0, "plan cache must serve repeated shapes from cache"
    if verbose:
        print(f"{len(shapes)} shapes x {workload}: cold total "
              f"{cold*1e3:.1f} ms, warm total {warm*1e3:.2f} ms "
              f"({cold/max(warm, 1e-12):.0f}x), "
              f"{st.hits} hits / {st.misses} misses "
              f"({st.hit_rate:.0%} hit rate)")
        w = max(rows, key=lambda r: r["cold_us"])
        print(f"worst shape M={w['M']} K={w['K']} N={w['N']}: "
              f"{w['cold_us']:.0f} us cold -> {w['warm_us']:.1f} us warm")
    return rows, st


def run_decision_quality(sizes=(512, 1024, 2048), verbose=True):
    """Score static vs calibrated decisions against measured CPU wall-clock."""
    rep = autotune.autotune(base="cpu_host", backend="jnp", reps=2, warmup=1,
                            validate=False)
    calibrated = rep.profile
    l = alg.get("strassen")
    gen = codegen.generate(l)
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        t_gemm = time_fn(jax.jit(lambda a, b: a @ b), A, B)
        t_lcma = time_fn(jax.jit(gen.fn), A, B)
        measured_lcma_wins = t_lcma < t_gemm
        for label, hw in (("static", CPU_HOST), ("calibrated", calibrated)):
            d = dec.decide(n, n, n, hw, "float32", candidates=[l])
            correct = d.use_lcma == measured_lcma_wins
            rows.append({"n": n, "profile": label, "pick_lcma": d.use_lcma,
                         "measured_lcma_wins": measured_lcma_wins,
                         "correct": correct,
                         "t_gemm_ms": t_gemm * 1e3, "t_lcma_ms": t_lcma * 1e3})
        if verbose:
            r0, r1 = rows[-2], rows[-1]
            print(f"n={n}: measured gemm={r0['t_gemm_ms']:.1f}ms "
                  f"strassen={r0['t_lcma_ms']:.1f}ms | static pick="
                  f"{'lcma' if r0['pick_lcma'] else 'gemm'}"
                  f"({'ok' if r0['correct'] else 'WRONG'}) calibrated pick="
                  f"{'lcma' if r1['pick_lcma'] else 'gemm'}"
                  f"({'ok' if r1['correct'] else 'WRONG'})")
    n_static = sum(r["correct"] for r in rows if r["profile"] == "static")
    n_cal = sum(r["correct"] for r in rows if r["profile"] == "calibrated")
    if verbose:
        print(f"decision accuracy over {len(sizes)} sizes: "
              f"static {n_static}/{len(sizes)}, calibrated {n_cal}/{len(sizes)}")
    return rows


def run(sizes=(512, 1024, 2048), verbose=True):
    rows, st = run_amortization(verbose=verbose)
    quality = run_decision_quality(sizes=sizes, verbose=verbose)
    return {"amortization": rows, "cache_stats": st.as_dict(),
            "quality": quality}


def main():
    out = run()
    for r in out["amortization"]:
        print(f"plan_cache,{r['M']},{r['K']},{r['N']},"
              f"{r['cold_us']:.1f},{r['warm_us']:.2f}")
    for r in out["quality"]:
        print(f"decision_quality,{r['n']},{r['profile']},"
              f"{int(r['pick_lcma'])},{int(r['correct'])}")


if __name__ == "__main__":
    main()
