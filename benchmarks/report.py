"""Render EXPERIMENTS.md tables from the dry-run JSON records."""
from __future__ import annotations

import glob
import json
import os

ARCH_ORDER = ["hymba_1_5b", "gemma3_27b", "granite_3_2b", "starcoder2_15b",
              "mistral_nemo_12b", "kimi_k2_1t", "dbrx_132b", "mamba2_370m",
              "musicgen_large", "pixtral_12b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir="artifacts/dryrun", suffix=""):
    recs = {}
    for f in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], r.get("falcon_mode", "auto"))
        recs[key] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}" if b else "-"


def dryrun_table(recs, mesh="single"):
    lines = ["| arch | shape | status | params | args GB/dev | temp GB/dev | "
             "compile s | HLO GFLOP/dev* | HLO GB/dev* | coll GB/dev* |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, "auto"))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | SKIP (full attention) | | | | | | | |")
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | ok | {r['n_params']/1e9:.2f}B | "
                f"{fmt_bytes(r['argument_bytes'])} | {fmt_bytes(r['temp_bytes'])} | "
                f"{r['compile_s']:.0f} | {rf['hlo_flops']/1e9:.1f} | "
                f"{rf['hlo_bytes']/2**30:.2f} | {rf['coll_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="single"):
    lines = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
             "6ND/2ND TFLOP | useful ratio | roofline frac | one-line next move |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    moves = {
        "collective": "cut TP collectives: remap small-model TP onto DP/ZeRO or overlap",
        "compute": "raise MXU efficiency: LCMA on big GEMMs / larger per-core tiles",
        "memory": "shrink HBM traffic: fuse combines, precombine weights, cast opt state",
    }
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, "auto"))
            if r is None or r["status"] != "ok":
                continue
            an = r["analytic"]
            lines.append(
                f"| {a} | {s} | {an['t_compute']:.4f} | {an['t_memory']:.4f} | "
                f"{an['t_collective']:.4f} | {an['bottleneck']} | "
                f"{an['model_flops']/1e12:.1f} | {an['useful_ratio']:.2f} | "
                f"{an['roofline_fraction']:.3f} | {moves[an['bottleneck']]} |")
    return "\n".join(lines)


def main():
    recs = load()
    print("## Dry-run (single-pod 16x16)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single-pod, analytic)\n")
    print(roofline_table(recs, "single"))


if __name__ == "__main__":
    main()
