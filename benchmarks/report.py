"""Benchmark reporting: EXPERIMENTS.md tables + the machine-readable report.

Two surfaces:

* ``python -m benchmarks.report`` (default) — render EXPERIMENTS.md tables
  from the dry-run JSON records, as before.
* the **machine-readable path** — :func:`to_metrics` / :func:`write_json`
  flatten section results from :mod:`benchmarks.run` into a flat
  ``{metric_name: {value, unit, higher_is_better}}`` report (tokens/s,
  GFLOPS, hit rates, error norms), and ``--check NEW --baseline BASE``
  exits non-zero when any baseline metric regressed by more than its
  tolerance (default 20%) — the CI ``bench-smoke`` gate:

      python -m benchmarks.run --quick --json BENCH_3.json
      python -m benchmarks.report --check BENCH_3.json \\
          --baseline benchmarks/baseline_cpu.json

  Wall-clock metrics carry wider per-metric ``tolerance`` values in the
  committed baseline (CPU timing noise across CI hosts); ratios and rates
  use the default.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

REPORT_VERSION = 1
DEFAULT_TOLERANCE = 0.20

ARCH_ORDER = ["hymba_1_5b", "gemma3_27b", "granite_3_2b", "starcoder2_15b",
              "mistral_nemo_12b", "kimi_k2_1t", "dbrx_132b", "mamba2_370m",
              "musicgen_large", "pixtral_12b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir="artifacts/dryrun", suffix=""):
    recs = {}
    for f in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], r.get("falcon_mode", "auto"))
        recs[key] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}" if b else "-"


def dryrun_table(recs, mesh="single"):
    lines = ["| arch | shape | status | params | args GB/dev | temp GB/dev | "
             "compile s | HLO GFLOP/dev* | HLO GB/dev* | coll GB/dev* |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, "auto"))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | SKIP (full attention) | | | | | | | |")
                continue
            rf = r["roofline"]
            lines.append(
                f"| {a} | {s} | ok | {r['n_params']/1e9:.2f}B | "
                f"{fmt_bytes(r['argument_bytes'])} | {fmt_bytes(r['temp_bytes'])} | "
                f"{r['compile_s']:.0f} | {rf['hlo_flops']/1e9:.1f} | "
                f"{rf['hlo_bytes']/2**30:.2f} | {rf['coll_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="single"):
    lines = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
             "6ND/2ND TFLOP | useful ratio | roofline frac | one-line next move |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    moves = {
        "collective": "cut TP collectives: remap small-model TP onto DP/ZeRO or overlap",
        "compute": "raise MXU efficiency: LCMA on big GEMMs / larger per-core tiles",
        "memory": "shrink HBM traffic: fuse combines, precombine weights, cast opt state",
    }
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, "auto"))
            if r is None or r["status"] != "ok":
                continue
            an = r["analytic"]
            lines.append(
                f"| {a} | {s} | {an['t_compute']:.4f} | {an['t_memory']:.4f} | "
                f"{an['t_collective']:.4f} | {an['bottleneck']} | "
                f"{an['model_flops']/1e12:.1f} | {an['useful_ratio']:.2f} | "
                f"{an['roofline_fraction']:.3f} | {moves[an['bottleneck']]} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Machine-readable benchmark report (CI bench-smoke artifact + gate)
# ---------------------------------------------------------------------------

def _metric(value, unit, higher_is_better=True):
    return {"value": float(value), "unit": unit,
            "higher_is_better": bool(higher_is_better)}


def to_metrics(results: dict) -> dict:
    """Flatten ``benchmarks.run`` section results into named metrics.

    Only sections present in ``results`` contribute (a failed section is
    simply absent — the regression check then flags its missing baseline
    metrics). Names are stable: ``<section>.<quantity>[_qualifier]``.
    """
    m: dict[str, dict] = {}
    for r in results.get("operator_level") or []:
        key = f"M{r['M']}_N{r['N']}_K{r['K']}"
        m[f"operator_level.falcon_gflops_{key}"] = _metric(r["falcon_gflops"], "GFLOPS")
        m[f"operator_level.meas_speedup_{key}"] = _metric(r["meas_speedup"], "x")
    for r in results.get("e2e_llm") or []:
        m[f"e2e_llm.speedup_S{r['S']}"] = _metric(r["speedup"], "x")
        m[f"e2e_llm.lcma_layer_frac_S{r['S']}"] = _metric(r["lcma_layer_frac"], "frac")
    for r in results.get("stepwise") or []:
        m[f"stepwise.alg2_gflops_n{r['n']}"] = _metric(r["alg2_gflops"], "GFLOPS")
        m[f"stepwise.alg2_over_alg1_n{r['n']}"] = _metric(
            r["alg2_gflops"] / max(r["alg1_gflops"], 1e-9), "x")
    rows = results.get("roofline_fig8") or []
    if rows:
        m["roofline_fig8.best_decision_tflops"] = _metric(
            max(r["decision_tflops"] for r in rows), "TFLOPS")
    pc = results.get("plan_cache") or {}
    st = pc.get("cache_stats") if isinstance(pc, dict) else None
    if st:
        m["plan_cache.hit_rate"] = _metric(st["hit_rate"], "frac")
    if isinstance(pc, dict) and pc.get("amortization"):
        am = pc["amortization"]
        cold = sum(r["cold_us"] for r in am)
        warm = sum(r["warm_us"] for r in am)
        m["plan_cache.amortization_x"] = _metric(cold / max(warm, 1e-9), "x")
    if isinstance(pc, dict) and pc.get("quality"):
        cal = [r for r in pc["quality"] if r["profile"] == "calibrated"]
        if cal:
            m["plan_cache.calibrated_accuracy"] = _metric(
                sum(r["correct"] for r in cal) / len(cal), "frac")
    for r in results.get("serve") or []:
        m["serve.tokens_per_s"] = _metric(r["tokens_per_s"], "tok/s")
        m["serve.decode_tokens_per_s"] = _metric(r["decode_tokens_per_s"], "tok/s")
        m["serve.bucket_hit_rate"] = _metric(r["bucket_hit_rate"], "frac")
        m["serve.padding_waste"] = _metric(r["padding_waste"], "frac",
                                           higher_is_better=False)
        m["serve.plan_cache_hit_rate"] = _metric(r["plan_cache_hit_rate"], "frac")
    for r in results.get("serve_latency") or []:
        m["serve_latency.token_exact_frac"] = _metric(
            r["token_exact"] / max(r["requests"], 1), "frac")
        m["serve_latency.acceptance_rate"] = _metric(
            r["acceptance_rate"], "frac")
        m["serve_latency.prefix_hit_rate"] = _metric(
            r["prefix_hit_rate"], "frac")
        m["serve_latency.spec_decode_tok_s"] = _metric(
            r["spec_decode_tok_s"], "tok/s")
        m["serve_latency.ttft_p99_ms"] = _metric(
            r["ttft_p99_ms"], "ms", higher_is_better=False)
        m["serve_latency.tok_latency_p99_ms"] = _metric(
            r["tok_latency_p99_ms"], "ms", higher_is_better=False)
    for r in results.get("quant_serve") or []:
        m["quant_serve.int8_gemm_gflops"] = _metric(
            r["int8_gemm_gflops"], "GFLOPS")
        m["quant_serve.tokens_per_s_fp32"] = _metric(
            r["fp_tokens_per_s"], "tok/s")
        m["quant_serve.tokens_per_s_int8"] = _metric(
            r["q_tokens_per_s"], "tok/s")
        m["quant_serve.quant_weight_frac"] = _metric(
            r["quant_weight_frac"], "frac")
        m["quant_serve.max_rel_logit_err"] = _metric(
            r["max_rel_logit_err"], "rel_err", higher_is_better=False)
    for r in results.get("train_bwd") or []:
        m[f"train_bwd.planned_bwd_gflops_n{r['n']}"] = _metric(
            r["planned_bwd_gflops"], "GFLOPS")
        m[f"train_bwd.planned_over_through_n{r['n']}"] = _metric(
            r["planned_over_through"], "x")
        m[f"train_bwd.bwd_planned_frac_n{r['n']}"] = _metric(
            r["bwd_planned_frac"], "frac")
    for r in results.get("moe_grouped") or []:
        key = f"E{r['E']}_C{r['C']}_K{r['K']}_N{r['N']}"
        m[f"moe_grouped.grouped_gflops_{key}"] = _metric(
            r["grouped_gflops"], "GFLOPS")
        m[f"moe_grouped.grouped_over_vmap_{key}"] = _metric(
            r["grouped_over_vmap"], "x")
        m[f"moe_grouped.combine_hoist_frac_{key}"] = _metric(
            r["combine_hoist_frac"], "frac")
    for r in results.get("distributed") or []:
        key = f"M{r['M']}_K{r['K']}_N{r['N']}_D{r['D']}"
        m[f"distributed.scaling_eff_{key}"] = _metric(r["scaling_eff"], "frac")
        m[f"distributed.coll_frac_{key}"] = _metric(
            r["coll_frac"], "frac", higher_is_better=False)
        m[f"distributed.layout_flip_{key}"] = _metric(r["layout_flip"], "bool")
    for r in results.get("precision") or []:
        m[f"precision.fused_rel_err_{r['algo']}_n{r['n']}"] = _metric(
            r["fused_rel_err"], "rel_err", higher_is_better=False)
    return m


def write_json(results: dict, path: str, quick: bool = False,
               failures: list[str] | None = None) -> str:
    doc = {
        "version": REPORT_VERSION,
        "quick": bool(quick),
        "failures": list(failures or []),
        "metrics": to_metrics(results),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


def check_regressions(new: dict, baseline: dict,
                      default_tolerance: float = DEFAULT_TOLERANCE) -> list[str]:
    """Compare a new report against a committed baseline.

    Every baseline metric must exist in the new report and sit within its
    tolerance band on the bad side (better-than-baseline never fails).
    Returns human-readable failure strings; empty means green.
    """
    problems: list[str] = []
    if new.get("failures"):
        problems.append(f"benchmark sections failed: {new['failures']}")
    new_metrics = new.get("metrics", {})
    for name, base in sorted(baseline.get("metrics", {}).items()):
        got = new_metrics.get(name)
        if got is None:
            problems.append(f"{name}: missing from new report "
                            f"(baseline {base['value']:g})")
            continue
        tol = float(base.get("tolerance", default_tolerance))
        bval, nval = float(base["value"]), float(got["value"])
        if base.get("higher_is_better", True):
            floor = bval * (1.0 - tol)
            if nval < floor:
                problems.append(f"{name}: {nval:g} < {floor:g} "
                                f"(baseline {bval:g} - {tol:.0%})")
        else:
            ceil = bval * (1.0 + tol)
            if nval > ceil:
                problems.append(f"{name}: {nval:g} > {ceil:g} "
                                f"(baseline {bval:g} + {tol:.0%})")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", default=None, metavar="NEW_JSON",
                    help="machine-readable report to gate (benchmarks.run --json)")
    ap.add_argument("--baseline", default=None, metavar="BASE_JSON",
                    help="committed baseline to compare against")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="default allowed regression fraction (default 0.2)")
    args = ap.parse_args(argv)

    if args.check:
        if not args.baseline:
            ap.error("--check requires --baseline")
        with open(args.check) as f:
            new = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
        problems = check_regressions(new, base, default_tolerance=args.tolerance)
        n = len(base.get("metrics", {}))
        if problems:
            print(f"REGRESSIONS ({len(problems)} of {n} gated metrics):")
            for p in problems:
                print(f"  - {p}")
            return 1
        print(f"benchmark gate green: {n} baseline metrics within tolerance")
        return 0

    recs = load()
    print("## Dry-run (single-pod 16x16)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single-pod, analytic)\n")
    print(roofline_table(recs, "single"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
