"""Fig. 6: end-to-end prefill speedup with the FalconGEMM backend.

Runs a reduced-but-real decoder LM (granite-family) prefill at several
sequence lengths on the host CPU, with (a) standard GEMM everywhere and
(b) the FalconGEMM backend (Decision-Module dispatch per layer shape).
Also reports the fraction of linear layers where LCMA was selected — the
paper's "97.9% / 85.7% / 57.7% of layers use LCMA" statistic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro.configs import registry
from repro.core import decision as dec
from repro.core.hardware import calibrate_cpu
from repro.models import model as M
from .common import time_fn


def _layer_shapes(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return [(d, H * hd), (d, Hkv * hd), (d, Hkv * hd), (H * hd, d),
            (d, ff), (d, ff), (ff, d)]


def run(seqs=(128, 256, 512), batch=2, verbose=True):
    hw = calibrate_cpu(1536)
    cfg = dataclasses.replace(
        registry.smoke_config("granite_3_2b"),
        d_model=512, d_ff=2048, num_heads=8, num_kv_heads=4, head_dim=64,
        num_layers=4, vocab_size=1024)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    for S in seqs:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, S)), jnp.int32)
        f_std = M.falcon_config_for(dataclasses.replace(cfg, use_falcon=False))
        f_fal = dataclasses.replace(
            M.falcon_config_for(cfg), hardware=hw.name, min_speedup=1.15)

        def fwd(fc):
            def run_fwd(p, t):
                with falcon.use(fc):
                    return M.forward(p, cfg, t, logits_mode="last")[0]
            return jax.jit(run_fwd)

        t_std = time_fn(fwd(f_std), params, tokens)
        t_fal = time_fn(fwd(f_fal), params, tokens)
        # per-layer LCMA selection ratio at this M
        Mtok = batch * S
        picks = [dec.decide(Mtok, N, K, hw, "float32").use_lcma
                 for (K, N) in _layer_shapes(cfg)]
        rows.append({"S": S, "std_ms": t_std * 1e3, "falcon_ms": t_fal * 1e3,
                     "speedup": t_std / t_fal,
                     "lcma_layer_frac": float(np.mean(picks))})
        if verbose:
            r = rows[-1]
            print(f"S={S}: std={r['std_ms']:.1f}ms falcon={r['falcon_ms']:.1f}ms "
                  f"x{r['speedup']:.3f} | LCMA on {r['lcma_layer_frac']:.0%} of layers")
    return rows


def main():
    for r in run():
        print(f"e2e_llm,{r['S']},{r['std_ms']:.2f},{r['falcon_ms']:.2f},"
              f"{r['speedup']:.4f},{r['lcma_layer_frac']:.2f}")


if __name__ == "__main__":
    main()
