"""Fig. 7: step-wise evaluation of the Execution Module.

Optimization path: Algorithm 1 (staged, fragmented GEMMs, H materialized)
-> Algorithm 2 jnp (grouped combines + one batched GEMM)
-> Algorithm 2 Pallas-fused (H never leaves VMEM — *TPU-target*; measured
   here via the Decision-Module memory model + validated in interpret mode).

CPU wall-clock covers the first two; the fused-H saving is reported as the
modeled bandwidth-term delta (Eq. 9 -> Eq. 10), since the container has no
TPU to time the Pallas kernel on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, codegen, decision as dec
from repro.core.hardware import TPU_V5E, calibrate_cpu
from .common import effective_gflops, time_fn


def run(sizes=(512, 1024, 2048), verbose=True):
    l = alg.get("strassen")
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        g1 = codegen.generate(l, codegen.CodegenOptions(fused=False,
                                                        gemm_backend="loop"))
        g2 = codegen.generate(l, codegen.CodegenOptions(fused=True))
        t_gemm = time_fn(jax.jit(lambda a, b: a @ b), A, B)
        t_alg1 = time_fn(jax.jit(g1.fn), A, B)
        t_alg2 = time_fn(jax.jit(g2.fn), A, B)
        # modeled v5e stage times: unfused vs fused (H-traffic elimination)
        e_unf = dec.estimate(l, n, n, n, TPU_V5E, fused=False)
        e_fus = dec.estimate(l, n, n, n, TPU_V5E, fused=True)
        rows.append({
            "n": n,
            "gemm_gflops": effective_gflops(n, n, n, t_gemm),
            "alg1_gflops": effective_gflops(n, n, n, t_alg1),
            "alg2_gflops": effective_gflops(n, n, n, t_alg2),
            "v5e_unfused_us": e_unf.time * 1e6,
            "v5e_fused_us": e_fus.time * 1e6,
            "fused_h_bytes_saved": sum(s.bytes for s in e_unf.stages)
                                   - sum(s.bytes for s in e_fus.stages),
        })
        if verbose:
            r = rows[-1]
            print(f"n={n}: cuBLAS-analogue={r['gemm_gflops']:.1f} "
                  f"Alg1={r['alg1_gflops']:.1f} Alg2={r['alg2_gflops']:.1f} GF/s | "
                  f"v5e model: unfused {r['v5e_unfused_us']:.0f}us -> fused "
                  f"{r['v5e_fused_us']:.0f}us (saves {r['fused_h_bytes_saved']/2**20:.0f} MiB)")
    return rows


def main():
    for r in run():
        print(f"stepwise,{r['n']},{r['alg1_gflops']:.1f},{r['alg2_gflops']:.1f},"
              f"{r['v5e_unfused_us']:.1f},{r['v5e_fused_us']:.1f}")


if __name__ == "__main__":
    main()
