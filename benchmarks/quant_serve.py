"""int8-quantized serving tier vs fp32: throughput, engagement, logit error.

Serves the same ragged request set through two ServeEngines built from the
same seed — fp32 and ``quantize=True`` — on a widened granite smoke config
(d_model 256: large enough that the Decision Module actually selects the
quantized LCMA tier for the serving buckets) and reports:

* raw int8 vs fp32 GEMM GFLOPS on a probe shape (what the decision tier's
  ``FLOPS_int8`` pricing is about);
* engine tokens/s for both tiers;
* quant-tier engagement: the fraction of precombined PlannedWeights that
  carry offline-quantized B̃q + scales;
* max *prefix-matched* relative logit error — step ``t`` of a request is
  comparable only while both engines generated identical tokens up to ``t``
  (greedy decode diverging on a near-tie changes every downstream context).

``--check`` is the CI gate: exits non-zero when the error exceeds
``REL_BUDGET`` or either engine fails to serve every request.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import plan_cache
from repro.core.engine import PlannedWeight
from repro.serve import ServeEngine, StepLoop

# Relative logit-error ceiling for blockwise int8 weights at these dims
# (mirrors tests/test_quant_serve.py; measured headroom is ~3x).
REL_BUDGET = 0.15


def _widened_cfg():
    return registry.lcma_smoke_config("granite_3_2b")


def _gemm_gflops(dtype, M=512, K=512, N=512, reps=3):
    a = jnp.ones((M, K), dtype)
    b = jnp.ones((K, N), dtype)
    acc = jnp.int32 if dtype == jnp.int8 else jnp.float32
    f = jax.jit(lambda x, y: jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())), preferred_element_type=acc))
    jax.block_until_ready(f(a, b))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(a, b))
    return 2.0 * M * N * K * reps / (time.perf_counter() - t0) / 1e9


def _serve(cfg, *, quantize, requests, max_slots, max_prompt_len,
           max_new_tokens, seed):
    plan_cache.reset()
    engine = ServeEngine(cfg, max_slots=max_slots,
                         max_prompt_len=max_prompt_len,
                         max_new_tokens=max_new_tokens,
                         record_logits=True, seed=seed, quantize=quantize)
    rng = np.random.default_rng(seed + 11)
    for _ in range(requests):
        plen = int(rng.integers(4, max_prompt_len + 1))
        engine.submit(rng.integers(0, cfg.vocab_size, plen),
                      max_new_tokens=int(rng.integers(2, max_new_tokens + 1)))
    done = StepLoop(engine).run_until_idle()
    return engine, sorted(done, key=lambda r: r.rid)


def _quantized_weights(engine) -> int:
    leaves = jax.tree_util.tree_leaves(
        engine.params, is_leaf=lambda x: isinstance(x, PlannedWeight))
    return sum(1 for x in leaves
               if isinstance(x, PlannedWeight) and x.quantized)


def _max_rel_logit_err(fp_done, q_done) -> tuple[float, int]:
    """Max prefix-matched |logit_q - logit_fp| / max|logit_fp|, #steps."""
    worst, compared = 0.0, 0
    for rf, rq in zip(fp_done, q_done):
        scale = max(float(np.max(np.abs(np.asarray(l)))) for l in rf.logits)
        for t, (lf, lq) in enumerate(zip(rf.logits, rq.logits)):
            if rf.generated[:t] != rq.generated[:t]:
                break
            err = float(np.max(np.abs(np.asarray(lf) - np.asarray(lq))))
            worst = max(worst, err / max(scale, 1e-30))
            compared += 1
    return worst, compared


def run(requests=12, max_slots=4, max_prompt_len=32, max_new_tokens=8,
        seed=0, verbose=True) -> list[dict]:
    cfg = _widened_cfg()
    fp_gflops = _gemm_gflops(jnp.float32)
    i8_gflops = _gemm_gflops(jnp.int8)

    kw = dict(requests=requests, max_slots=max_slots,
              max_prompt_len=max_prompt_len, max_new_tokens=max_new_tokens,
              seed=seed)
    fp_engine, fp_done = _serve(cfg, quantize=False, **kw)
    q_engine, q_done = _serve(cfg, quantize=True, **kw)

    nq = _quantized_weights(q_engine)
    n_pre = max(q_engine.n_precombined, 1)
    err, compared = _max_rel_logit_err(fp_done, q_done)
    row = {
        "requests": requests,
        "fp_finished": len(fp_done), "q_finished": len(q_done),
        "fp32_gemm_gflops": fp_gflops, "int8_gemm_gflops": i8_gflops,
        "fp_tokens_per_s": fp_engine.summary()["tokens_per_s"],
        "q_tokens_per_s": q_engine.summary()["tokens_per_s"],
        "quant_weights": nq, "precombined": q_engine.n_precombined,
        "quant_weight_frac": nq / n_pre,
        "max_rel_logit_err": err, "compared_steps": compared,
        "rel_budget": REL_BUDGET,
    }
    if verbose:
        print(f"raw GEMM 512^3: {fp_gflops:.1f} GF/s fp32 vs "
              f"{i8_gflops:.1f} GF/s int8 "
              f"({i8_gflops / max(fp_gflops, 1e-9):.2f}x)")
        print(f"served {len(q_done)}/{requests} quant, "
              f"{len(fp_done)}/{requests} fp32: "
              f"{row['q_tokens_per_s']:.1f} vs {row['fp_tokens_per_s']:.1f} tok/s")
        print(f"quant tier: {nq}/{q_engine.n_precombined} precombined "
              f"weights carry int8 B̃q ({row['quant_weight_frac']:.0%})")
        print(f"logit error: max {err:.4f} relative over {compared} "
              f"prefix-matched steps (budget {REL_BUDGET})")
    return [row]


def check(row: dict) -> list[str]:
    problems = []
    if row["q_finished"] != row["requests"]:
        problems.append(f"quant engine served {row['q_finished']}/"
                        f"{row['requests']} requests")
    if row["quant_weights"] < 1:
        problems.append("quant tier never engaged: 0 quantized PlannedWeights")
    if row["compared_steps"] < row["requests"]:
        problems.append(f"only {row['compared_steps']} comparable steps for "
                        f"{row['requests']} requests")
    if row["max_rel_logit_err"] > REL_BUDGET:
        problems.append(f"max relative logit error "
                        f"{row['max_rel_logit_err']:.4f} > budget {REL_BUDGET}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="gate: non-zero exit when the quantized tier's "
                         "logit error drifts past the budget")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args(argv)
    (row,) = run(requests=args.requests)
    print(f"quant_serve,{row['requests']},{row['q_tokens_per_s']:.1f},"
          f"{row['quant_weight_frac']:.3f},{row['max_rel_logit_err']:.4f}")
    if args.check:
        problems = check(row)
        for p in problems:
            print(f"QUANT GATE: {p}", file=sys.stderr)
        if problems:
            return 1
        print(f"quant gate green: {row['compared_steps']} steps within "
              f"{REL_BUDGET} relative logit-error budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
