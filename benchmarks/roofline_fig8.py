"""Fig. 8: effective-TFLOPS roofline with LCMA selection overlay (v5e).

Sweeps arithmetic intensity (via square size), reporting predicted effective
TFLOPS for standard GEMM, Strassen <2,2,2>;7, <4,4,4>;49 and the Decision
Module's pick. Reproduces the paper's qualitative structure: below the ridge
GEMM wins; past it, higher-R schemes pull further above the hardware peak.
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg, decision as dec
from repro.core.hardware import TPU_V5E


def run(sizes=(1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072),
        dtype="bfloat16", verbose=True):
    hw = TPU_V5E
    s7 = alg.get("strassen")
    s49 = alg.get("s444")
    rows = []
    for n in sizes:
        ai = 2 * n**3 / (3 * n * n * dec._dtype_bytes(dtype))
        t_gemm = dec.gemm_time(n, n, n, hw, dtype)
        row = {
            "n": n, "ai": ai,
            "gemm": dec.effective_tflops(n, n, n, t_gemm),
            "strassen7": dec.effective_tflops(
                n, n, n, dec.lcma_time(s7, n, n, n, hw, dtype=dtype)),
            "s444_49": dec.effective_tflops(
                n, n, n, dec.lcma_time(s49, n, n, n, hw, dtype=dtype)),
        }
        d = dec.decide(n, n, n, hw, dtype)
        row["decision"] = d.algo.name if d.use_lcma else "gemm"
        row["decision_tflops"] = dec.effective_tflops(n, n, n, d.seconds)
        rows.append(row)
        if verbose:
            print(f"n={n:6d} AI={ai:7.0f}  gemm={row['gemm']:6.1f}  "
                  f"strassen={row['strassen7']:6.1f}  s444={row['s444_49']:6.1f}  "
                  f"-> {row['decision']} ({row['decision_tflops']:.1f} eff TF/s)")
    peak = hw.flops_for(dtype) / 1e12
    best = max(r["decision_tflops"] for r in rows)
    if verbose:
        print(f"\nv5e bf16 peak = {peak:.0f} TF/s; best effective = {best:.1f} "
              f"TF/s ({best/peak:.2%} of peak) — peak-breaking = {best > peak}")
    return rows


def main():
    for r in run():
        print(f"roofline_fig8,{r['n']},{r['ai']:.0f},{r['gemm']:.1f},"
              f"{r['strassen7']:.1f},{r['s444_49']:.1f},{r['decision']}")


if __name__ == "__main__":
    main()
