"""§IV-F: numerical precision — fused (f32 H on-chip) vs downcast-H baseline."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, codegen


def run(sizes=(64, 128, 256), trials=4, verbose=True):
    rows = []
    for name in ("strassen", "s444"):
        l = alg.get(name)
        fused = codegen.generate(l, codegen.CodegenOptions(fused=True))
        down = codegen.generate(l, codegen.CodegenOptions(
            fused=False, downcast_h=True, gemm_backend="loop"))
        for n in sizes:
            m = -(-n // l.m) * l.m
            ef, eds = [], []
            for t in range(trials):
                r = np.random.default_rng(t)
                A64 = r.standard_normal((m, m)) * 3
                B64 = r.standard_normal((m, m)) * 3
                ref = A64 @ B64
                A = jnp.asarray(A64, jnp.bfloat16)
                B = jnp.asarray(B64, jnp.bfloat16)
                nrm = np.linalg.norm(ref)
                ef.append(np.linalg.norm(np.asarray(fused.fn(A, B), np.float64) - ref) / nrm)
                eds.append(np.linalg.norm(np.asarray(down.fn(A, B), np.float64) - ref) / nrm)
            improve = 1 - np.mean(ef) / np.mean(eds)
            rows.append({"algo": name, "n": m, "fused_rel_err": float(np.mean(ef)),
                         "downcast_rel_err": float(np.mean(eds)),
                         "improvement": float(improve)})
            if verbose:
                print(f"{name} n={m}: fused={np.mean(ef):.4f} "
                      f"downcast={np.mean(eds):.4f} (-{improve:.1%} error)")
    return rows


def main():
    rows = run()
    mean_imp = np.mean([r["improvement"] for r in rows])
    print(f"\nmean error reduction of fused vs downcast-H: {mean_imp:.1%} "
          f"(paper reports ~17.2% vs AlphaTensor)")
    for r in rows:
        print(f"precision,{r['algo']},{r['n']},{r['fused_rel_err']:.5f},"
              f"{r['downcast_rel_err']:.5f}")


if __name__ == "__main__":
    main()
