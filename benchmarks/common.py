"""Shared benchmark utilities (timing on the real CPU device)."""
from __future__ import annotations

import time

import jax
import numpy as np

# Paper §IV-B LLM projection (K, N) pairs, derived from the workload
# registry's paper contraction sets — the same source the tune CLI's cache
# warming consumes, so benchmark and deploy-time shape grids cannot drift.
from repro.core.workloads import paper_projection_shapes, paper_workloads

LLM_SHAPES = {w: paper_projection_shapes(w) for w in paper_workloads()}


def time_fn(fn, *args, warmup: int = 2, reps: int = 5) -> float:
    """Best-of wall-time of a jitted function (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def effective_gflops(M: int, N: int, K: int, seconds: float) -> float:
    """Paper metric: 2MNK/time regardless of algorithm => LCMA can beat peak."""
    return 2.0 * M * N * K / seconds / 1e9
