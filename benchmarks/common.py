"""Shared benchmark utilities (timing on the real CPU device)."""
from __future__ import annotations

import time

import jax
import numpy as np

# Linear-layer (N, K) shapes extracted from the paper's three LLM workloads
# (§IV-B): DeepSeek-R1-, Qwen3.5- and HunyuanVideo-style projections.
LLM_SHAPES = {
    "deepseek_r1": [(7168, 18432), (18432, 7168), (7168, 2048), (2048, 7168),
                    (7168, 4096), (4096, 7168), (1536, 7168), (7168, 1536),
                    (7168, 9216), (9216, 7168), (7168, 7168)],
    "qwen3_5": [(5120, 25600), (25600, 5120), (5120, 5120), (5120, 640),
                (640, 5120), (5120, 13824), (13824, 5120)],
    "hunyuan_video": [(3072, 12288), (12288, 3072), (3072, 3072),
                      (3072, 9216), (9216, 3072), (3072, 6144)],
}


def time_fn(fn, *args, warmup: int = 2, reps: int = 5) -> float:
    """Best-of wall-time of a jitted function (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def effective_gflops(M: int, N: int, K: int, seconds: float) -> float:
    """Paper metric: 2MNK/time regardless of algorithm => LCMA can beat peak."""
    return 2.0 * M * N * K / seconds / 1e9
