"""Sharded Decision Module metrics: layout pricing at a simulated pod scale.

Deterministic (modeled on the static tpu_v5e profile, no accelerator or
multi-process runtime needed — CI-gateable on a CPU host): for each
benchmarked shape ``decide_sharded`` prices every layout at D=8 and reports

* ``scaling_eff`` — T(1 device) / (D * T(best layout)): per-device
  throughput scaling efficiency of the chosen layout (1.0 = linear),
* ``coll_frac`` — collective seconds / total seconds of the chosen plan,
* ``layout_flip`` — 1.0 when re-pricing the same shape over a slow 1 GB/s
  interconnect flips the winner to the replicated (communication-free)
  layout: the acceptance property that the collective term is load-bearing.

An optional measured lane (``--measured``, not gated) runs the mesh
ServeEngine on simulated host devices in a subprocess and reports real
tokens/s next to the model.
"""
from __future__ import annotations

import dataclasses

from repro.core import decision as dec
from repro.core.hardware import TPU_V5E

SLOW_LINK_BW = 1e9          # bytes/s: the "bad interconnect" re-pricing


def run(shapes=((4096, 4096, 4096), (8192, 8192, 8192), (8192, 8192, 32768)),
        n_devices=8, dtype="bfloat16", verbose=True):
    hw = TPU_V5E
    slow_hw = dataclasses.replace(hw, collective_bw=SLOW_LINK_BW)
    rows = []
    for (M, K, N) in shapes:
        d = dec.decide_sharded(M, N, K, hw, dtype, n_devices=n_devices)
        d_slow = dec.decide_sharded(M, N, K, slow_hw, dtype,
                                    n_devices=n_devices)
        single = dec.decide(M, N, K, hw, dtype)
        rows.append({
            "M": M, "K": K, "N": N, "D": n_devices,
            "layout": d.layout,
            "sharded_tflops": dec.effective_tflops(M, N, K, d.seconds),
            "scaling_eff": single.seconds / (n_devices * d.seconds),
            "coll_frac": d.collective_fraction,
            "layout_flip": float(d.layout != d_slow.layout
                                 and d_slow.layout == "replicated"),
            "slow_layout": d_slow.layout,
        })
        if verbose:
            r = rows[-1]
            print(f"{M}x{K}x{N} @ D={n_devices}: layout={r['layout']:10s} "
                  f"scaling_eff={r['scaling_eff']:.2f} "
                  f"coll_frac={r['coll_frac']:.2f} "
                  f"slow-link -> {r['slow_layout']} "
                  f"(flip={int(r['layout_flip'])})")
    return rows


def run_measured(n_devices=8, requests=16, verbose=True):
    """Real mesh ServeEngine throughput on simulated host devices (un-gated)."""
    import json
    import os
    import subprocess
    import sys
    body = (
        "import json, numpy as np\n"
        "from repro.configs import smoke_config\n"
        "from repro.serve import ServeEngine, StepLoop\n"
        "cfg = smoke_config('granite_3_2b')\n"
        "eng = ServeEngine(cfg, max_slots=4, max_prompt_len=16,\n"
        "                  max_new_tokens=4,\n"
        f"                 mesh_shape={{'data': 1, 'model': {n_devices}}})\n"
        "rng = np.random.default_rng(0)\n"
        f"for _ in range({requests}):\n"
        "    eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)\n"
        "StepLoop(eng).run_until_idle()\n"
        "print('@@', json.dumps(eng.summary()['tokens_per_s']))\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    out = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"measured mesh serve failed:\n{out.stderr}")
    tps = json.loads(out.stdout.split("@@")[1].strip().splitlines()[0])
    if verbose:
        print(f"measured mesh serve: {tps:.1f} tok/s over {n_devices} "
              f"simulated devices")
    return {"mesh_tokens_per_s": tps, "D": n_devices}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true",
                    help="also run the real mesh ServeEngine on simulated "
                         "host devices (slow; never gated)")
    args = ap.parse_args()
    for r in run():
        print(f"distributed,{r['M']},{r['K']},{r['N']},{r['D']},{r['layout']},"
              f"{r['scaling_eff']:.3f},{r['coll_frac']:.3f},"
              f"{int(r['layout_flip'])}")
    if args.measured:
        run_measured()


if __name__ == "__main__":
    main()
