"""Continuous-batching serve engine: tokens/s and bucket/plan reuse.

Serves a synthetic ragged workload (random prompt lengths + token budgets)
through :class:`repro.serve.ServeEngine` on the reduced granite model and
reports the ``ServeStats`` surface — real tokens/s, decode tokens/s, bucket
hit rate (should be 1.0 after warmup: every step shape was pre-planned and
pre-compiled), plan-cache behavior, and padding waste (the price of the
power-of-two bucket grid).
"""
from __future__ import annotations

import numpy as np

from repro.configs import registry
from repro.core import plan_cache
from repro.serve import ServeEngine, StepLoop


def run(requests=16, max_slots=4, max_prompt_len=32, max_new_tokens=8,
        seed=0, verbose=True) -> list[dict]:
    plan_cache.reset()
    cfg = registry.smoke_config("granite_3_2b")
    engine = ServeEngine(cfg, max_slots=max_slots,
                         max_prompt_len=max_prompt_len,
                         max_new_tokens=max_new_tokens, seed=seed)
    warm = engine.warm()
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        plen = int(rng.integers(3, max_prompt_len + 1))
        engine.submit(rng.integers(0, cfg.vocab_size, plen),
                      max_new_tokens=int(rng.integers(1, max_new_tokens + 1)))
    done = StepLoop(engine).run_until_idle()
    s = engine.summary()
    row = {
        "requests": requests, "finished": len(done),
        "warm_plans": warm["plans"], "warm_shapes": warm["shapes"],
        "warm_s": warm["seconds"],
        "prefill_steps": s["prefill_steps"], "decode_steps": s["decode_steps"],
        "tokens_per_s": s["tokens_per_s"],
        "decode_tokens_per_s": s["decode_tokens_per_s"],
        "bucket_hit_rate": s["bucket_hit_rate"],
        "padding_waste": s["padding_waste"],
        "plan_cache_hit_rate": s["plan_cache"]["hit_rate"],
        "plan_cache_entries": s["plan_cache"]["entries"],
    }
    if verbose:
        print(f"{requests} ragged requests over {max_slots} slots: "
              f"{s['prefill_steps']} prefill + {s['decode_steps']} decode steps")
        print(f"throughput: {row['tokens_per_s']:.1f} tok/s real "
              f"({row['decode_tokens_per_s']:.1f} decode tok/s)")
        print(f"bucket hit rate {row['bucket_hit_rate']:.1%} | padding waste "
              f"{row['padding_waste']:.1%} | plan cache "
              f"{row['plan_cache_hit_rate']:.0%} hits "
              f"({row['plan_cache_entries']} plans)")
    assert len(done) == requests, (len(done), requests)
    return [row]


def main():
    for r in run():
        print(f"serve,{r['requests']},{r['tokens_per_s']:.1f},"
              f"{r['bucket_hit_rate']:.3f},{r['padding_waste']:.3f}")


if __name__ == "__main__":
    main()
