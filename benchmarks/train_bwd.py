"""Backward-pass benchmark: planned custom-VJP training vs differentiate-through.

Measures one ``jax.value_and_grad`` of a falcon-dispatched loss under the two
autodiff regimes the engine supports:

  * ``planned_vjp=True``  — the custom VJP computes ``dA = g Bᵀ`` and
    ``dB = Aᵀ g`` as independently planned falcon contractions,
  * ``planned_vjp=False`` — autodiff transposes the combine/R-GEMM/combine
    graph (the pre-tentpole behavior).

Also reports the structural acceptance signal: after tracing the planned
step in auto mode, the plan cache must contain entries for both backward
shapes of every contraction (``bwd_planned_frac == 1.0`` — gated in CI).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, plan_cache
from repro.core.decision import backward_shapes
from repro.core.falcon_gemm import FalconConfig
from repro.core.hardware import HardwareProfile, register_profile

from .common import effective_gflops, time_fn

# Deterministic profile for the structural bwd_planned_frac gate: enormous
# bandwidth makes every benchmark shape compute-bound, so the auto-mode
# forward always picks an LCMA and engages the custom-VJP core regardless of
# the CI host's measured characteristics.
LCMA_ALWAYS = HardwareProfile(name="train_bwd_lcma_always",
                              flops_mul=1e12, flops_add=1e12, beta=1e15)


def _grad_step(cfg: FalconConfig):
    def loss(a, b):
        return jnp.sum(engine.matmul(a, b, cfg=cfg) ** 2)

    return jax.jit(jax.value_and_grad(loss, (0, 1)))


def run(sizes=(512, 1024), verbose=True):
    prof = register_profile(LCMA_ALWAYS)
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        # Rectangular on purpose: for a square problem the two backward
        # shapes coincide with the forward shape and the structural check
        # below would be vacuously true. (n, n/2) @ (n/2, 2n) gives three
        # distinct plan-cache keys for fwd / dA / dB.
        M, K, N = n, n // 2, 2 * n
        A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

        base = FalconConfig(mode="strassen", backend="jnp")
        t_planned = time_fn(_grad_step(base), A, B)
        t_through = time_fn(_grad_step(
            dataclasses.replace(base, planned_vjp=False)), A, B)
        t_eager = time_fn(jax.jit(jax.value_and_grad(
            lambda a, b: jnp.sum((a @ b) ** 2), (0, 1))), A, B)

        # Structural check: auto-mode trace must pre-plan both bwd shapes.
        plan_cache.reset()
        auto = FalconConfig(mode="auto", hardware=prof.name, backend="jnp")
        jax.jit(jax.value_and_grad(
            lambda a, b: jnp.sum(engine.matmul(a, b, cfg=auto) ** 2),
            (0, 1)))(A, B)
        cache = plan_cache.default_cache()
        want = {(M, K, N)} | set(backward_shapes(M, K, N))
        assert len(want) == 3, want     # rectangular => three distinct keys
        frac = sum(cache.has_shape(*s) for s in want) / len(want)
        plan_cache.reset()

        # grad FLOPs: fwd (2MNK) + two bwd GEMMs of the same volume
        gflops = lambda t: effective_gflops(M, N, K, t) * 3
        rows.append({
            "n": n,
            "planned_bwd_gflops": gflops(t_planned),
            "through_bwd_gflops": gflops(t_through),
            "eager_bwd_gflops": gflops(t_eager),
            "planned_over_through": t_through / t_planned,
            "bwd_planned_frac": frac,
        })
        if verbose:
            r = rows[-1]
            print(f"train_bwd,n={n}: planned={r['planned_bwd_gflops']:.1f} "
                  f"through={r['through_bwd_gflops']:.1f} "
                  f"eager={r['eager_bwd_gflops']:.1f} GF/s | "
                  f"planned/through={r['planned_over_through']:.2f}x | "
                  f"bwd shapes planned: {r['bwd_planned_frac']:.0%}")
    return rows


def main():
    for r in run():
        print(f"train_bwd,{r['n']},{r['planned_bwd_gflops']:.1f},"
              f"{r['through_bwd_gflops']:.1f},{r['planned_over_through']:.3f},"
              f"{r['bwd_planned_frac']:.2f}")


if __name__ == "__main__":
    main()
