"""Grouped batched LCMA execution: grouped vs vmap vs eager (MoE-shaped).

Measures the tentpole lowering on MoE-expert-shaped groups ``E x (C, d) @
(d, ff)``:

  * **eager**   — plain batched ``jnp.matmul`` (the no-falcon baseline),
  * **vmap**    — the pre-grouped lowering: ``jax.vmap`` over the
    independently-combined 2-D LCMA core (per-element Combine A/B/H),
  * **grouped** — ``falcon.grouped_matmul``: one batched Combine A, one
    grouped GEMM over the E*R intermediate products, per-group Combine H,
  * **grouped-hoisted** — the shared-B form (one (d, ff) weight broadcast
    across the group): Combine B runs ONCE for the whole group.

Reported per shape: effective GF/s for each lowering plus the *combine-hoist
fraction* — the share of the grouped pipeline's combine traffic that sharing
the B operand eliminates, from the Decision-Module stage model (measured
wall-clock on CPU covers the execution ratios; the hoist fraction is a model
quantity so it stays host-independent for the CI gate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro.core import algorithms as alg, decision as dec
from repro.core.hardware import TPU_V5E
from .common import time_fn


def _grouped_gflops(E, C, K, N, seconds):
    return 2.0 * E * C * K * N / seconds / 1e9


def combine_hoist_fraction(l, E, C, N, K, dtype="float32") -> float:
    """Fraction of grouped combine bytes eliminated by hoisting Combine B.

    From ``decision.estimate_grouped``: combine traffic with per-group B
    minus traffic with the shared (hoisted) B, over the per-group combine
    traffic. Pure model arithmetic — deterministic across hosts.
    """
    def combine_bytes(shared):
        e = dec.estimate_grouped(l, E, C, N, K, TPU_V5E, dtype,
                                 shared_b=shared)
        return sum(s.bytes for s in e.stages if s.name.startswith("combine"))

    per_group = combine_bytes(False)
    hoisted = combine_bytes(True)
    return (per_group - hoisted) / per_group


def run(shapes=((8, 128, 256, 512), (8, 256, 512, 512)), scheme="strassen",
        verbose=True):
    """shapes: (E, C, K, N) grouped problems — E experts, C-row token blocks."""
    l = alg.get(scheme)
    rng = np.random.default_rng(0)
    rows = []
    cfg = falcon.FalconConfig(mode=scheme, backend="jnp")
    for (E, C, K, N) in shapes:
        a3 = jnp.asarray(rng.standard_normal((E, C, K)), jnp.float32)
        b3 = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
        b2 = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

        eager = jax.jit(lambda a, b: jnp.matmul(a, b))
        vmapped = jax.jit(jax.vmap(
            lambda a, b: falcon.matmul(a, b, cfg=cfg)))
        grouped = jax.jit(lambda a, b: falcon.grouped_matmul(a, b, cfg=cfg))

        t_eager = time_fn(eager, a3, b3)
        t_vmap = time_fn(vmapped, a3, b3)
        t_grouped = time_fn(grouped, a3, b3)
        t_hoisted = time_fn(grouped, a3, b2)

        np.testing.assert_allclose(
            np.asarray(grouped(a3, b3)), np.asarray(vmapped(a3, b3)),
            rtol=2e-4, atol=2e-4)

        rows.append({
            "E": E, "C": C, "K": K, "N": N,
            "eager_gflops": _grouped_gflops(E, C, K, N, t_eager),
            "vmap_gflops": _grouped_gflops(E, C, K, N, t_vmap),
            "grouped_gflops": _grouped_gflops(E, C, K, N, t_grouped),
            "hoisted_gflops": _grouped_gflops(E, C, K, N, t_hoisted),
            "grouped_over_vmap": t_vmap / t_grouped,
            "combine_hoist_frac": combine_hoist_fraction(l, E, C, N, K),
        })
        if verbose:
            r = rows[-1]
            print(f"E={E} C={C} K={K} N={N}: eager={r['eager_gflops']:.1f} "
                  f"vmap={r['vmap_gflops']:.1f} "
                  f"grouped={r['grouped_gflops']:.1f} "
                  f"hoisted={r['hoisted_gflops']:.1f} GF/s | "
                  f"grouped/vmap={r['grouped_over_vmap']:.2f}x "
                  f"hoist_frac={r['combine_hoist_frac']:.3f}")
    return rows


def main():
    for r in run():
        print(f"moe_grouped,{r['E']},{r['C']},{r['K']},{r['N']},"
              f"{r['eager_gflops']:.1f},{r['vmap_gflops']:.1f},"
              f"{r['grouped_gflops']:.1f},{r['grouped_over_vmap']:.3f},"
              f"{r['combine_hoist_frac']:.3f}")


if __name__ == "__main__":
    main()
