"""Checkpointing: atomicity, integrity, retention, bf16, async, restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.ones((3,), jnp.bfloat16),
                   "c": jnp.arange(5, dtype=jnp.int32)},
    }


def test_roundtrip_with_bf16(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    got, step, extra = restore_checkpoint(str(tmp_path), t)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_0000000003", "step_0000000004"]


def test_integrity_detects_corruption(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    path = tmp_path / "step_0000000001" / "arrays.npz"
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))
    with pytest.raises(IOError, match="integrity"):
        restore_checkpoint(str(tmp_path), _tree())


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    other = {"a": jnp.zeros((8, 4)), "nested": {"b": jnp.zeros((3,), jnp.bfloat16),
                                                "WRONG": jnp.zeros((5,), jnp.int32)}}
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), other)


def test_crash_mid_save_never_corrupts_latest(tmp_path):
    """A stale .tmp dir (simulated crash) is invisible to latest_step."""
    save_checkpoint(str(tmp_path), 5, _tree())
    os.makedirs(tmp_path / "step_0000000009.tmp")
    (tmp_path / "step_0000000009.tmp" / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 5
    got, step, _ = restore_checkpoint(str(tmp_path), _tree())
    assert step == 5


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_elastic_reshard_on_restore(tmp_path):
    """Save on one topology, restore with different shardings (subprocess)."""
    from conftest import run_multidevice
    out = run_multidevice(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh1 = compat.make_mesh((8,), ("data",))
        t1 = jax.device_put(t, {{"w": NamedSharding(mesh1, P("data", None))}})
        save_checkpoint(r"{tmp_path}", 3, t1)
        # "new cluster": 4x2 mesh, different layout
        mesh2 = compat.make_mesh((4, 2), ("data", "model"))
        sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
        got, step, _ = restore_checkpoint(r"{tmp_path}", t, shardings=sh2)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
        assert got["w"].sharding.spec == P("model", "data")
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
