"""Roofline: HLO collective parser + analytic cost model sanity."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import SHAPE_CELLS
from repro.core.hardware import TPU_V5E
from repro.roofline.analysis import collective_bytes
from repro.roofline.analytic import analytic_costs


HLO = """
HloModule test
%fused (x: bf16[1024,512]) -> bf16[1024,512] {
  %ag = bf16[2048,512]{1,0} all-gather(bf16[1024,512]{1,0} %x), replica_groups={}
  %ar.1 = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %y), to_apply=%sum
  %rs = f32[64,256]{1,0} reduce-scatter(f32[128,256]{1,0} %z), dimensions={0}
  %cp = bf16[32]{0} collective-permute(bf16[32]{0} %w), source_target_pairs={{0,1}}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(f32[16,16] %p, f32[16,16] %q)
  %donttouch = f32[999,999]{1,0} add(f32[999,999] %a, f32[999,999] %b)
}
"""


def test_collective_parser():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 2048 * 512 * 2 * 1.0
    assert got["all-reduce"] == 128 * 256 * 4 * 2.0          # 2x ring traffic
    assert got["reduce-scatter"] == 64 * 256 * 4
    assert got["collective-permute"] == 32 * 2
    assert got["all-to-all"] == 2 * 16 * 16 * 4
    counts = got["_counts"]
    assert counts["all-reduce"] == 1 and counts["all-gather"] == 1


def test_parser_skips_async_done_pairs():
    txt = """
  %s = bf16[64,64]{1,0} all-gather-start(bf16[32,64] %x)
  %d = bf16[64,64]{1,0} all-gather-done(bf16[64,64] %s)
"""
    got = collective_bytes(txt)
    assert got["all-gather"] == 64 * 64 * 2  # start counted once, done skipped


def _costs(arch, shape, mesh=None):
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    mesh = mesh or {"data": 16, "model": 16}
    # rough param counts; exact counts come from specs in the dry-run
    n = {"granite_3_2b": 2.5e9, "gemma3_27b": 27e9, "kimi_k2_1t": 1.04e12,
         "mamba2_370m": 4e8}[arch]
    return analytic_costs(cfg, cell, mesh, int(n), int(n))


def test_train_flops_close_to_6nd():
    c = _costs("granite_3_2b", "train_4k")
    model = 6 * 2.5e9 * 256 * 4096 / 256  # per device
    # remat adds 1/3, attention adds ~10-20%
    assert model < c.flops < 2.2 * model


def test_decode_flops_tiny_vs_prefill():
    dec = _costs("granite_3_2b", "decode_32k")
    pre = _costs("granite_3_2b", "prefill_32k")
    assert dec.flops < pre.flops / 100


def test_multi_pod_scales_flops_down():
    c1 = _costs("gemma3_27b", "train_4k", {"data": 16, "model": 16})
    c2 = _costs("gemma3_27b", "train_4k", {"pod": 2, "data": 16, "model": 16})
    np.testing.assert_allclose(c1.flops / 2, c2.flops, rtol=0.01)


def test_terms_positive_and_finite():
    for arch in ("granite_3_2b", "kimi_k2_1t", "mamba2_370m"):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            c = _costs(arch, shape)
            t = c.terms(TPU_V5E)
            assert all(np.isfinite(x) and x >= 0 for x in t), (arch, shape, t)
            assert c.flops > 0 and c.hbm_bytes > 0
