"""Continuous-batching serve engine: buckets, scheduler, engine vs eager,
plan-cache warm/bucket reuse, and concurrent plan-cache writers."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.configs import registry
from repro.core import engine as core_engine, plan_cache
from repro.core.falcon_gemm import FalconConfig, plan
from repro.models import model as M
from repro.serve import (BucketPolicy, Request, RequestQueue, Scheduler,
                         ServeEngine, StepLoop, next_pow2)
from repro.serve.scheduler import DecodeWork, PrefillWork
from repro.train.steps import (make_decode_step, make_prefill_step,
                               make_serve_prefill_step)

CFG = registry.smoke_config("granite_3_2b")

# a small closed set of prompt lengths keeps the eager-reference jit count
# bounded while still exercising both sequence buckets and ragged decode
PROMPT_LENS = (3, 8, 11, 16)


# ---------------------------------------------------------------------------
# Buckets
# ---------------------------------------------------------------------------

def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 5, 8, 9, 31)] == \
        [1, 2, 4, 8, 8, 16, 32]
    with pytest.raises(ValueError):
        next_pow2(0)


def test_bucket_policy_grid():
    p = BucketPolicy.build(max_prompt_len=24, max_slots=4, min_seq=8)
    assert p.prefill_seq == (8, 16, 32)
    assert p.prefill_batch == (1, 2, 4)
    assert p.decode_batch == (1, 2, 4)
    assert p.seq_bucket(3) == 8 and p.seq_bucket(17) == 32
    assert p.decode_batch_bucket(3) == 4
    with pytest.raises(ValueError):
        p.seq_bucket(33)
    ms = p.bucket_ms()
    assert ms == sorted(set(ms))
    assert set(p.decode_batch) <= set(ms)
    assert 4 * 32 in ms           # largest prefill M


# ---------------------------------------------------------------------------
# Request queue
# ---------------------------------------------------------------------------

def test_request_queue_fifo_and_threaded_submit():
    q = RequestQueue()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=2) for _ in range(16)]
    threads = [threading.Thread(target=q.submit, args=(r,)) for r in reqs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(q) == 16
    head = q.peek(4)
    assert len(head) == 4
    q.pop(head[:2])
    assert len(q) == 14
    assert q.peek(1)[0] is head[2]    # FIFO order preserved after pop


def test_request_validation():
    with pytest.raises(ValueError):
        Request(prompt=[])
    with pytest.raises(ValueError):
        Request(prompt=[1], max_new_tokens=0)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def _sched(max_slots=4):
    q = RequestQueue()
    policy = BucketPolicy.build(max_prompt_len=16, max_slots=max_slots, min_seq=8)
    # cap disabled: these tests pin down bucket grouping / slot accounting;
    # the decode-fairness cap has its own coverage in test_serve_spec.py
    return q, Scheduler(q, policy, max_slots=max_slots,
                        max_consecutive_prefills=0)


def test_scheduler_prefill_groups_by_seq_bucket():
    q, s = _sched()
    for plen in (5, 7, 16, 6):        # buckets: 8, 8, 16, 8
        q.submit(Request(prompt=list(range(1, plen + 1)), max_new_tokens=2))
    work = s.next_work()
    assert isinstance(work, PrefillWork)
    # FIFO head group: the two 8-bucket prompts before the 16-bucket one
    assert [r.prompt_len for r in work.requests] == [5, 7]
    assert work.seq_pad == 8 and work.batch_pad == 2
    assert work.padded_tokens == 16 and work.real_tokens == 12
    # next: still free slots + waiting work, so prefill again; the 16-bucket
    # head runs alone (the 8-bucket prompt behind it starts its own group)
    work2 = s.next_work()
    assert isinstance(work2, PrefillWork)
    assert [r.prompt_len for r in work2.requests] == [16]
    assert work2.seq_pad == 16 and work2.batch_pad == 1
    work3 = s.next_work()
    assert isinstance(work3, PrefillWork)
    assert [r.prompt_len for r in work3.requests] == [6]
    assert s.n_free == 0
    work4 = s.next_work()
    assert isinstance(work4, DecodeWork)
    assert work4.batch_pad == 4 and len(work4.slots) == 4
    # releasing a slot lets admission resume
    done = work.requests[0]
    s.release(done)
    assert s.n_free == 1


def test_scheduler_slot_exhaustion_forces_decode():
    q, s = _sched(max_slots=1)
    q.submit(Request(prompt=[1, 2], max_new_tokens=2))
    q.submit(Request(prompt=[3, 4], max_new_tokens=2))
    w1 = s.next_work()
    assert isinstance(w1, PrefillWork) and len(w1.requests) == 1
    w2 = s.next_work()
    assert isinstance(w2, DecodeWork)      # no free slot: decode runs
    assert len(q) == 1


# ---------------------------------------------------------------------------
# Serve prefill step: per-row last index on right-padded prompts
# ---------------------------------------------------------------------------

def test_serve_prefill_matches_unpadded_prefill(rng):
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    prompt = rng.integers(0, CFG.vocab_size, 11)
    ref_fn = jax.jit(make_prefill_step(CFG, max_len=32))
    ref_logits, _ = ref_fn(params, jnp.asarray(prompt[None], jnp.int32))
    toks = np.zeros((2, 16), np.int32)
    toks[0, :11] = prompt
    toks[1, :5] = prompt[:5]
    fn = jax.jit(make_serve_prefill_step(CFG, max_len=32))
    logits, cache = fn(params, jnp.asarray(toks), jnp.asarray([10, 4], jnp.int32))
    assert logits.shape[:2] == (2, 1)
    assert cache["k"].shape[2] == 32     # cache sized to engine max_len
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(ref_logits[0, -1]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Engine vs unbatched eager decode (the tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    """One warmed engine serving 8 ragged requests, with recorded logits."""
    plan_cache.reset()
    engine = ServeEngine(CFG, max_slots=4, max_prompt_len=16,
                         max_new_tokens=4, record_logits=True, seed=0)
    warm = engine.warm()
    misses_after_warm = plan_cache.stats().misses
    rng = np.random.default_rng(7)
    for i in range(8):
        plen = int(PROMPT_LENS[i % len(PROMPT_LENS)])
        engine.submit(rng.integers(0, CFG.vocab_size, plen),
                      max_new_tokens=int(rng.integers(1, 5)))
    done = StepLoop(engine).run_until_idle()
    misses_after_serve = plan_cache.stats().misses
    return engine, warm, (misses_after_warm, misses_after_serve), done


def test_engine_completes_all_requests(served):
    engine, _, _, done = served
    assert len(done) == 8
    assert all(r.done for r in engine.requests)
    assert all(1 <= len(r.generated) <= 4 for r in done)
    assert engine.scheduler.idle
    s = engine.summary()
    assert s["requests_finished"] == 8
    assert s["generated_tokens"] == sum(len(r.generated) for r in done)


def test_engine_output_allclose_vs_eager_decode(served):
    engine, _, _, done = served
    params = M.init_params(CFG, jax.random.PRNGKey(0))   # same seed as engine
    decode = jax.jit(make_decode_step(CFG))
    prefills = {}                                        # one jit per length
    for r in done:
        plen = r.prompt_len
        if plen not in prefills:
            prefills[plen] = jax.jit(make_prefill_step(CFG, max_len=32))
        logits, cache = prefills[plen](
            params, jnp.asarray(np.asarray(r.prompt)[None], jnp.int32))
        toks, ref_logits = [], []
        for i in range(len(r.generated)):
            row = np.asarray(logits[0, -1])
            ref_logits.append(row)
            nxt = int(np.argmax(row))
            toks.append(nxt)
            if len(toks) == len(r.generated):
                break
            logits, cache = decode(params, cache,
                                   jnp.asarray([[nxt]], jnp.int32), plen + i)
        assert toks == r.generated, (r.rid, toks, r.generated)
        for got, ref in zip(r.logits, ref_logits):
            np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


def test_engine_bucket_hit_rate_after_warm(served):
    engine, warm, (misses_after_warm, misses_after_serve), _ = served
    s = engine.summary()
    assert warm["shapes"] == len(engine.policy.prefill_shapes()) \
        + len(engine.policy.decode_batch)
    # every step ran a pre-compiled bucket shape
    assert s["bucket_hit_rate"] >= 0.9, s
    assert s["bucket_misses"] == 0
    # ... and a pre-planned one: serving added no Decision-Module misses
    assert misses_after_serve == misses_after_warm
    assert s["padding_waste"] < 0.9


def test_engine_rejects_non_token_frontends():
    """Non-token frontends stay rejected; decoder families (incl. SSM) serve."""
    with pytest.raises(NotImplementedError):
        ServeEngine(registry.smoke_config("pixtral_12b"))
    with pytest.raises(NotImplementedError):
        ServeEngine(registry.smoke_config("musicgen_large"))
    ServeEngine(registry.smoke_config("mamba2_370m"),
                max_slots=2, max_prompt_len=8, max_new_tokens=2)


def test_engine_submit_validation():
    engine = ServeEngine(CFG, max_slots=2, max_prompt_len=8, max_new_tokens=2)
    with pytest.raises(ValueError):
        engine.submit(list(range(9)))            # prompt off the bucket grid
    with pytest.raises(ValueError):
        engine.submit([1, 2], max_new_tokens=3)  # exceeds engine cap


# ---------------------------------------------------------------------------
# warm_buckets: pre-planning makes serving a pure plan-cache hit
# ---------------------------------------------------------------------------

def test_warm_buckets_preplans_grid():
    plan_cache.reset()
    cfg = FalconConfig(hardware="tpu_v5e")
    buckets = [1, 2, 4, 64, 128]
    n = core_engine.warm_buckets(cfg, CFG, buckets, dtype="float32")
    shapes = falcon.dense_projection_shapes(CFG)
    assert n == 2 * len(buckets) * len(shapes)
    st0 = plan_cache.stats()
    assert st0.misses == n and st0.inserts == n
    # replan the whole grid (both profitability variants): zero new misses
    for mb in buckets:
        for (K, N) in shapes:
            plan(mb, K, N, cfg, "float32")
            plan(mb, K, N, cfg, "float32", precombined_b=True)
    st = plan_cache.stats()
    assert st.misses == n
    assert st.hits == n


def test_projection_shapes_cover_model_dims():
    shapes = falcon.dense_projection_shapes(CFG)
    d = CFG.d_model
    H, hd = CFG.num_heads, CFG.resolved_head_dim
    assert (d, H * hd) in shapes and (H * hd, d) in shapes
    assert (d, CFG.d_ff) in shapes and (CFG.d_ff, d) in shapes
    assert (d, -(-CFG.vocab_size // 256) * 256) in shapes
    assert len(shapes) == len(set(shapes))


# ---------------------------------------------------------------------------
# Registry-driven warm: 100% plan-key coverage of real serve runs
# ---------------------------------------------------------------------------

def _serve_32_requests(arch, seed=0):
    """Warm an engine, then serve 32 ragged requests; return key sets."""
    cfg = registry.smoke_config(arch)
    plan_cache.reset()
    engine = ServeEngine(cfg, max_slots=4, max_prompt_len=16,
                         max_new_tokens=4, seed=seed)
    engine.warm()
    cache = plan_cache.default_cache()
    keys_warm = set(cache.keys())
    misses_warm = plan_cache.stats().misses
    rng = np.random.default_rng(seed)
    for plen in rng.integers(2, 16, size=32):
        engine.submit(list(rng.integers(0, cfg.vocab_size, size=int(plen))),
                      max_new_tokens=4)
    done = engine.run()
    assert len(done) == 32
    return keys_warm, set(cache.keys()), misses_warm, plan_cache.stats().misses


@pytest.mark.parametrize("arch", ["dbrx_132b", "mamba2_370m"])
def test_warm_covers_all_serve_plan_keys(arch):
    """ServeEngine.warm (via the workload registry) pre-plans EVERY key a
    32-request serve run touches — MoE expert FFNs and SSD scan/decode
    contractions included, not just dense projections."""
    try:
        keys_warm, keys_serve, misses_warm, misses_serve = \
            _serve_32_requests(arch)
        assert keys_serve == keys_warm, (
            f"{arch}: serving created plan keys warm missed: "
            f"{sorted(keys_serve - keys_warm)}")
        assert misses_serve == misses_warm
        if arch == "mamba2_370m":
            # SSD contractions are Decision-routed: the warm set must hold
            # grouped (gGxMxKxN) keys from the scan/decode registry entries
            assert any("|g" in k for k in keys_warm)
    finally:
        plan_cache.reset()


def test_mamba2_engine_output_allclose_vs_eager_decode():
    """SSM serving is exact: right-padded bucketed prefill (dt zeroed on pad
    via the length mask) + per-slot decode == per-request eager decode, at
    off-bucket prompt lengths."""
    cfg = registry.smoke_config("mamba2_370m")
    plan_cache.reset()
    try:
        engine = ServeEngine(cfg, max_slots=4, max_prompt_len=16,
                             max_new_tokens=4, seed=0)
        engine.warm()
        rng = np.random.default_rng(7)
        prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
                   for n in (3, 11, 16, 5)]
        reqs = [engine.submit(p, max_new_tokens=4) for p in prompts]
        engine.run()
        for req in reqs:
            toks = jnp.asarray([req.prompt], jnp.int32)
            cache = M.init_cache(cfg, 1, engine.max_len)
            with falcon.use(engine.fcfg):
                hidden, cache, _ = M.forward(engine.params, cfg, toks,
                                             cache=cache, cache_index=0,
                                             logits_mode="none")
                logits = M.compute_logits(engine.params, cfg, hidden[:, -1:])
                gen = [int(jnp.argmax(logits[0, -1]))]
                pos = len(req.prompt)
                for _ in range(3):
                    logits, cache, _ = M.forward(
                        engine.params, cfg,
                        jnp.asarray([[gen[-1]]], jnp.int32), cache=cache,
                        cache_index=pos, logits_mode="last")
                    gen.append(int(jnp.argmax(logits[0, -1])))
                    pos += 1
            assert gen == req.generated, (len(req.prompt), gen, req.generated)
    finally:
        plan_cache.reset()


# ---------------------------------------------------------------------------
# Plan cache: concurrent-writer safety
# ---------------------------------------------------------------------------

def _mk_decision(m):
    cfg = FalconConfig(hardware="tpu_v5e", use_plan_cache=False)
    return plan(m, 512, 512, cfg, "float32")


def test_plan_cache_concurrent_writers(tmp_path):
    """Writers with independent caches on one path must union, not clobber."""
    path = str(tmp_path / "plans.json")
    n_writers, per_writer = 8, 4
    errors = []

    def writer(i):
        try:
            c = plan_cache.PlanCache(path=path, autoload=False)
            for j in range(per_writer):
                c.insert(f"w{i}_e{j}", _mk_decision(64 + i))
            c.save()
        except Exception as e:          # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    doc = json.load(open(path))
    keys = {k for k, _ in doc["entries"]}
    assert keys == {f"w{i}_e{j}" for i in range(n_writers)
                    for j in range(per_writer)}
    # no temp/lock debris beyond the sidecar lock file
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
    assert leftovers == []
    # a fresh cache loads the union
    c = plan_cache.PlanCache(path=path)
    assert len(c) == n_writers * per_writer


def test_plan_cache_save_merges_disk_entries(tmp_path):
    path = str(tmp_path / "plans.json")
    a = plan_cache.PlanCache(path=path, autoload=False)
    a.insert("only_a", _mk_decision(32))
    a.save()
    b = plan_cache.PlanCache(path=path, autoload=False)
    b.insert("only_b", _mk_decision(48))
    b.save()                                  # must keep a's entry
    doc = json.load(open(path))
    assert {k for k, _ in doc["entries"]} == {"only_a", "only_b"}
    c = plan_cache.PlanCache(path=path, autoload=False)
    c.insert("only_c", _mk_decision(96))
    c.save(merge=False)                       # explicit overwrite still works
    doc = json.load(open(path))
    assert {k for k, _ in doc["entries"]} == {"only_c"}


def test_plan_cache_threaded_shared_instance():
    """The in-process default cache takes concurrent replans (the scheduler
    replans from multiple threads sharing one cache)."""
    plan_cache.reset()
    cfg = FalconConfig(hardware="tpu_v5e")

    def worker():
        for m in (64, 128, 256):
            plan(m, 1024, 1024, cfg, "bfloat16")

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = plan_cache.stats()
    assert st.lookups == 8 * 3
    assert len(plan_cache.default_cache()) == 3   # one entry per shape


# ---------------------------------------------------------------------------
# Nightly soak (larger shapes; gated off the PR lane)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not os.environ.get("FALCON_SOAK"),
                    reason="nightly soak only (FALCON_SOAK=1)")
def test_soak_larger_shapes():
    plan_cache.reset()
    engine = ServeEngine(CFG, max_slots=8, max_prompt_len=64,
                         max_new_tokens=12, seed=0)
    engine.warm()
    rng = np.random.default_rng(0)
    for _ in range(48):
        plen = int(rng.integers(4, 65))
        engine.submit(rng.integers(0, CFG.vocab_size, plen),
                      max_new_tokens=int(rng.integers(1, 13)))
    done = StepLoop(engine).run_until_idle()
    s = engine.summary()
    assert len(done) == 48
    assert s["bucket_hit_rate"] >= 0.9, s
    assert s["generated_tokens"] == sum(len(r.generated) for r in done)


# keep the falcon import meaningful: the engine runs under the ambient config
def test_engine_uses_ambient_falcon_config():
    engine = ServeEngine(CFG, max_slots=2, max_prompt_len=8, max_new_tokens=2,
                         precombine=False)
    assert engine.fcfg.enabled == CFG.use_falcon
    with falcon.use(engine.fcfg):
        assert falcon.active_config() is engine.fcfg
