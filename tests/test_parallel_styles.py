"""fsdp_only remap + parallel_block correctness (multi-device subprocess)."""
import numpy as np

from conftest import run_multidevice


def test_fsdp_only_matches_tp_numerics():
    """Same params, same batch: tp and fsdp_only styles must agree."""
    out = run_multidevice("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import registry
        from repro.models import model as M
        from repro.parallel import sharding as SH
        cfg = dataclasses.replace(registry.smoke_config("granite_3_2b"), remat=False)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        losses = {}
        for style in ("tp", "fsdp_only"):
            c2 = dataclasses.replace(cfg, parallel_style=style)
            tok = SH.set_parallel_style(style)
            with compat.set_mesh(mesh):
                rules = SH.make_rules(mesh, fsdp=True, style=style)
                psh = SH.param_sharding(params, mesh, rules)
                p2 = jax.device_put(params, psh)
                loss, _ = jax.jit(lambda p, b: M.lm_loss(p, c2, b))(p2, batch)
                losses[style] = float(loss)
        assert abs(losses["tp"] - losses["fsdp_only"]) < 1e-4, losses
        print("STYLES_OK", losses)
    """)
    assert "STYLES_OK" in out


def test_parallel_block_changes_math_but_trains():
    """parallel_block is a different (PaLM-style) architecture: outputs differ
    from the sequential block but remain finite and trainable."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import model as M
    cfg = registry.smoke_config("granite_3_2b")
    cfg_pb = dataclasses.replace(cfg, parallel_block=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = M.lm_loss(params, cfg, batch)
    l1, _ = M.lm_loss(params, cfg_pb, batch)
    assert np.isfinite(float(l1))
    assert abs(float(l0) - float(l1)) > 1e-6  # genuinely different arch
    g = jax.grad(lambda p: M.lm_loss(p, cfg_pb, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
