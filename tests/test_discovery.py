"""Discovery module: the homotopy-ALS search finds real ternary schemes."""

from repro.core import algorithms as alg
from repro.core.discovery import discover
from repro.core.lcma import validate


def test_discover_strassen_rank7():
    """<2,2,2>;7 is rediscovered from random inits within a few restarts."""
    l = discover(2, 2, 2, 7, restarts=30, als_iters=80, seed=2)
    assert l is not None, "failed to discover a rank-7 <2,2,2> scheme"
    assert validate(l)
    assert l.R == 7 and l.grid == (2, 2, 2)


def test_discover_repairs_corrupted_scheme():
    """Seeding with a corrupted Strassen converges back to a valid scheme —
    the exact procedure that recovered our Laderman-family coefficients."""
    s = alg.strassen()
    U = s.U.copy()
    U[0, 0, 1] = 1  # corrupt two entries
    U[3, 1, 0] = -1
    from repro.core.lcma import LCMA
    bad = LCMA("corrupt", 2, 2, 2, 7, U, s.V, s.W)
    assert not validate(bad)
    fixed = discover(2, 2, 2, 7, restarts=3, als_iters=60, init=bad, seed=0)
    assert fixed is not None and validate(fixed)


def test_discover_rejects_impossible_rank():
    """Rank 6 for <2,2,2> does not exist (Strassen is optimal): must fail."""
    l = discover(2, 2, 2, 6, restarts=3, als_iters=30, seed=0)
    assert l is None
