"""Autotune: deterministic calibration, profile persistence, block-plan export."""
import json

import numpy as np
import pytest

from repro.core import autotune, decision as dec, plan_cache
from repro.core import hardware as hw
from repro.core.falcon_gemm import FalconConfig


@pytest.fixture(autouse=True)
def isolated_profiles(tmp_path, monkeypatch):
    """Point the profile dir at a tmpdir and undo registry side effects."""
    monkeypatch.setenv(hw.ENV_PROFILE_DIR, str(tmp_path))
    before = dict(hw._PROFILES)
    plan_cache.reset()
    yield tmp_path
    hw._PROFILES.clear()
    hw._PROFILES.update(before)
    plan_cache.reset()


def model_timer(fn, *args):
    """Deterministic 'clock': seconds as a pure function of operand sizes."""
    elems = sum(int(np.prod(a.shape)) for a in args)
    return 1e-9 * elems + 1e-6


def test_autotune_deterministic_with_injected_timer():
    kw = dict(base="cpu_host", backend="jnp", timer=model_timer, validate=True)
    r1 = autotune.autotune(**kw)
    r2 = autotune.autotune(**kw)
    assert r1.profile.to_dict() == r2.profile.to_dict()
    assert [p.as_dict() for p in r1.probes] == [p.as_dict() for p in r2.probes]
    assert r1.model_rel_err == r2.model_rel_err
    assert r1.profile.name == "cpu_host_autotuned"
    assert r1.profile.flops_mul > 0 and r1.profile.beta > 0
    assert 0 < r1.profile.lcma_gemm_efficiency <= 1.0


def test_autotune_deterministic_on_pallas_interpret_backend():
    """Same probes, same timer => bit-identical calibration through the
    Pallas interpret-mode pipeline (kernels run, clock is injected)."""
    kw = dict(base="cpu_host", backend="pallas_interpret",
              shapes=[(16, 16, 16), (32, 16, 32)], timer=model_timer,
              validate=True)
    r1 = autotune.autotune(**kw)
    r2 = autotune.autotune(**kw)
    assert r1.profile.to_dict() == r2.profile.to_dict()
    assert r1.model_rel_err == r2.model_rel_err
    assert len(r1.probes) == 2 and len(r1.model_rel_err) == 2


def test_autotune_real_timing_smoke():
    """Tiny real-clock run: sane, positive, registered."""
    rep = autotune.autotune(base="cpu_host", backend="jnp",
                            shapes=[(64, 64, 64)], reps=1, warmup=1,
                            validate=False)
    p = rep.profile
    assert np.isfinite([p.flops_mul, p.flops_add, p.beta]).all()
    assert p.flops_mul > 0 and p.beta > 0
    assert hw.get_profile(p.name) is p            # registered by name


def test_calibrated_profile_loads_from_disk_into_decide(tmp_path):
    rep = autotune.autotune(base="cpu_host", backend="jnp", timer=model_timer,
                            validate=False, name="testhost_autotuned")
    path = hw.save_profile(rep.profile)
    assert path == hw.profile_path("testhost_autotuned")
    # drop the in-memory registration: decide() must load the JSON
    hw._PROFILES.pop("testhost_autotuned")
    d = dec.decide(8192, 8192, 8192, "testhost_autotuned", "float32")
    assert d.gemm_seconds == pytest.approx(
        dec.gemm_time(8192, 8192, 8192, rep.profile, "float32"))
    # FalconConfig resolves the same way (serving config by name)
    assert FalconConfig(hardware="testhost_autotuned").profile.beta == \
        pytest.approx(rep.profile.beta)


def test_calibrate_writes_profile_json_with_metadata(tmp_path):
    rep, path = autotune.calibrate(base="cpu_host", backend="jnp",
                                   timer=model_timer, validate=True)
    doc = json.load(open(path))
    assert doc["name"] == rep.profile.name
    meta = doc["_metadata"]
    assert meta["backend"] == "jnp" and meta["scheme"] == "strassen"
    assert len(meta["probes"]) == len(rep.probes)
    assert "strassen" in meta["block_plans"]
    # profile round-trips ignoring metadata
    p2 = hw.load_profile(path, register=False)
    assert p2.to_dict() == rep.profile.to_dict()


def test_block_plans_fit_vmem_budget():
    from repro.core import algorithms as alg
    from repro.kernels import tuning
    for name in ("strassen", "laderman"):
        l = alg.get(name)
        bp = tuning.block_plans(l, 4096, 4096, 4096, dtype="float32")
        assert bp["fused_gemm_vmem_bytes"] <= bp["vmem_budget_bytes"]
        assert bp["combine_a_vmem_bytes"] <= bp["vmem_budget_bytes"]
        Mp, Kp, Np = bp["padded_shape"]
        assert Mp % l.m == 0 and Kp % l.k == 0 and Np % l.n == 0
    # High-rank schemes overflow VMEM through the (R, bx, bz) accumulator even
    # at the smallest block (paper §IV-C); the planner degrades to minimum
    # blocks and the export reports the honest over-budget footprint.
    s444 = tuning.block_plans(alg.get("s444"), 4096, 4096, 4096)
    strassen = tuning.block_plans(alg.get("strassen"), 4096, 4096, 4096)
    assert s444["fused_gemm"] <= strassen["fused_gemm"]   # degraded blocks
    assert s444["fused_gemm_vmem_bytes"] > 0


def test_tune_cli_end_to_end(tmp_path, capsys):
    from repro.tools import tune
    rc = tune.main(["--hardware", "cpu_host", "--backend", "jnp",
                    "--shape", "64,64,64", "--reps", "1",
                    "--name", "cli_autotuned"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "wrote" in out and "warmed plan cache" in out
    prof = hw.load_profile(hw.profile_path("cli_autotuned"), register=False)
    assert prof.name == "cli_autotuned" and prof.flops_mul > 0
    warmed = plan_cache.PlanCache(
        path=str(tmp_path / "cli_autotuned.plans.json"))
    assert len(warmed) > 0
