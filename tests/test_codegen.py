"""Deployment Module: generated source correctness across variants."""
import jax
import numpy as np
import pytest

from repro.core import algorithms as alg, codegen


@pytest.mark.parametrize("name", ["strassen", "laderman", "s223", "s444"])
@pytest.mark.parametrize("fused", [True, False])
def test_generated_matches_reference(name, fused, rng):
    l = alg.get(name)
    g = codegen.generate(l, codegen.CodegenOptions(
        fused=fused, gemm_backend="batched" if fused else "loop"))
    M, K, N = l.m * 8, l.k * 8, l.n * 8
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = np.asarray(jax.jit(g.fn)(A, B))
    np.testing.assert_allclose(C, A @ B, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_dtypes(dtype, rng):
    import jax.numpy as jnp
    l = alg.get("strassen")
    g = codegen.generate(l)
    A = jnp.asarray(rng.standard_normal((32, 32)), dtype)
    B = jnp.asarray(rng.standard_normal((32, 32)), dtype)
    C = g.fn(A, B)
    assert C.dtype == jnp.dtype(dtype)
    ref = np.asarray(A, np.float32) @ np.asarray(B, np.float32)
    tol = 1e-4 if dtype == "float32" else 0.15
    np.testing.assert_allclose(np.asarray(C, np.float32), ref, rtol=tol, atol=tol)


from _schemes import mag2_scheme as _mag2_scheme  # noqa: E402 - shared fixture


@pytest.mark.parametrize("fused", [True, False])
def test_generated_honors_coefficient_magnitude(fused, rng):
    l = _mag2_scheme()
    assert int(np.abs(l.U).max()) > 1  # the regression precondition
    g = codegen.generate(l, codegen.CodegenOptions(fused=fused))
    M, K, N = l.m * 8, l.k * 8, l.n * 8
    A = rng.integers(-4, 4, (M, K)).astype(np.float32)
    B = rng.integers(-4, 4, (K, N)).astype(np.float32)
    C = np.asarray(jax.jit(g.fn)(A, B))
    np.testing.assert_array_equal(C, A @ B)  # integer inputs => exact


def test_source_has_no_runtime_coefficients():
    """Coefficients must be compile-time constants (constant-folded +/-)."""
    g = codegen.generate(alg.get("strassen"))
    # no indexed coefficient-tensor reads anywhere in the emitted program
    assert "U[" not in g.source and "V[" not in g.source and "W[" not in g.source
    assert "a_0_0 + a_1_1" in g.source or "a_0_0 +a_1_1" in g.source.replace("  ", " ")


def test_source_is_cached():
    a = codegen.generate(alg.get("strassen"))
    b = codegen.generate(alg.get("strassen"))
    assert a is b
    c = codegen.generate(alg.get("strassen"), codegen.CodegenOptions(fused=False))
    assert c is not a


def test_precombined_b(rng):
    l = alg.get("laderman")
    g = codegen.generate(l, codegen.CodegenOptions(precombined_b=True))
    A = rng.standard_normal((l.m * 4, l.k * 4)).astype(np.float32)
    B = rng.standard_normal((l.k * 4, l.n * 4)).astype(np.float32)
    Bt = g.combine_b(B)
    assert Bt.shape == (l.R, 4, 4)
    np.testing.assert_allclose(np.asarray(g.fn(A, Bt)), A @ B, rtol=1e-4, atol=1e-4)


def test_stagewise_equivalence(rng):
    """Alg.1 staged execution == fused end-to-end (the step-wise bench basis)."""
    l = alg.get("strassen")
    g1 = codegen.generate(l, codegen.CodegenOptions(fused=False, gemm_backend="loop"))
    A = rng.standard_normal((16, 16)).astype(np.float32)
    B = rng.standard_normal((16, 16)).astype(np.float32)
    At = g1.stages["combine_a"](A)
    Bt = g1.stages["combine_b"](B)
    H = g1.stages["gemm"](At, Bt)
    C = g1.stages["combine_h"](H, A.dtype)
    np.testing.assert_allclose(np.asarray(C), A @ B, rtol=1e-4, atol=1e-4)
