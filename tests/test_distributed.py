"""Simulated-mesh tier: sharded decisions + the multi-device ServeEngine.

Auto-marked ``mesh`` by conftest; CI's distributed job runs this module (and
the other mesh modules) under 8 simulated host devices. Multi-device bodies
go through ``run_multidevice`` so this process keeps its single real device.
"""
import dataclasses

import pytest

from conftest import run_multidevice


def test_decide_sharded_collective_cost_flips_layout():
    """The collective term flips col-sharded vs replicated on one shape.

    On tpu_v5e at D=8, 8192^3 bf16: with the fast ICI link the
    communication-avoiding "col" layout (shard N, all-gather C) wins; price
    the same shape over a slow 1 GB/s interconnect and the replicated layout
    (no collectives, full local contraction) is cheaper. The layout axis is
    doing real work — it is not a constant argmin.
    """
    from repro.core import decision as dec
    from repro.core.hardware import TPU_V5E

    fast = dec.decide_sharded(8192, 8192, 8192, TPU_V5E, "bfloat16",
                              n_devices=8)
    slow_hw = dataclasses.replace(TPU_V5E, collective_bw=1e9)
    slow = dec.decide_sharded(8192, 8192, 8192, slow_hw, "bfloat16",
                              n_devices=8)
    assert fast.communication_avoiding and not slow.communication_avoiding
    assert fast.layout != slow.layout == "replicated"
    assert fast.collective_seconds > 0.0 and slow.collective_seconds == 0.0
    # each winner beats the other's layout under its own bandwidth
    assert fast.seconds < slow.seconds


def test_plan_sharded_caches_and_roundtrips(tmp_path):
    from repro.core import decision as dec, falcon_gemm as fg, plan_cache

    cache = plan_cache.configure(path=str(tmp_path / "plans.json"),
                                 autoload=False)
    cfg = fg.FalconConfig(mode="auto")
    d1 = fg.plan_sharded(4096, 4096, 4096, cfg, "bfloat16", n_devices=8,
                         layouts=dec.default_layouts())
    misses = cache.stats.misses
    d2 = fg.plan_sharded(4096, 4096, 4096, cfg, "bfloat16", n_devices=8,
                         layouts=dec.default_layouts())
    assert isinstance(d1, dec.ShardedDecision)
    assert cache.stats.hits >= 1 and cache.stats.misses == misses
    assert (d2.layout, d2.n_devices) == (d1.layout, d1.n_devices)

    cache.save()
    fresh = plan_cache.PlanCache(path=str(tmp_path / "plans.json"))
    key = next(k for k in fresh.keys() if "ly=" in k)
    hit = fresh.lookup(key)
    assert isinstance(hit, dec.ShardedDecision)
    assert hit.layout == d1.layout
    assert hit.local_shape_mnk == d1.local_shape_mnk
    plan_cache.configure()  # restore the process default


def test_collective_probe_on_simulated_mesh():
    """measure_collective_bw sees 8 host devices; autotune records it."""
    out = run_multidevice("""
        from repro.core import autotune
        bw = autotune.measure_collective_bw(size_bytes=1 << 18, reps=1)
        assert bw is not None and bw > 0, bw
        rep = autotune.autotune(shapes=[(64, 64, 64)], reps=1, warmup=0,
                                validate=False, collectives=True,
                                name="probe_mesh")
        assert rep.profile.collective_bw > 0, rep.profile
        assert rep.profile.coll_bw() == rep.profile.collective_bw
        print("COLL_OK", bw)
    """, timeout=420)
    assert "COLL_OK" in out


@pytest.mark.slow
def test_mesh_serve_engine_matches_single_device():
    """Acceptance: --mesh 1,8 tensor parallelism serves 32/32 identically.

    One subprocess builds the same granite smoke model twice — single-device
    and sharded over an 8-way model mesh — submits the same 32 ragged
    requests to both, and requires equal tokens plus allclose recorded
    per-step logits, compared in submission order.
    """
    out = run_multidevice("""
        import numpy as np
        from repro.configs import smoke_config
        from repro.serve import ServeEngine, StepLoop

        cfg = smoke_config("granite_3_2b")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 17)))
                   for _ in range(32)]
        gens = [int(rng.integers(1, 5)) for _ in range(32)]

        def serve(mesh_shape):
            eng = ServeEngine(cfg, max_slots=4, max_prompt_len=16,
                              max_new_tokens=4, record_logits=True, seed=0,
                              mesh_shape=mesh_shape)
            for p, g in zip(prompts, gens):
                eng.submit(p, max_new_tokens=g)
            done = StepLoop(eng).run_until_idle()
            assert len(done) == 32, len(done)
            return eng

        e1 = serve(None)
        e8 = serve({"data": 1, "model": 8})
        assert e8.mesh is not None and dict(e8.mesh.shape)["model"] == 8
        worst = 0.0
        for r1, r8 in zip(e1.requests, e8.requests):
            assert r1.generated == r8.generated, (r1.generated, r8.generated)
            for l1, l8 in zip(r1.logits, r8.logits):
                worst = max(worst, float(np.max(np.abs(l1 - l8))))
        assert worst < 1e-4, worst
        print("SERVE_OK", len(e8.requests), worst)
    """, timeout=600)
    assert "SERVE_OK 32" in out
