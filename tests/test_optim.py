"""Optimizer + schedule unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)


def test_adamw_optimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_weight_decay_shrinks_params():
    cfg = AdamWConfig(lr=0.01, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    zeros = {"w": jnp.zeros((4,))}
    params2, _, _ = adamw_update(params, zeros, state, cfg)
    assert float(jnp.max(params2["w"])) < 1.0


@given(st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_clip_bounds_norm(max_norm):
    g = {"a": jnp.full((16,), 7.0), "b": jnp.full((4, 4), -3.0)}
    clipped, gnorm = clip_by_global_norm(g, max_norm)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    assert total <= max_norm * (1 + 1e-5) or total <= float(gnorm) + 1e-5


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, 10, 100))
    s10 = float(cosine_schedule(10, 10, 100))
    s100 = float(cosine_schedule(100, 10, 100))
    assert s0 == 0.0
    assert abs(s10 - 1.0) < 1e-5
    assert 0.09 < s100 < 0.11  # min_ratio floor


def test_bf16_params_f32_state():
    cfg = AdamWConfig(lr=1e-3)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    p2, s2, _ = adamw_update(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(s2["step"]) == 1


def test_gradient_accumulation_matches_full_batch():
    """microbatched train step == single-batch step (same grads/params)."""
    import numpy as np
    from repro.configs import registry
    from repro.models import model as M
    from repro.train.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    import dataclasses
    cfg = dataclasses.replace(registry.smoke_config("granite_3_2b"), remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    oc = AdamWConfig(lr=1e-3)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1 = jax.jit(make_train_step(cfg, oc, total_steps=10))
    s4 = jax.jit(make_train_step(cfg, oc, total_steps=10, microbatches=4))
    p1, _, m1 = s1(params, adamw_init(params, oc), batch, 0)
    p4, _, m4 = s4(params, adamw_init(params, oc), batch, 0)
    # losses are means over the same tokens; params should match closely
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 1e-3, d
