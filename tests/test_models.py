"""Model zoo: per-arch smoke, decode==forward consistency, SSD correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models import ssd as SSD


def _batch_for(cfg, rng, B=2, S=16):
    if cfg.frontend == "audio_codebooks":
        t = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S, cfg.num_codebooks)),
                        jnp.int32)
        return {"tokens": t, "labels": t}
    t = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    out = {"tokens": t, "labels": t}
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", registry.list_archs())
def test_arch_smoke_train_and_decode(arch, rng):
    """Reduced config: one loss eval (finite) + one cached decode step."""
    cfg = registry.smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: M.lm_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    cache = M.init_cache(cfg, 2, 32)
    tok = batch["tokens"][:, :1]
    logits, new_cache, _ = jax.jit(
        lambda p, t, c: M.forward(p, cfg, t, cache=c, cache_index=0,
                                  logits_mode="last"))(params, tok, cache)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch


@pytest.mark.parametrize("arch", ["granite_3_2b", "hymba_1_5b", "mamba2_370m"])
def test_decode_matches_full_forward(arch, rng):
    """Autoregressive consistency: prefill+decode logits == full forward."""
    cfg = dataclasses.replace(registry.smoke_config(arch), remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # full forward logits at every position
    hidden, _, _ = M.forward(params, cfg, tokens, logits_mode="none")
    full_logits = M.compute_logits(params, cfg, hidden)

    # prefill on the first S-1 tokens, then decode token S-1
    cache = M.init_cache(cfg, B, S + 4)
    _, cache, _ = M.forward(params, cfg, tokens[:, :S - 1], cache=cache,
                            cache_index=0, logits_mode="none")
    dec_logits, _, _ = M.forward(params, cfg, tokens[:, S - 1:S], cache=cache,
                                 cache_index=S - 1, logits_mode="last")
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)


def test_ssd_scan_matches_sequential(rng):
    """Chunked SSD == naive per-step recurrence."""
    B, L, H, P, G, N = 2, 16, 4, 8, 2, 16
    chunk = 4
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, L, H)), jnp.float32)
    A = jnp.asarray(rng.uniform(-1.5, -0.2, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((B, L, G, N)) * 0.3, jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((B, L, G, N)) * 0.3, jnp.float32)

    y_chunk, s_chunk = SSD.ssd_scan(x, dt, A, B_, C_, chunk)

    # sequential oracle via the decode step
    s = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(L):
        y_t, s = SSD.ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], A,
                                     B_[:, t:t + 1], C_[:, t:t + 1], s)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s),
                               rtol=2e-4, atol=2e-4)


def test_gemma3_layer_window_pattern():
    cfg = registry.get_config("gemma3_27b")
    w = cfg.layer_windows()
    assert len(w) == 62
    assert w[5] == 0 and all(x == 1024 for x in w[:5])  # 5 local : 1 global
    assert sum(1 for x in w if x == 0) == 62 // 6


def test_sliding_window_masks_old_tokens(rng):
    """A token beyond the window must not influence local-attention logits."""
    from repro.models.layers import attention_scores
    B, S, H, hd = 1, 8, 2, 4
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    pos = jnp.arange(S)[None]
    out1 = attention_scores(q, k, v, pos, pos, window=2)
    k2 = k.at[:, 0].set(99.0)  # outside the window of the last query
    v2 = v.at[:, 0].set(99.0)
    out2 = attention_scores(q, k2, v2, pos, pos, window=2)
    np.testing.assert_allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]),
                               atol=1e-5)


def test_param_counts_match_literature():
    """Full-config param counts are in the right ballpark (catches config typos)."""
    import repro.launch  # noqa: F401
    expect = {
        "granite_3_2b": (2.0e9, 3.5e9),
        "gemma3_27b": (24e9, 30e9),
        "starcoder2_15b": (13e9, 17e9),
        "mistral_nemo_12b": (11e9, 14e9),
        "kimi_k2_1t": (0.95e12, 1.15e12),
        "dbrx_132b": (1.2e11, 1.45e11),
        "mamba2_370m": (3.0e8, 4.6e8),
        "hymba_1_5b": (1.2e9, 1.9e9),
        "musicgen_large": (1.5e9, 2.6e9),
        "pixtral_12b": (11e9, 14e9),
    }
    import jax as _jax
    from repro.configs import get_config
    from repro.models import model as MM
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        sds = _jax.eval_shape(lambda c=cfg: MM.init_params(c, _jax.random.PRNGKey(0)))
        n = sum(int(np.prod(l.shape)) for l in _jax.tree.leaves(sds))
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"
