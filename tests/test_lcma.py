"""LCMA scheme library: tensor-identity validation + closure operations."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import algorithms as alg
from repro.core.lcma import LCMA, apply_reference, validate


def test_all_library_schemes_validate():
    lib = alg.library()
    assert len(lib) >= 20
    for name, l in lib.items():
        assert validate(l), name
        assert l.R < l.m * l.k * l.n, f"{name} is not lower-complexity"


def test_known_ranks():
    lib = alg.library()
    assert lib["strassen"].R == 7
    assert lib["laderman"].R == 23          # Laderman-family <3,3,3>
    assert lib["s223"].R == 11              # Hopcroft-Kerr rank
    assert lib["s444"].R == 49              # two-level Strassen


def test_strassen_nnz_matches_paper():
    # paper §III-C: ||U||_0 = 12 for Strassen
    s = alg.get("strassen")
    assert s.nnz_u == 12 and s.nnz_v == 12 and s.nnz_w == 12


@pytest.mark.parametrize("name", ["strassen", "strassen-winograd", "laderman",
                                  "s223", "s232", "s322", "s444", "s555"])
def test_apply_reference_exact(name, rng):
    l = alg.get(name)
    M, K, N = l.m * 4, l.k * 4, l.n * 4
    A = rng.integers(-8, 8, (M, K)).astype(np.float64)
    B = rng.integers(-8, 8, (K, N)).astype(np.float64)
    # integer inputs => LCMA must be EXACT (coefficients are +-1)
    np.testing.assert_array_equal(apply_reference(l, A, B), A @ B)


def test_invalid_scheme_rejected():
    s = alg.strassen()
    bad_w = s.W.copy()
    bad_w[0, 0, 0] = -bad_w[0, 0, 0] or 1
    bad = LCMA("bad", 2, 2, 2, 7, s.U, s.V, bad_w)
    assert not validate(bad)


from _schemes import mag2_111 as _mag2_111  # noqa: E402 - shared fixture


def test_magnitude_coefficients_validate_and_apply(rng):
    """Schemes with |c| > 1 (AlphaTensor standard-arithmetic / Smirnov
    listings) are first-class: the identity holds and the reference apply
    honors coefficient magnitude."""
    l = _mag2_111()
    assert validate(l)
    big = alg.tensor_product(l, alg.strassen(), "mag2-222")
    assert validate(big)
    A = rng.integers(-8, 8, (big.m * 3, big.k * 3)).astype(np.float64)
    B = rng.integers(-8, 8, (big.k * 3, big.n * 3)).astype(np.float64)
    np.testing.assert_array_equal(apply_reference(big, A, B), A @ B)


def test_non_integer_coefficients_rejected():
    U = np.array([[[0.5]]], np.float64)
    with pytest.raises(ValueError, match="non-integer"):
        LCMA("halfs", 1, 1, 1, 1, U, U, U)


def test_out_of_range_coefficients_rejected():
    U = np.array([[[300]]], np.int32)
    ok = np.array([[[1]]], np.int8)
    with pytest.raises(ValueError, match="int8 range"):
        LCMA("huge", 1, 1, 1, 1, U, ok, ok)


def test_register_validates_and_guards_names():
    l = _mag2_111()
    try:
        alg.register(l)
        assert alg.get(l.name) is l
        with pytest.raises(ValueError, match="already registered"):
            alg.register(l)
    finally:
        alg.unregister(l.name)
    s = alg.strassen()
    bad_w = s.W.copy()
    bad_w[0, 0, 0] += 1
    with pytest.raises(ValueError, match="Brent equations violated"):
        alg.register(LCMA("bad-reg", 2, 2, 2, 7, s.U, s.V, bad_w))
    assert "bad-reg" not in alg.library()


@given(st.sampled_from(["strassen", "s223", "laderman"]),
       st.sampled_from(["strassen", "s322"]))
@settings(max_examples=8, deadline=None)
def test_tensor_product_closure(n1, n2):
    l = alg.tensor_product(alg.get(n1), alg.get(n2))
    assert validate(l)
    l1, l2 = alg.get(n1), alg.get(n2)
    assert l.R == l1.R * l2.R
    assert l.grid == (l1.m * l2.m, l1.k * l2.k, l1.n * l2.n)


@given(st.sampled_from(["strassen", "s223", "s232", "laderman"]))
@settings(max_examples=8, deadline=None)
def test_symmetry_closures(name):
    l = alg.get(name)
    assert validate(alg.transpose_dual(l))
    assert validate(alg.cyclic(l))


def test_concat_closures():
    s = alg.strassen()
    assert validate(alg.concat_n(s, alg.standard(2, 2, 3)))
    assert validate(alg.concat_m(s, alg.standard(3, 2, 2)))
    assert validate(alg.concat_k(s, alg.standard(2, 3, 2)))


def test_candidates_sorted_by_saving():
    cands = alg.candidates(max_grid=5)
    savings = [c.mult_saving for c in cands]
    assert savings == sorted(savings, reverse=True)
    assert all(max(c.grid) <= 5 for c in cands)
