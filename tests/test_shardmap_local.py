import numpy as np
from conftest import run_multidevice

def test_shard_map_local_backend():
    out = run_multidevice("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.core.falcon_gemm import FalconConfig, falcon_dense
        from repro.parallel import sharding as SH
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        SH.set_parallel_style("fsdp_only")
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 12, 48))
        w = jax.random.normal(jax.random.PRNGKey(1), (48, 40))
        cfg = FalconConfig(mode="strassen", backend="shard_map_local")
        with compat.set_mesh(mesh):
            got = jax.jit(lambda a, b: falcon_dense(a, b, cfg))(x, w)
            # grads flow through the shard_map + LCMA path
            g = jax.jit(jax.grad(lambda b: jnp.sum(falcon_dense(x, b, cfg) ** 2)))(w)
        ref = np.asarray(x) @ np.asarray(w)
        err = float(np.max(np.abs(np.asarray(got) - ref)))
        assert err < 1e-3, err
        g0 = jax.grad(lambda b: jnp.sum((x @ b) ** 2))(w)
        gerr = float(jnp.max(jnp.abs(g - g0)))
        assert gerr < 1e-2, gerr
        print("SM_LOCAL_OK", err, gerr)
    """)
    assert "SM_LOCAL_OK" in out
