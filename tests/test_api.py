"""Unified dispatch API: context config, backend registry, dot_general/einsum
normalization, PlannedWeight, and the deprecation/compat shims.

This module must stay clean under ``-W error::DeprecationWarning`` (the CI
deprecation lane): tests that exercise the legacy ``fcfg`` shim capture the
warning explicitly with ``pytest.warns``.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.core import backends, decision as dec, engine
from repro.core.falcon_gemm import FalconConfig, plan

FORCE = FalconConfig(mode="strassen", backend="jnp")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Context-scoped config
# ---------------------------------------------------------------------------

def test_use_context_nesting_and_restoration():
    assert falcon.active_config() is None
    assert falcon.current_config() == FalconConfig()
    outer = FalconConfig(mode="strassen")
    inner = FalconConfig(mode="gemm", hardware="a100")
    with falcon.use(outer):
        assert falcon.current_config() is outer
        with falcon.use(inner):
            assert falcon.current_config() is inner
        assert falcon.current_config() is outer
    assert falcon.active_config() is None


def test_use_context_restores_on_exception():
    with pytest.raises(RuntimeError):
        with falcon.use(FalconConfig(mode="strassen")):
            raise RuntimeError("boom")
    assert falcon.active_config() is None


def test_context_config_drives_dispatch(rng):
    A = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((32, 48)), jnp.float32)
    with falcon.use(FORCE):
        got = falcon.matmul(A, B)           # no cfg argument anywhere
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ B),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def test_register_dispatch_unregister(rng):
    calls = []

    def spy(a2, b, l, cfg):
        calls.append((a2.shape, b.shape, l.name))
        return backends.get_backend("jnp").apply(a2, b, l, cfg)

    falcon.register_backend("spy_backend", spy)
    try:
        A = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        got = falcon.matmul(A, B, cfg=dataclasses.replace(FORCE,
                                                          backend="spy_backend"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(A @ B),
                                   rtol=1e-4, atol=1e-4)
        assert calls == [((32, 32), (32, 32), "strassen")]
        assert "spy_backend" in falcon.available_backends()
    finally:
        falcon.unregister_backend("spy_backend")
    assert "spy_backend" not in falcon.available_backends()


def test_unknown_backend_error_lists_registered(rng):
    A = jnp.ones((16, 16), jnp.float32)
    with pytest.raises(KeyError, match="no_such_backend"):
        falcon.matmul(A, A, cfg=dataclasses.replace(FORCE,
                                                    backend="no_such_backend"))
    with pytest.raises(KeyError, match="jnp"):
        backends.get_backend("no_such_backend")


def test_reregister_requires_overwrite():
    falcon.register_backend("dup_backend", lambda *a: None)
    try:
        with pytest.raises(ValueError, match="already registered"):
            falcon.register_backend("dup_backend", lambda *a: None)
        falcon.register_backend("dup_backend", lambda *a: None, overwrite=True)
    finally:
        falcon.unregister_backend("dup_backend")


def test_builtin_backends_present():
    for name in ("jnp", "pallas", "pallas_interpret", "shard_map_local"):
        assert name in falcon.available_backends()


# ---------------------------------------------------------------------------
# dot_general / einsum normalization
# ---------------------------------------------------------------------------

DOT_CASES = [
    # (a_shape, b_shape, dimension_numbers)
    ((64, 32), (32, 48), (((1,), (0,)), ((), ()))),          # plain dense
    ((32, 64), (32, 48), (((0,), (0,)), ((), ()))),          # transposed lhs
    ((64, 32), (48, 32), (((1,), (1,)), ((), ()))),          # transposed rhs
    ((4, 24, 16), (4, 16, 20), (((2,), (1,)), ((0,), (0,)))),  # batched
    ((4, 16, 24), (4, 16, 20), (((1,), (1,)), ((0,), (0,)))),  # batched + T
    ((3, 5, 24, 16), (3, 5, 16, 10),
     (((3,), (2,)), ((0, 1), (0, 1)))),                      # 2 batch dims
    ((6, 8, 10), (8, 10, 7), (((1, 2), (0, 1)), ((), ()))),  # 2 contract dims
]


@pytest.mark.parametrize("ashape,bshape,dn", DOT_CASES)
@pytest.mark.parametrize("mode", ["strassen", "auto"])
def test_dot_general_matches_lax(rng, ashape, bshape, dn, mode):
    a = jnp.asarray(rng.standard_normal(ashape), jnp.float32)
    b = jnp.asarray(rng.standard_normal(bshape), jnp.float32)
    cfg = dataclasses.replace(FORCE, mode=mode)
    got = falcon.dot_general(a, b, dn, cfg=cfg)
    want = jax.lax.dot_general(a, b, dn)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_dot_general_under_jit_and_grad(rng):
    a = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 40)), jnp.float32)
    dn = (((1,), (0,)), ((), ()))
    f = lambda x, y: jnp.sum(jnp.sin(falcon.dot_general(x, y, dn, cfg=FORCE)))
    g_got = jax.jit(jax.grad(f))(a, b)
    g_want = jax.grad(lambda x, y: jnp.sum(jnp.sin(x @ y)))(a, b)
    np.testing.assert_allclose(np.asarray(g_got), np.asarray(g_want),
                               rtol=1e-3, atol=1e-3)


def test_dot_general_preferred_element_type_falls_back(rng):
    a = jnp.asarray(rng.standard_normal((32, 32)), jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.bfloat16)
    dn = (((1,), (0,)), ((), ()))
    got = falcon.dot_general(a, b, dn, cfg=FORCE,
                             preferred_element_type=jnp.float32)
    want = jax.lax.dot_general(a, b, dn, preferred_element_type=jnp.float32)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


EINSUM_CASES = [
    ("mk,kn->mn", (40, 24), (24, 32)),
    ("km,kn->mn", (24, 40), (24, 32)),       # transposed
    ("bqhd,bkhd->bhqk", (2, 16, 4, 8), (2, 12, 4, 8)),   # attention scores
    ("bhqk,bkhd->bqhd", (2, 4, 16, 12), (2, 12, 4, 8)),  # attention values
    ("bij,bjk->bik", (3, 20, 16), (3, 16, 24)),
    ("ij,kj->ik", (20, 16), (24, 16)),
]


@pytest.mark.parametrize("subs,ashape,bshape", EINSUM_CASES)
def test_einsum_matches_jnp(rng, subs, ashape, bshape):
    a = jnp.asarray(rng.standard_normal(ashape), jnp.float32)
    b = jnp.asarray(rng.standard_normal(bshape), jnp.float32)
    got = falcon.einsum(subs, a, b, cfg=FORCE)
    want = jnp.einsum(subs, a, b)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_einsum_fallback_paths(rng):
    # sum-out label, single operand, three operands: all must fall back to
    # jnp.einsum semantics rather than erroring.
    a = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(falcon.einsum("ij,jk->k", a, b, cfg=FORCE)),
        np.asarray(jnp.einsum("ij,jk->k", a, b)), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(falcon.einsum("ii->i", jnp.eye(5) * 3.0)),
        np.asarray(jnp.einsum("ii->i", jnp.eye(5) * 3.0)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(falcon.einsum("ij,jk,kl->il", a, b, c, cfg=FORCE)),
        np.asarray(jnp.einsum("ij,jk,kl->il", a, b, c)), rtol=1e-5, atol=1e-5)


def test_einsum_parser_rejects_unsupported():
    p = engine._einsum_dimension_numbers
    assert p("...ij,jk->...ik", 3, 2) is None        # ellipsis
    assert p("ii,ij->ij", 2, 2) is None              # repeated label
    assert p("ij,jk->k", 2, 2) is None               # summed-out free label
    assert p("ij,jk", 3, 2) is None                  # rank mismatch
    dn, perm = p("ij,jk", 2, 2)                      # implicit output
    assert dn == (((1,), (0,)), ((), ())) and perm == (0, 1)


# ---------------------------------------------------------------------------
# PlannedWeight (offline Combine B)
# ---------------------------------------------------------------------------

def test_planned_weight_matches_eager(rng):
    W = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 8, 128)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE, m_hint=256)
    assert pw.precombined and pw.algo == "strassen"
    eager = falcon.dense(x, W, cfg=FORCE)
    planned = falcon.dense(x, pw, cfg=FORCE)
    np.testing.assert_allclose(np.asarray(planned), np.asarray(eager),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(planned), np.asarray(x @ W),
                               rtol=1e-3, atol=1e-3)


def test_planned_weight_is_a_pytree_through_jit(rng):
    W = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE)
    leaves = jax.tree.leaves(pw)
    assert len(leaves) == 2  # w and bt ride as children; scheme is static
    got = jax.jit(lambda x_, p_: falcon.dense(x_, p_, cfg=FORCE))(x, pw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-3, atol=1e-3)


def test_planned_weight_gemm_bound_passthrough(rng):
    # auto mode on a tiny shape: the Decision Module declines, the wrapper
    # degrades to a plain weight and matches jnp.matmul bitwise.
    W = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FalconConfig(), m_hint=4)
    assert pw.algo is None and not pw.precombined
    np.testing.assert_array_equal(
        np.asarray(falcon.dense(x, pw)), np.asarray(x @ W))


def test_planned_weight_keep_weight_false(rng):
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE, keep_weight=False)
    assert pw.w is None and pw.precombined
    # raw weight dropped: the precombined path is always taken, even in auto
    got = falcon.dense(x, pw, cfg=FalconConfig())
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-3, atol=1e-3)


def test_planned_weight_stacked_and_getitem(rng):
    W = jnp.asarray(rng.standard_normal((3, 64, 48)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE)
    assert pw.precombined and pw.bt.shape[0] == 3
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    got = falcon.dense(x, pw[1], cfg=FORCE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W[1]),
                               rtol=1e-3, atol=1e-3)


def test_precombine_params_plans_dense_projections(rng):
    params = {
        "layers": {"attn": {"w_q": jnp.asarray(
            rng.standard_normal((2, 64, 64)), jnp.float32)}},
        "embed": jnp.asarray(rng.standard_normal((100, 64)), jnp.float32),
        "final_norm": jnp.ones((64,), jnp.float32),
    }
    new, n = falcon.precombine_params(params, cfg=FORCE, m_hint=256)
    assert n == 1
    assert isinstance(new["layers"]["attn"]["w_q"], falcon.PlannedWeight)
    assert new["embed"] is params["embed"]          # not a projection pattern
    assert new["final_norm"] is params["final_norm"]


def test_precombine_params_idempotent(rng):
    params = {"w_q": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    once, n1 = falcon.precombine_params(params, cfg=FORCE, m_hint=256)
    twice, n2 = falcon.precombine_params(once, cfg=FORCE, m_hint=256)
    assert n1 == 1 and n2 == 0
    assert isinstance(twice["w_q"], falcon.PlannedWeight)
    assert not isinstance(twice["w_q"].w, falcon.PlannedWeight)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    got = falcon.dense(x, twice["w_q"], cfg=FORCE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ params["w_q"]),
                               rtol=1e-3, atol=1e-3)


def test_planned_weight_pallas_backend(rng):
    # the precombined serving path must route through the selected backend's
    # apply_precombined (kernel pipeline), not silently fall back to jnp
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((10, 64)), jnp.float32)
    cfg = dataclasses.replace(FORCE, backend="pallas_interpret")
    pw = falcon.plan_weight(W, cfg=cfg)
    got = falcon.dense(x, pw, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-3, atol=1e-3)


def test_backend_apply_precombined_is_dispatched(rng):
    calls = []

    def pre_spy(a2, bt, l, n_logical, cfg):
        calls.append((a2.shape, bt.shape, l.name, n_logical))
        return backends.get_backend("jnp").apply_precombined(
            a2, bt, l, n_logical, cfg)

    falcon.register_backend("pre_spy", backends.get_backend("jnp").apply,
                            apply_precombined=pre_spy)
    try:
        cfg = dataclasses.replace(FORCE, backend="pre_spy")
        W = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
        pw = falcon.plan_weight(W, cfg=cfg)
        got = falcon.dense(x, pw, cfg=cfg)
        assert calls and calls[0][2] == "strassen" and calls[0][3] == 32
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                                   rtol=1e-3, atol=1e-3)
    finally:
        falcon.unregister_backend("pre_spy")


def test_dot_general_accepts_planned_weight(rng):
    W = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE)
    dn = (((1,), (0,)), ((), ()))
    got = falcon.dot_general(x, pw, dn, cfg=FORCE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-3, atol=1e-3)
    with pytest.raises(ValueError, match="canonical dense contraction"):
        falcon.dot_general(x, pw, (((0,), (0,)), ((), ())), cfg=FORCE)


# ---------------------------------------------------------------------------
# Deprecation shim: legacy fcfg arguments warn; ported paths are clean
# ---------------------------------------------------------------------------

def test_explicit_fcfg_still_works_but_warns(rng):
    from repro.models.layers import mlp_apply
    p = {"mlp_up": jnp.asarray(rng.standard_normal((16, 32)), jnp.float32),
         "mlp_down": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)}
    x = jnp.asarray(rng.standard_normal((2, 4, 16)), jnp.float32)
    with pytest.warns(DeprecationWarning, match="falcon.use"):
        got = mlp_apply(p, x, FalconConfig(enabled=False))
    want = mlp_apply(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_model_forward_ported_path_is_warning_free():
    from repro.configs import registry
    from repro.models import model as M
    cfg = registry.smoke_config("granite_3_2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 8), jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with falcon.use(M.falcon_config_for(cfg)):
            hidden, _, _ = M.forward(params, cfg, tokens)
            loss, _ = M.lm_loss(params, cfg,
                                {"tokens": tokens, "labels": tokens})
    assert np.isfinite(float(loss))


def test_forward_fcfg_kwarg_warns_and_overrides():
    from repro.configs import registry
    from repro.models import model as M
    cfg = registry.smoke_config("granite_3_2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 4), jnp.int32)
    with pytest.warns(DeprecationWarning):
        M.forward(params, cfg, tokens, fcfg=FalconConfig(enabled=False))


# ---------------------------------------------------------------------------
# Satellites: _dtype_bytes fallback, shard round-up, compat shims
# ---------------------------------------------------------------------------

def test_dtype_bytes_extended_dtypes():
    assert dec._dtype_bytes("bfloat16") == 2
    assert dec._dtype_bytes("int32") == 4
    assert dec._dtype_bytes("float8_e4m3fn") == 1
    with pytest.raises(ValueError, match="unknown dtype"):
        dec._dtype_bytes("not_a_dtype")


def test_decide_on_extended_dtype_does_not_raise():
    d = dec.decide(4096, 4096, 4096, "tpu_v5e", "int32")
    assert d.gemm_seconds > 0


def test_plan_shards_round_up_not_truncate(caplog):
    cfg = FalconConfig(mode="gemm", shards=(3, 1, 1))
    d = plan(100, 64, 64, cfg, "float32")
    assert d.M == 34  # ceil(100/3), not 33
    cfg16 = FalconConfig(mode="gemm", shards=(16, 1, 16))
    d2 = plan(100, 64, 100, cfg16, "float32")
    assert d2.M == 7 and d2.N == 7
    with pytest.raises(ValueError, match="shards"):
        plan(64, 64, 64, FalconConfig(shards=(0, 1, 1)), "float32")


def test_plan_shards_warns_once(caplog):
    import logging
    cfg = FalconConfig(mode="gemm", shards=(7, 1, 1))
    with caplog.at_level(logging.WARNING, logger="repro.core.falcon_gemm"):
        plan(99, 32, 32, cfg, "float32")
        plan(99, 32, 32, cfg, "float32")
    hits = [r for r in caplog.records if "do not divide" in r.message]
    assert len(hits) == 1


def test_compat_mesh_roundtrip():
    from repro import compat
    assert compat.get_abstract_mesh() is None
    mesh = compat.make_mesh((1,), ("data",))
    with compat.set_mesh(mesh):
        m = compat.get_abstract_mesh()
        assert m is not None and "data" in m.axis_names
    assert compat.get_abstract_mesh() is None


def test_compat_shard_map_single_device():
    from jax.sharding import PartitionSpec as P
    from repro import compat
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: x * 2, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"), check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(f(jnp.arange(4.0))), np.arange(4.0) * 2)
