"""Quantization-fused Combine A + int8 fused GEMM (paper §IV-C, int8/TPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.kernels.group_combine import group_combine
from repro.kernels.quant_combine import (fused_gemm_combine_h_quant,
                                         group_combine_quant,
                                         quantize_b_blockwise)


@pytest.mark.parametrize("name", ["strassen", "s223"])
def test_quant_combine_roundtrip(name, rng):
    """Dequantized Ã matches the f32 combine within int8 resolution."""
    l = alg.get(name)
    X, Y, by = 32, 64, 32
    x = jnp.asarray(rng.standard_normal((l.m * X, l.k * Y)), jnp.float32)
    q, s = group_combine_quant(x, l.U, block=(16, by), interpret=True)
    assert q.dtype == jnp.int8 and q.shape == (l.R, X, Y)
    assert s.shape == (l.R, X, Y // by)
    deq = q.astype(jnp.float32) * jnp.repeat(s, by, axis=2)
    want = group_combine(x, l.U, block=(16, 32), interpret=True)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(deq), np.asarray(want),
                               atol=scale / 127 * 1.01)


def test_int8_fused_lcma_matmul(rng):
    """End-to-end int8 LCMA: quant-combined A x offline-quantized B ~= A@B."""
    l = alg.get("strassen")
    M = K = N = 128
    by = 32
    A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    aq, as_ = group_combine_quant(A, l.U, block=(32, by), interpret=True)
    bq, bs = quantize_b_blockwise(B, l.V, by=by, interpret=True)
    cp = fused_gemm_combine_h_quant(aq, as_, bq, bs, l.W,
                                    block=(32, 32, by), interpret=True)
    C = cp.transpose(0, 2, 1, 3).reshape(M, N)
    ref_c = np.asarray(A) @ np.asarray(B)
    rel = np.linalg.norm(np.asarray(C) - ref_c) / np.linalg.norm(ref_c)
    assert rel < 0.02, rel  # int8 block-scaled: ~1% relative error expected


def test_int8_error_comparable_to_plain_int8_gemm(rng):
    """LCMA int8 error stays within ~2x of a plain blockwise-int8 GEMM."""
    l = alg.get("strassen")
    M = K = N = 128
    by = 32
    A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    ref_c = np.asarray(A) @ np.asarray(B)

    # plain blockwise int8 (no LCMA): quantize directly
    def q8(x, axis_block):
        xb = x.reshape(x.shape[0], x.shape[1] // axis_block, axis_block)
        s = np.maximum(np.abs(xb).max(axis=2) / 127.0, 1e-12)
        q = np.clip(np.round(xb / s[..., None]), -127, 127)
        return (q * s[..., None]).reshape(x.shape)

    plain = q8(np.asarray(A), by) @ np.asarray(B)
    e_plain = np.linalg.norm(plain - ref_c) / np.linalg.norm(ref_c)

    aq, as_ = group_combine_quant(A, l.U, block=(32, by), interpret=True)
    bq, bs = quantize_b_blockwise(B, l.V, by=by, interpret=True)
    cp = fused_gemm_combine_h_quant(aq, as_, bq, bs, l.W,
                                    block=(32, 32, by), interpret=True)
    C = np.asarray(cp.transpose(0, 2, 1, 3).reshape(M, N))
    e_lcma = np.linalg.norm(C - ref_c) / np.linalg.norm(ref_c)
    assert e_lcma < 4 * e_plain + 1e-4, (e_lcma, e_plain)


def test_quant_combine_honors_coefficient_magnitude(rng):
    """|c|=2 scheme through the quantized Combine-A: the f32 pre-quantization
    accumulator must scale by the coefficient magnitude (regression for the
    ``t if c > 0 else -t`` bug that mapped every |c| to 1)."""
    from _schemes import mag2_scheme

    l = mag2_scheme()
    X, Y, by = 16, 32, 16
    x = jnp.asarray(rng.standard_normal((l.m * X, l.k * Y)), jnp.float32)
    q, s = group_combine_quant(x, l.U, block=(16, by), interpret=True)
    deq = q.astype(jnp.float32) * jnp.repeat(s, by, axis=2)
    want = group_combine(x, l.U, block=(16, 16), interpret=True)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(deq), np.asarray(want),
                               atol=scale / 127 * 1.01)
