"""Property tests for the falcon-check analysis passes (tests/_propcheck.py).

Three families:

  * composition operators preserve exact Brent validity — any pairing of
    library schemes through ``tensor_product``/``concat_*``/``cyclic``/
    ``transpose_dual`` must verify with zero residual;
  * the int8 accumulator bound is an actual bound: no randomized int8
    contraction of a given depth exceeds ``int8_accum_bound(depth)``, and
    every depth admitted by ``max_safe_accum_depth(32)`` stays inside int32;
  * the stability regression: the |c|>1 family from ``tests/_schemes.py``
    carries a strictly larger error bound than same-grid ternary Strassen,
    and falcon-check's stability pass flags it.
"""
import numpy as np

from repro import analysis
from repro.core import algorithms as alg

from _propcheck import given, settings, st
from _schemes import mag2_111, mag2_scheme

_BASE = ("strassen", "strassen-winograd", "laderman", "s223")
_UNARY = ("cyclic", "transpose_dual")


@settings(max_examples=16, deadline=None)
@given(st.sampled_from(_BASE), st.sampled_from(_BASE),
       st.sampled_from(("tensor_product", "concat_n", "concat_m", "concat_k")))
def test_composition_preserves_brent_validity(n1, n2, op):
    l1, l2 = alg.get(n1), alg.get(n2)
    fn = getattr(alg, op)
    if op != "tensor_product":
        # concat ops require matching grids on the non-concatenated dims
        if (l1.m, l1.k, l1.n) != (l2.m, l2.k, l2.n):
            return
    out = fn(l1, l2, f"prop-{op}-{n1}-{n2}")
    assert analysis.check_scheme(out) == []


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(_BASE), st.sampled_from(_UNARY))
def test_unary_composition_preserves_brent_validity(name, op):
    out = getattr(alg, op)(alg.get(name))
    assert analysis.check_scheme(out) == []


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=4096), st.integers(0, 2**31 - 1))
def test_int8_accum_bound_never_violated(depth, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-127, 128, size=depth, dtype=np.int64)
    b = rng.integers(-127, 128, size=depth, dtype=np.int64)
    assert abs(int(a @ b)) <= analysis.int8_accum_bound(depth)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=(2**31 - 1) // 127**2))
def test_safe_depth_fits_int32(depth):
    assert depth <= analysis.max_safe_accum_depth(32)
    assert analysis.int8_accum_bound(depth) <= 2**31 - 1
    assert not analysis.has_errors(analysis.check_quant_accumulator(depth, 32))


def test_unsafe_depth_overflows_int32():
    depth = analysis.max_safe_accum_depth(32) + 1
    assert analysis.int8_accum_bound(depth) > 2**31 - 1
    assert analysis.has_errors(analysis.check_quant_accumulator(depth, 32))
    # the bound is attainable: all-(-127) against all-127 meets it exactly
    a = np.full(4, 127, np.int64)
    assert int(a @ a) == analysis.int8_accum_bound(4)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(("float32", "bfloat16", "float16")))
def test_mag2_family_has_larger_bound_and_is_flagged(dtype):
    """Regression: the |c|>1 family must carry a larger bound than Strassen
    on the same grid, and the stability pass must flag it."""
    m2 = mag2_scheme()
    assert m2.grid == alg.strassen().grid
    assert m2.stability.error_bound(dtype) > \
        alg.strassen().stability.error_bound(dtype)
    findings = analysis.check_scheme_stability(m2, dtype=dtype)
    assert any(f.severity == "warning" and "magnitude" in f.message
               for f in findings)
    # and with Strassen's own bound as the budget, it becomes an error
    budget = alg.strassen().stability.error_bound(dtype)
    findings = analysis.check_scheme_stability(m2, budget=budget, dtype=dtype)
    assert analysis.has_errors(findings)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_stability_bound_dominates_reference_error(seed):
    """The Higham bound is conservative: measured float32 error of the |c|>1
    scheme against an exact float64 product stays under error_bound."""
    from repro.core.lcma import apply_reference

    l = mag2_111()
    rng = np.random.default_rng(seed)
    A = rng.uniform(-1, 1, (8, 8))
    B = rng.uniform(-1, 1, (8, 8))
    exact = A @ B
    got = apply_reference(l, A.astype(np.float32), B.astype(np.float32))
    scale = np.abs(A).max() * np.abs(B).max() * A.shape[1]
    rel = np.max(np.abs(got.astype(np.float64) - exact)) / scale
    assert rel <= l.stability.error_bound("float32")
