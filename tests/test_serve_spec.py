"""Speculative decoding, prefix reuse, chunked prefill and streaming.

The contract under test is *token-exactness*: every serving optimization in
this file — γ-token speculation with greedy verify/rollback, radix prefix-KV
reuse, fixed-bucket chunked prefill, per-token streaming — must emit exactly
the tokens the plain non-speculative engine emits, while adding zero
Decision-Module plan keys beyond ``warm()``. Properties (radix invariants,
bucket monotonicity) go through ``tests/_propcheck.py`` so they run with or
without hypothesis installed.
"""
import random
import threading

import numpy as np
import pytest

from repro.configs import registry
from repro.core import plan_cache
from repro.serve import (BucketPolicy, DraftModel, Request, RequestQueue,
                         Scheduler, SelfDraft, ServeEngine, ServeStats)
from repro.serve.prefix_cache import RadixPrefixCache
from repro.serve.scheduler import DecodeWork, PrefillWork
from tests._propcheck import given, settings, st

CFG = registry.smoke_config("granite_3_2b")

# one ragged request set shared by every exactness test in this file; the
# lengths cross both seq buckets (8, 16) and exercise chunk boundaries
PROMPT_LENS = (5, 11, 3, 16, 7, 9)


def _prompts(rng, cfg=CFG, lens=PROMPT_LENS):
    return [list(rng.integers(1, cfg.vocab_size, int(n))) for n in lens]


@pytest.fixture(scope="module")
def baseline():
    """Non-speculative reference: prompts -> greedy generations (+ logits)."""
    plan_cache.reset()
    engine = ServeEngine(CFG, max_slots=4, max_prompt_len=16,
                         max_new_tokens=6, record_logits=True, seed=0)
    engine.warm()
    prompts = _prompts(np.random.default_rng(3))
    reqs = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    out = {tuple(p): (list(r.generated), [np.asarray(x) for x in r.logits])
           for p, r in zip(prompts, reqs)}
    return prompts, out


@pytest.fixture(scope="module")
def spec_served(baseline):
    """One engine with every tier-2 feature on, serving ``baseline``'s
    prompts twice (second pass = prefix-cache hits)."""
    prompts, _ = baseline
    plan_cache.reset()
    engine = ServeEngine(CFG, max_slots=4, max_prompt_len=16,
                         max_new_tokens=6, seed=0, speculate=2,
                         prefix_cache=True, prefill_chunk=8)
    engine.warm()
    first = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    second = [engine.submit(p, max_new_tokens=6) for p in prompts]
    engine.run()
    return engine, first, second


# ---------------------------------------------------------------------------
# Radix prefix cache: properties against a naive reference
# ---------------------------------------------------------------------------

def _naive_longest_prefix(inserted: dict, key: tuple) -> tuple:
    best = ()
    for toks in inserted:
        if len(toks) > len(best) and key[:len(toks)] == toks:
            best = toks
    return best


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_radix_longest_prefix_matches_naive(seed):
    """lookup() returns exactly the longest inserted key that prefixes the
    query — checked against a brute-force scan over random small-alphabet
    token sequences (shared prefixes guaranteed by the tiny alphabet)."""
    rnd = random.Random(seed)
    cache = RadixPrefixCache(max_entries=64)
    inserted = {}
    for i in range(30):
        toks = tuple(rnd.randrange(4) for _ in range(rnd.randint(1, 12)))
        cache.insert(toks, {"id": i})
        inserted[toks] = i
    for _ in range(30):
        query = tuple(rnd.randrange(4) for _ in range(rnd.randint(1, 14)))
        n, entry = cache.lookup(query)
        best = _naive_longest_prefix(inserted, query)
        assert n == len(best)
        if best:
            assert entry is not None and tuple(entry.tokens) == best
            assert entry.payload["id"] == inserted[best]
        else:
            assert entry is None


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=8))
def test_radix_capacity_and_pinned_survival(seed, max_entries):
    """Eviction keeps ``entries <= max_entries`` whenever an unpinned victim
    exists, and a pinned entry is NEVER evicted regardless of pressure."""
    rnd = random.Random(seed)
    cache = RadixPrefixCache(max_entries=max_entries)
    pinned_key = tuple(rnd.randrange(4) for _ in range(6))
    cache.insert(pinned_key, {"pinned": True})
    n, entry = cache.lookup(pinned_key, pin=True)
    assert n == len(pinned_key)
    for i in range(4 * max_entries):
        toks = tuple(rnd.randrange(4) for _ in range(rnd.randint(1, 10)))
        if toks != pinned_key:
            cache.insert(toks, {"i": i})
        assert cache.stats()["entries"] <= max_entries + cache.stats()["pinned"]
        m, e = cache.lookup(pinned_key)
        assert m == len(pinned_key) and e is entry, \
            "pinned entry evicted under pressure"
    cache.release(entry)


def test_radix_lru_eviction_order():
    cache = RadixPrefixCache(max_entries=2)
    cache.insert((1, 2, 3), {"a": 1})
    cache.insert((1, 2, 4), {"b": 2})
    cache.lookup((1, 2, 3))                     # refresh a -> b is now LRU
    cache.insert((5, 6), {"c": 3})              # evicts b
    assert cache.lookup((1, 2, 3))[0] == 3
    # b is gone, and no surviving entry prefixes (1, 2, 4)
    assert cache.lookup((1, 2, 4)) == (0, None)
    assert cache.stats()["evictions"] == 1


def test_radix_edge_split_preserves_entries():
    cache = RadixPrefixCache(max_entries=8)
    cache.insert((7, 8, 9, 10), {"long": 1})
    cache.insert((7, 8), {"short": 1})          # splits the (7,8,9,10) edge
    n, e = cache.lookup((7, 8, 9, 10, 11))
    assert n == 4 and e.payload == {"long": 1}
    n, e = cache.lookup((7, 8, 9))
    assert n == 2 and e.payload == {"short": 1}


# ---------------------------------------------------------------------------
# Bucket monotonicity: speculative verify shapes stay on the pow2 grid
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=4))
def test_verify_batch_bucket_monotone(b1, b2, gamma):
    """decode_batch_bucket is monotone and idempotent, so a verify launch
    (batch_bucket, γ+1) never leaves the warmed grid: the γ+1 axis is a
    compile-time constant and the batch axis only ever rounds up pow2."""
    policy = BucketPolicy.build(max_prompt_len=16, max_slots=8, min_seq=8)
    lo, hi = sorted((b1, b2))
    assert policy.decode_batch_bucket(lo) <= policy.decode_batch_bucket(hi)
    assert policy.decode_batch_bucket(policy.decode_batch_bucket(b1)) == \
        policy.decode_batch_bucket(b1)
    assert policy.decode_batch_bucket(b1) >= b1
    # the verify row-shape set over every reachable batch is exactly the
    # decode-batch grid x {gamma+1}: no data-dependent shapes exist
    shapes = {(policy.decode_batch_bucket(b), gamma + 1) for b in range(1, 9)}
    assert shapes == {(b, gamma + 1) for b in policy.decode_batch}


# ---------------------------------------------------------------------------
# Scheduler: decode-fairness cap (starvation regression)
# ---------------------------------------------------------------------------

def _drive_scheduler(cap, steps=60):
    """Simulate serving with instantaneous steps and continuous arrivals;
    returns the per-work-item sequence of ("P"|"D") labels."""
    q = RequestQueue()
    policy = BucketPolicy.build(max_prompt_len=16, max_slots=8, min_seq=8)
    s = Scheduler(q, policy, max_slots=8, max_consecutive_prefills=cap)
    rng = np.random.default_rng(0)

    def arrive(n):
        for _ in range(n):
            plen = int(rng.choice([5, 16]))     # mixed buckets: small groups
            q.submit(Request(prompt=list(range(1, plen + 1)),
                             max_new_tokens=4))

    arrive(16)
    trace = []
    for _ in range(steps):
        work = s.next_work()
        if work is None:
            arrive(4)
            continue
        if isinstance(work, PrefillWork):
            trace.append("P")
        else:
            trace.append("D")
            # fake one decode step: age every request, retire finished ones
            for r in work.requests:
                r.generated.append(0)
                if len(r.generated) >= r.max_new_tokens:
                    r.state = "done"
                    s.release(r)
            arrive(2)                           # arrivals keep pressure up
    return "".join(trace)


def test_scheduler_decode_fairness_cap_bounds_gaps():
    """With the cap, no in-flight decode ever waits more than
    ``max_consecutive_prefills`` work items; with the cap disabled the same
    arrival stream produces longer prefill runs (the cap is load-bearing)."""
    capped = _drive_scheduler(cap=2)
    assert "D" in capped
    # after the first decode becomes ready, prefill runs are bounded by 2
    first_d = capped.index("D")
    runs = [len(r) for r in capped[first_d:].split("D") if r]
    assert runs and max(runs) <= 2, capped
    uncapped = _drive_scheduler(cap=0)
    runs0 = [len(r) for r in uncapped.split("D") if r]
    assert max(runs0) > 2, uncapped             # starvation without the cap


def test_scheduler_rejects_off_grid_prefill_chunk():
    q = RequestQueue()
    policy = BucketPolicy.build(max_prompt_len=16, max_slots=4, min_seq=8)
    with pytest.raises(ValueError):
        Scheduler(q, policy, max_slots=4, prefill_chunk=12)
    Scheduler(q, policy, max_slots=4, prefill_chunk=8)


def test_scheduler_chunked_prefill_work_geometry():
    """A long prompt splits into exactly-full intermediate chunks plus a
    bucketed final chunk, and the slot decodes only after the final chunk."""
    q = RequestQueue()
    policy = BucketPolicy.build(max_prompt_len=32, max_slots=2, min_seq=8)
    s = Scheduler(q, policy, max_slots=2, prefill_chunk=8,
                  max_consecutive_prefills=0)
    q.submit(Request(prompt=list(range(1, 21)), max_new_tokens=2))  # plen 20
    chunks = []
    for _ in range(3):
        w = s.next_work()
        assert isinstance(w, PrefillWork)
        chunks.append((w.starts[0], w.lengths[0], w.seq_pad, w.final[0]))
    assert chunks == [(0, 8, 8, False), (8, 8, 8, False), (16, 4, 8, True)]
    assert isinstance(s.next_work(), DecodeWork)


# ---------------------------------------------------------------------------
# ServeStats: accounting invariants + stable observable surface
# ---------------------------------------------------------------------------

def test_serve_stats_as_dict_keys_are_stable():
    """Dashboards key on this dict: adding a field is fine, renaming or
    dropping one is a breaking change this assertion makes loud."""
    expected = {
        "prefill_steps", "decode_steps", "verify_steps", "steps",
        "prompt_tokens", "generated_tokens", "decode_real_rows",
        "decode_emitted_tokens", "prefill_padded_tokens",
        "decode_padded_tokens", "drafted_tokens", "accepted_tokens",
        "prefix_hits", "prefix_misses", "prefix_tokens_reused",
        "bucket_hits", "bucket_misses", "warmed_shapes", "warm_plans",
        "t_warm", "t_prefill", "t_decode", "requests_admitted",
        "requests_finished", "bucket_hit_rate", "padding_waste",
        "tokens_per_s", "decode_tokens_per_s", "acceptance_rate",
        "prefix_hit_rate",
    }
    assert set(ServeStats().as_dict()) == expected


def test_serve_stats_rates_safe_on_zero():
    s = ServeStats()
    assert s.acceptance_rate == 0.0 and s.prefix_hit_rate == 0.0
    assert s.decode_tokens_per_s == 0.0 and s.padding_waste == 0.0


def test_spec_stats_attribution(spec_served):
    """Speculation's accounting: verify rows are launched work (padding
    waste), accepted tokens are throughput (decode_tokens_per_s numerator),
    and each request's first token still comes from prefill."""
    engine, first, second = spec_served
    s = engine.stats
    n_req = len(first) + len(second)
    assert s.requests_finished == n_req
    assert 0 < s.acceptance_rate <= 1.0
    assert s.accepted_tokens <= s.drafted_tokens
    # every verify step launches gamma+1 rows per real request and drafts
    # gamma per real request, so rows = drafted * (gamma+1)/gamma (gamma=2)
    assert s.decode_real_rows == (s.drafted_tokens // 2) * 3
    assert s.generated_tokens == s.decode_emitted_tokens + n_req
    assert s.decode_padded_tokens >= s.decode_real_rows
    assert s.padding_waste < 1.0
    d = s.as_dict()
    assert d["acceptance_rate"] == round(s.acceptance_rate, 4)
    assert d["prefix_hit_rate"] == round(s.prefix_hit_rate, 4)


# ---------------------------------------------------------------------------
# Token-exactness: speculation + prefix reuse + chunked prefill
# ---------------------------------------------------------------------------

def test_speculative_identity_draft_token_exact(baseline, spec_served):
    prompts, out = baseline
    engine, first, second = spec_served
    for p, r in zip(prompts, first):
        assert list(r.generated) == out[tuple(p)][0], (r.rid, r.generated)
    assert engine.stats.acceptance_rate > 0
    assert engine.stats.verify_steps > 0 and engine.stats.decode_steps == 0


def test_prefix_reuse_token_exact_and_hits(baseline, spec_served):
    """The second pass over identical prompts reuses prompt[:-1] KV from the
    radix cache and still emits identical tokens."""
    prompts, out = baseline
    engine, _, second = spec_served
    for p, r in zip(prompts, second):
        assert list(r.generated) == out[tuple(p)][0]
    st_ = engine.prefix.stats()
    assert engine.stats.prefix_hits == len(prompts)
    assert st_["hits"] == len(prompts)
    assert engine.stats.prefix_tokens_reused == \
        sum(len(p) - 1 for p in prompts)


@pytest.mark.parametrize("arch", ["granite_3_2b", "dbrx_132b"])
def test_speculative_shrunk_draft_token_exact(arch):
    """A 1-layer sliced draft mispredicts freely on random weights; greedy
    verify/rollback must still emit exactly the non-speculative tokens on
    both a dense and a MoE attention arch."""
    cfg = registry.smoke_config(arch)
    plan_cache.reset()
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg, lens=(6, 13, 4))
    base = ServeEngine(cfg, max_slots=2, max_prompt_len=16,
                       max_new_tokens=6, seed=0)
    base.warm()
    base_reqs = [base.submit(p, max_new_tokens=6) for p in prompts]
    base.run()
    want = [list(r.generated) for r in base_reqs]
    eng = ServeEngine(cfg, max_slots=2, max_prompt_len=16, max_new_tokens=6,
                      seed=0, speculate=2, draft_keep_layers=1)
    eng.warm()
    assert isinstance(eng.draft, SelfDraft)
    assert isinstance(eng.draft, DraftModel)   # protocol conformance
    assert eng.draft.keep_layers == 1 < cfg.num_layers
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()
    assert [list(r.generated) for r in reqs] == want
    assert eng.stats.acceptance_rate > 0       # some drafts survive ...
    assert eng.stats.accepted_tokens < eng.stats.drafted_tokens  # ... not all


def test_ssm_family_rejects_speculation():
    """Recurrent state cannot roll back a rejected draft; the engine must
    refuse rather than silently emit wrong tokens."""
    with pytest.raises(ValueError, match="specul"):
        ServeEngine(registry.smoke_config("mamba2_370m"), max_slots=2,
                    max_prompt_len=8, max_new_tokens=2, speculate=2)


def test_chunked_prefill_logits_allclose_one_shot(baseline):
    """Chunked prefill is numerically the same computation: the recorded
    per-step logits of a chunked engine match the one-shot engine's."""
    prompts, out = baseline
    plan_cache.reset()
    eng = ServeEngine(CFG, max_slots=4, max_prompt_len=16, max_new_tokens=6,
                      seed=0, prefill_chunk=8, record_logits=True)
    eng.warm()
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        want_toks, want_logits = out[tuple(p)]
        assert list(r.generated) == want_toks
        for got, ref in zip(r.logits, want_logits):
            np.testing.assert_allclose(np.asarray(got), ref,
                                       rtol=1e-4, atol=1e-4)


def test_ssm_prefix_continuation_token_exact():
    """State-bearing caches key entries at the full prompt; a prompt that
    extends a served one resumes from the exact-length state snapshot."""
    cfg = registry.smoke_config("mamba2_370m")
    plan_cache.reset()
    rng = np.random.default_rng(5)
    head = list(rng.integers(1, cfg.vocab_size, 9))
    cont = head + list(rng.integers(1, cfg.vocab_size, 3))
    base = ServeEngine(cfg, max_slots=2, max_prompt_len=16, max_new_tokens=5,
                       seed=0)
    base.warm()
    rb = base.submit(cont, max_new_tokens=5)
    base.run()
    eng = ServeEngine(cfg, max_slots=2, max_prompt_len=16, max_new_tokens=5,
                      seed=0, prefix_cache=True)
    eng.warm()
    eng.submit(head, max_new_tokens=5)
    eng.run()
    r = eng.submit(cont, max_new_tokens=5)
    eng.run()
    assert list(r.generated) == list(rb.generated)
    assert eng.stats.prefix_hits == 1
    assert eng.stats.prefix_tokens_reused == len(head)


# ---------------------------------------------------------------------------
# Streaming
# ---------------------------------------------------------------------------

def test_streaming_matches_final_output(spec_served):
    """Tokens seen through the iterator and the callback equal the request's
    final ``generated`` list, in order, under speculation."""
    engine, _, _ = spec_served
    rng = np.random.default_rng(9)
    cb = []
    r = engine.submit(list(rng.integers(1, CFG.vocab_size, 7)),
                      max_new_tokens=6, stream=True,
                      on_token=lambda rq, t: cb.append((rq.rid, t)))
    streamed = []
    th = threading.Thread(
        target=lambda: streamed.extend(r.token_stream(timeout=60)))
    th.start()
    engine.run()
    th.join(60)
    assert not th.is_alive()
    assert streamed == list(r.generated) and len(streamed) >= 1
    assert cb == [(r.rid, t) for t in r.generated]


def test_token_stream_requires_stream_submit(spec_served):
    engine, first, _ = spec_served
    with pytest.raises(ValueError):
        next(first[0].token_stream())


# ---------------------------------------------------------------------------
# Warm coverage: speculation adds zero plan keys beyond warm()
# ---------------------------------------------------------------------------

def test_spec_serve_adds_no_plan_keys_beyond_warm():
    """32 ragged speculative requests (γ=2, prefix cache, chunked prefill)
    touch ONLY plan-cache keys ``warm()`` created: the verify and catch-up
    contexts are registry symbols, not runtime surprises."""
    plan_cache.reset()
    try:
        engine = ServeEngine(CFG, max_slots=4, max_prompt_len=16,
                             max_new_tokens=4, seed=0, speculate=2,
                             prefix_cache=True, prefill_chunk=8)
        engine.warm()
        cache = plan_cache.default_cache()
        keys_warm = set(cache.keys())
        misses_warm = plan_cache.stats().misses
        rng = np.random.default_rng(0)
        for plen in rng.integers(2, 16, size=32):
            engine.submit(list(rng.integers(0, CFG.vocab_size, int(plen))),
                          max_new_tokens=4)
        done = engine.run()
        assert len(done) == 32
        assert set(cache.keys()) == keys_warm, (
            "speculative serving created plan keys warm missed: "
            f"{sorted(set(cache.keys()) - keys_warm)}")
        assert plan_cache.stats().misses == misses_warm
        assert engine.stats.bucket_misses == 0
        assert engine.stats.acceptance_rate > 0
    finally:
        plan_cache.reset()
