"""Property-based tests of the Decision Module's pricing invariants.

Via ``tests/_propcheck`` (real hypothesis when installed, deterministic
corner+seeded sampling otherwise): estimate monotonicity in each dimension,
the grouped eff_B amortization bounds, plan-key uniqueness across the
batch/shared_b/layout parameter space, and the sharded tier's lower bound
(local-only time) when collectives are free.
"""
import dataclasses

from _propcheck import given, settings, st

from repro.core import decision as dec, plan_cache
from repro.core.algorithms import candidates
from repro.core.hardware import CPU_HOST, TPU_V5E

STRASSEN = candidates()[0]
DIMS = st.integers(1, 4096)
PROFILES = st.sampled_from([TPU_V5E, CPU_HOST])


@settings(max_examples=40)
@given(DIMS, DIMS, DIMS, st.integers(1, 2048), PROFILES)
def test_gemm_and_estimate_monotone_in_each_dim(M, N, K, step, hw):
    """Growing any of M/N/K never makes GEMM or an LCMA estimate cheaper."""
    base_g = dec.gemm_time(M, N, K, hw)
    base_e = dec.estimate(STRASSEN, M, N, K, hw).time
    for grown in ((M + step, N, K), (M, N + step, K), (M, N, K + step)):
        assert dec.gemm_time(*grown, hw) >= base_g
        assert dec.estimate(STRASSEN, *grown, hw).time >= base_e


@settings(max_examples=40)
@given(st.integers(1, 4096), st.floats(1e-3, 1.0))
def test_grouped_eff_b_bounded(B, eff):
    """eff_B = B*eff/(B*eff + 1 - eff) lies in [eff, 1] and grows with B."""
    eff_b = B * eff / (B * eff + 1.0 - eff)
    assert eff - 1e-12 <= eff_b <= 1.0 + 1e-12
    eff_b2 = (B + 1) * eff / ((B + 1) * eff + 1.0 - eff)
    assert eff_b2 >= eff_b - 1e-12


@settings(max_examples=60)
@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 512),
       st.integers(1, 8), st.sampled_from([False, True]),
       st.sampled_from([None, "replicated", "col", "row", "data"]),
       st.integers(1, 8))
def test_plan_key_uniqueness(M, K, N, batch, shared_b, layout, n_devices):
    """Distinct (shape, batch, shared_b, layout, D) never collide in the key.

    The key must be injective over every parameter combination the planners
    emit: a collision would hand one configuration another's cached plan.
    """
    seen = getattr(test_plan_key_uniqueness, "_seen", None)
    if seen is None:
        seen = test_plan_key_uniqueness._seen = {}
    # normalize params the key intentionally does not distinguish: shared_b
    # only prices (and keys) grouped decisions, n_devices only sharded ones
    params = (M, K, N, batch, shared_b if batch > 1 else False, layout,
              n_devices if layout is not None else 1)
    key = plan_cache.plan_key(M, K, N, TPU_V5E, "bfloat16", batch=batch,
                              shared_b=shared_b, layout=layout,
                              n_devices=n_devices)
    assert seen.setdefault(key, params) == params, \
        f"plan_key collision: {key!r} for {params} and {seen[key]}"


@settings(max_examples=40)
@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096),
       st.integers(2, 16),
       st.sampled_from(["replicated", "col", "row", "gathered", "data"]))
def test_sharded_estimate_bounded_below_by_local(M, N, K, D, layout_name):
    """Sharded >= local on the same local shape; equal when collectives free.

    The collective term can only add time: with infinite collective bandwidth
    the sharded estimate must equal the pure local estimate of the layout's
    per-shard shape, and with any finite bandwidth it must dominate it.
    """
    ly = dec.layout_by_name(layout_name)
    local = dec.estimate(STRASSEN, *ly.local_shape(M, N, K, D), TPU_V5E).time
    free = dataclasses.replace(TPU_V5E, collective_bw=float("inf"))
    est_free = dec.estimate_sharded(STRASSEN, M, N, K, free,
                                    layout=ly, n_devices=D)
    assert abs(est_free.time - local) <= 1e-12 * max(local, 1.0)
    est_paid = dec.estimate_sharded(STRASSEN, M, N, K, TPU_V5E,
                                    layout=ly, n_devices=D)
    assert est_paid.time >= local
    assert est_paid.collective.time >= 0.0


@settings(max_examples=40)
@given(DIMS, DIMS, DIMS, st.integers(1, 2048), PROFILES)
def test_quant_estimate_monotone_in_each_dim(M, N, K, step, hw):
    """Growing any of M/N/K never makes a quantized estimate cheaper."""
    base = dec.estimate_quant(STRASSEN, M, N, K, hw).time
    for grown in ((M + step, N, K), (M, N + step, K), (M, N, K + step)):
        assert dec.estimate_quant(STRASSEN, *grown, hw).time >= base


@settings(max_examples=40)
@given(st.integers(64, 4096), st.integers(64, 4096), st.integers(64, 4096),
       st.floats(1e-6, 1e-1), PROFILES)
def test_quant_tier_respects_accuracy_budget(M, N, K, budget, hw):
    """The int8 tier never wins past its static error bound.

    ``decide(..., quantize=True)`` may only return precision="int8" when the
    winning scheme's int8 bound fits the budget; a budget below every
    candidate's bound (int8 eps is ~3.9e-3, so 1e-6 is below all of them)
    must always yield an fp decision.
    """
    d = dec.decide(M, N, K, hw, "float32", quantize=True,
                   accuracy_budget=budget)
    if d.quantized:
        assert d.algo.stability.within_budget(budget, "int8")
    d_tight = dec.decide(M, N, K, hw, "float32", quantize=True,
                         accuracy_budget=1e-6)
    assert not d_tight.quantized
    assert d_tight.precision == "fp"
    assert all(e.precision != "int8" for e in d_tight.estimates)


@settings(max_examples=60)
@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 512),
       st.sampled_from([False, True]), st.integers(1, 4))
def test_plan_key_injective_across_precision(M, K, N, quantize, batch):
    """quantize=True/False key disjoint cache slots for every shape/batch.

    A collision would hand the fp pipeline a quantized plan (or vice versa);
    the quant token must also survive alongside the grouped-key format.
    """
    seen = getattr(test_plan_key_injective_across_precision, "_seen", None)
    if seen is None:
        seen = test_plan_key_injective_across_precision._seen = {}
    params = (M, K, N, quantize, batch)
    key = plan_cache.plan_key(M, K, N, TPU_V5E, "bfloat16", batch=batch,
                              quantize=quantize)
    assert seen.setdefault(key, params) == params, \
        f"plan_key collision: {key!r} for {params} and {seen[key]}"
