"""Grouped batched LCMA execution: decision model, kernels, engine, MoE.

The grouped path must be numerically equivalent to the old ``vmap``-over-2-D
lowering for every backend/dtype, the Decision Module must price (and pick)
grouped LCMAs where per-element pricing declines, and a batched shape must
occupy exactly ONE grouped plan-cache key.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.core import algorithms as alg
from repro.core import decision as dec
from repro.core import engine, plan_cache
from repro.core.falcon_gemm import FalconConfig, plan_batched
from repro.core.hardware import TPU_V5E, register_profile
from repro.kernels import ops, ref
from repro.kernels.fused_gemm import batched_fused_gemm_combine_h
from repro.kernels.group_combine import batched_group_combine
from repro.models import moe


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_cache.reset()
    yield
    plan_cache.reset()


def _tol(dtype):
    return dict(atol=1e-4, rtol=1e-4) if dtype == "float32" \
        else dict(atol=0.15, rtol=5e-2)


# ---------------------------------------------------------------------------
# Decision model
# ---------------------------------------------------------------------------

def test_grouped_estimate_degenerates_to_2d_at_b1():
    l = alg.get("strassen")
    e1 = dec.estimate(l, 512, 384, 256, TPU_V5E, "bfloat16")
    eg = dec.estimate_grouped(l, 1, 512, 384, 256, TPU_V5E, "bfloat16")
    assert eg.time == pytest.approx(e1.time, rel=1e-12)
    assert dec.gemm_time_batched(1, 512, 384, 256, TPU_V5E, "bfloat16") == \
        pytest.approx(dec.gemm_time(512, 384, 256, TPU_V5E, "bfloat16"))


def test_grouped_sharing_hoists_combine_b():
    """Shared-B pricing: Combine B charged once, not B times; grouped time
    strictly below the unshared group for any B > 1."""
    l = alg.get("strassen")
    shared = dec.estimate_grouped(l, 8, 1024, 4096, 4096, TPU_V5E, "bfloat16",
                                  shared_b=True)
    unshared = dec.estimate_grouped(l, 8, 1024, 4096, 4096, TPU_V5E, "bfloat16")
    cb_s = next(s for s in shared.stages if s.name == "combine_b")
    cb_u = next(s for s in unshared.stages if s.name == "combine_b")
    assert cb_u.flops == pytest.approx(8 * cb_s.flops)
    assert cb_u.bytes == pytest.approx(8 * cb_s.bytes)
    assert shared.time < unshared.time


def test_grouped_gemm_efficiency_amortizes_with_b():
    """eff_B law: a profile with launch-limited batched GEMMs (eff < 1)
    prices the grouped stage closer to peak as B grows."""
    hw = dataclasses.replace(TPU_V5E, name="eff_test", lcma_gemm_efficiency=0.5)
    l = alg.get("strassen")
    t1 = dec.estimate_grouped(l, 1, 2048, 2048, 2048, hw, "bfloat16").time
    t8 = dec.estimate_grouped(l, 8, 2048, 2048, 2048, hw, "bfloat16").time
    t64 = dec.estimate_grouped(l, 64, 2048, 2048, 2048, hw, "bfloat16").time
    # per-group-element time falls monotonically toward the eff=1 floor
    assert t8 / 8 < t1
    assert t64 / 64 < t8 / 8
    floor = dec.estimate_grouped(
        l, 1, 2048, 2048, 2048,
        dataclasses.replace(hw, lcma_gemm_efficiency=1.0), "bfloat16").time
    assert t64 / 64 > floor * 0.99


def test_decision_selects_grouped_lcma_for_attention_shape():
    """Acceptance: a batched attention score shape — B*H = 32 groups of a
    long-prefill QK^T with wide heads, (Sq=8192, hd=1024) @ (hd, Sk=8192) —
    where per-element pricing declines (the eff-limited GEMM stage loses to
    one standard GEMM) but the grouped decision, with the eff_B amortization
    of the 32*R-product grouped GEMM, picks an LCMA."""
    hw = dataclasses.replace(TPU_V5E, name="attn_test",
                             lcma_gemm_efficiency=0.6)
    d1 = dec.decide(8192, 8192, 1024, hw, "float32")
    dg = dec.decide_batched(32, 8192, 8192, 1024, hw, "float32")
    assert not d1.use_lcma
    assert dg.use_lcma and dg.B == 32 and dg.speedup > 1.05
    # ...and through plan_batched it lands in the plan cache under ONE
    # grouped key carrying the selected scheme
    register_profile(hw)
    cfg = FalconConfig(hardware="attn_test")
    dp = plan_batched(32, 8192, 1024, 8192, cfg, "float32")
    assert dp.use_lcma and dp.algo.name == dg.algo.name
    keys = [k for k in plan_cache.default_cache().keys()
            if "g32x8192x1024x8192" in k]
    assert len(keys) == 1


def test_decision_selects_grouped_lcma_for_moe_expert_shape():
    """Acceptance: the MoE expert group (E x (C, d) @ (d, ff), precombined
    stacked weights so Combine B is offline) picks an LCMA where pricing one
    expert block declines."""
    hw = dataclasses.replace(TPU_V5E, name="moe_test",
                             lcma_gemm_efficiency=0.35)
    E, C, d, ff = 16, 2048, 4096, 14336
    d1 = dec.decide(C, ff, d, hw, "bfloat16", precombined_b=True)
    dg = dec.decide_batched(E, C, ff, d, hw, "bfloat16", precombined_b=True)
    assert not d1.use_lcma
    assert dg.use_lcma and dg.speedup > 1.05
    register_profile(hw)
    cfg = FalconConfig(hardware="moe_test")
    dp = plan_batched(E, C, d, ff, cfg, "bfloat16", precombined_b=True)
    assert dp.use_lcma and dp.algo.name == dg.algo.name
    keys = [k for k in plan_cache.default_cache().keys()
            if f"g{E}x{C}x{d}x{ff}" in k]
    assert len(keys) == 1


def test_batched_memory_bound_guard():
    assert dec.batched_is_memory_bound(8, 64, 64, 64, TPU_V5E, "bfloat16")
    d = dec.decide_batched(8, 64, 64, 64, TPU_V5E, "bfloat16")
    assert not d.use_lcma and d.estimates == ()


# ---------------------------------------------------------------------------
# Plan cache: one grouped key per batched shape
# ---------------------------------------------------------------------------

def test_plan_batched_single_grouped_key():
    cfg = FalconConfig(hardware="tpu_v5e")
    for _ in range(5):
        plan_batched(8, 256, 512, 384, cfg, "bfloat16")
    cache = plan_cache.default_cache()
    keys = cache.keys()
    assert len(keys) == 1, keys
    assert "g8x256x512x384" in keys[0]
    assert cache.stats.misses == 1 and cache.stats.hits == 4
    # shared-B prices differently => its own (single) key
    plan_batched(8, 256, 512, 384, cfg, "bfloat16", shared_b=True)
    assert len(cache.keys()) == 2


def test_plan_batched_key_distinct_from_elementwise():
    cfg = FalconConfig(hardware="tpu_v5e")
    falcon.plan(256, 512, 384, cfg, "bfloat16")
    plan_batched(8, 256, 512, 384, cfg, "bfloat16")
    assert len(plan_cache.default_cache().keys()) == 2


def test_grouped_decision_cache_roundtrip(tmp_path):
    cfg = FalconConfig(hardware="tpu_v5e")
    d = plan_batched(8, 1024, 4096, 14336, cfg, "bfloat16", shared_b=True)
    path = str(tmp_path / "plans.json")
    plan_cache.default_cache().save(path)
    fresh = plan_cache.PlanCache(path=path)
    assert len(fresh) == 1
    hit = fresh.lookup(fresh.keys()[0])
    assert isinstance(hit, dec.GroupedDecision)
    assert hit.B == 8 and hit.shared_b and hit.use_lcma == d.use_lcma
    assert (hit.algo.name if hit.algo else None) == \
        (d.algo.name if d.algo else None)


def test_dot_general_batched_uses_one_grouped_key():
    """The batched dot_general lowering plans ONE grouped key for the whole
    batch — not per-element keys — and still falls back cleanly."""
    cfg = FalconConfig(hardware="tpu_v5e", use_plan_cache=True)
    a = jnp.ones((4, 32, 24), jnp.float32)
    b = jnp.ones((4, 24, 16), jnp.float32)
    out = falcon.dot_general(a, b, (((2,), (1,)), ((0,), (0,))), cfg=cfg)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(jax.lax.dot_general(a, b, (((2,), (1,)), ((0,), (0,))))),
        rtol=1e-6)
    keys = plan_cache.default_cache().keys()
    grouped = [k for k in keys if "g4x32x24x16" in k]
    assert len(grouped) == 1, keys


# ---------------------------------------------------------------------------
# Kernels: batched pipelines vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["strassen", "s223"])
def test_batched_group_combine_matches_oracle(name, rng):
    l = alg.get(name)
    G, X, Y = 3, 16, 8
    x = jnp.asarray(rng.standard_normal((G, l.m * X, l.k * Y)), jnp.float32)
    got = batched_group_combine(x, l.U, block=(8, 8), interpret=True)
    want = jax.vmap(lambda xi: ref.group_combine_ref(
        xi.reshape(l.m, X, l.k, Y).transpose(0, 2, 1, 3), l.U))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("shared_bt", [True, False])
def test_batched_fused_gemm_matches_oracle(shared_bt, rng):
    l = alg.get("strassen")
    G, X, Y, Z = 3, 16, 16, 16
    at = jnp.asarray(rng.standard_normal((G, l.R, X, Y)), jnp.float32)
    bt_shape = (l.R, Y, Z) if shared_bt else (G, l.R, Y, Z)
    bt = jnp.asarray(rng.standard_normal(bt_shape), jnp.float32)
    got = batched_fused_gemm_combine_h(at, bt, l.W, block=(8, 8, 8),
                                       interpret=True)
    want = jax.vmap(
        lambda ai: ref.fused_gemm_combine_h_ref(ai, bt, l.W))(at) \
        if shared_bt else jax.vmap(
        lambda ai, bi: ref.fused_gemm_combine_h_ref(ai, bi, l.W))(at, bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("shared", [True, False])
def test_grouped_pallas_pipeline_odd_shapes(shared, rng):
    l = alg.get("laderman")
    G, M, K, N = 2, 13, 9, 11
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N) if shared else (G, K, N)),
                    jnp.float32)
    got = ops.falcon_grouped_matmul_pallas(a3, b, l, interpret=True)
    want = np.einsum("gmk,kn->gmn" if shared else "gmk,gkn->gmn",
                     np.asarray(a3), np.asarray(b))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_grouped_ref_equals_vmap_of_2d_ref(rng):
    l = alg.get("strassen")
    a3 = jnp.asarray(rng.standard_normal((3, 16, 8)), jnp.float32)
    b3 = jnp.asarray(rng.standard_normal((3, 8, 12)), jnp.float32)
    got = ref.grouped_lcma_matmul_ref(a3, b3, l)
    want = jax.vmap(lambda a, b: ref.lcma_matmul_ref(a, b, l))(a3, b3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# Engine: grouped vs vmap equivalence across backends and dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shared", [True, False])
def test_grouped_matmul_matches_vmap_lowering(backend, dtype, shared, rng):
    """The tentpole equivalence: grouped lowering == vmap of the 2-D core,
    per backend and dtype, shared and per-group B."""
    cfg = FalconConfig(mode="strassen", backend=backend)
    G, M, K, N = 4, 24, 20, 28
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), dtype)
    b = jnp.asarray(rng.standard_normal((K, N) if shared else (G, K, N)),
                    dtype)
    got = falcon.grouped_matmul(a3, b, cfg=cfg)
    assert got.dtype == a3.dtype and got.shape == (G, M, N)
    if shared:
        want = jax.vmap(lambda ai: falcon.matmul(ai, b, cfg=cfg))(a3)
    else:
        want = jax.vmap(lambda ai, bi: falcon.matmul(ai, bi, cfg=cfg))(a3, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("scheme", ["strassen", "laderman", "s223"])
def test_grouped_matmul_matches_lax_per_scheme(scheme, rng):
    cfg = FalconConfig(mode=scheme, backend="jnp")
    a3 = jnp.asarray(rng.standard_normal((3, 26, 17)), jnp.float32)
    b3 = jnp.asarray(rng.standard_normal((3, 17, 22)), jnp.float32)
    got = falcon.grouped_matmul(a3, b3, cfg=cfg)
    np.testing.assert_allclose(np.asarray(got),
                               np.einsum("gmk,gkn->gmn", np.asarray(a3),
                                         np.asarray(b3)),
                               rtol=2e-4, atol=2e-4)


def test_grouped_matmul_gemm_fallback_is_exact(rng):
    cfg = FalconConfig(mode="gemm")
    a3 = jnp.asarray(rng.standard_normal((2, 8, 8)), jnp.float32)
    b3 = jnp.asarray(rng.standard_normal((2, 8, 8)), jnp.float32)
    got = falcon.grouped_matmul(a3, b3, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.matmul(a3, b3)))


def test_grouped_matmul_shape_validation():
    cfg = FalconConfig()
    with pytest.raises(ValueError):
        falcon.grouped_matmul(jnp.ones((4, 8)), jnp.ones((8, 4)), cfg=cfg)
    with pytest.raises(ValueError):
        falcon.grouped_matmul(jnp.ones((2, 4, 8)), jnp.ones((3, 8, 4)), cfg=cfg)
    with pytest.raises(ValueError):
        falcon.grouped_matmul(jnp.ones((2, 4, 8)), jnp.ones((9, 4)), cfg=cfg)


@pytest.mark.parametrize("shared", [True, False])
def test_grouped_grads_match_lax(shared, rng):
    """Planned grouped custom-VJP gradients == lax reference gradients."""
    cfg = FalconConfig(mode="strassen", backend="jnp")
    G, M, K, N = 3, 24, 16, 20
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N) if shared else (G, K, N)),
                    jnp.float32)
    sub = "gmk,kn->gmn" if shared else "gmk,gkn->gmn"

    def loss(a, b):
        return jnp.sum(falcon.grouped_matmul(a, b, cfg=cfg) ** 2)

    def loss_ref(a, b):
        return jnp.sum(jnp.einsum(sub, a, b) ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a3, b)
    ra, rb = jax.grad(loss_ref, argnums=(0, 1))(a3, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), rtol=1e-3,
                               atol=1e-3)


@pytest.mark.parametrize("stacked", [True, False])
def test_grouped_planned_weight_apply(stacked, rng):
    """PlannedWeight through the grouped path: stacked (per-expert B̃) and
    shared (hoisted) forms both allclose to the raw contraction."""
    cfg = FalconConfig(mode="strassen", backend="jnp")
    G, M, K, N = 4, 24, 20, 28
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((G, K, N) if stacked else (K, N)),
                    jnp.float32)
    with falcon.use(cfg):
        pw = falcon.plan_weight(w)
        assert pw.precombined
        got = falcon.grouped_matmul(a3, pw)
    want = jnp.einsum("gmk,gkn->gmn" if stacked else "gmk,kn->gmn", a3, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("stacked", [True, False])
def test_grouped_planned_weight_trains(stacked, rng):
    """Precombined PlannedWeights through the grouped path TRAIN: the
    cotangent routes to the raw weight via the grouped custom-VJP and
    matches the lax reference (zero-grad regression guard — the primal
    reads only B̃, so without the custom VJP grads.w would be 0 and the
    B̃ update would be discarded by refresh_planned_params)."""
    cfg = FalconConfig(mode="strassen", backend="jnp")
    G, M, K, N = 4, 24, 20, 28
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((G, K, N) if stacked else (K, N)),
                    jnp.float32)
    sub = "gmk,gkn->gmn" if stacked else "gmk,kn->gmn"
    with falcon.use(cfg):
        pw = falcon.plan_weight(w, grouped=stacked)
        assert pw.precombined and pw.w is not None

        def loss(p):
            return jnp.sum(falcon.grouped_matmul(a3, p) ** 2)

        g = jax.grad(loss)(pw)
    ref = jax.grad(lambda ww: jnp.sum(jnp.einsum(sub, a3, ww) ** 2))(w)
    assert float(jnp.max(jnp.abs(g.w))) > 0.0
    np.testing.assert_allclose(np.asarray(g.w), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_array_equal(np.asarray(g.bt), np.zeros_like(g.bt))


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("stacked", [True, False])
def test_grouped_planned_weight_trains_without_raw_weight(backend, stacked,
                                                          rng):
    """keep_weight=False: B̃ is the parameter. The rotated rank-R grouped
    backward supplies exact cotangents — including on the Pallas backends,
    whose precombined kernels have no autodiff rule (this crashed before)."""
    cfg = FalconConfig(mode="strassen", backend=backend)
    G, M, K, N = 3, 16, 12, 8
    a3 = jnp.asarray(rng.standard_normal((G, M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((G, K, N) if stacked else (K, N)),
                    jnp.float32)
    sub = "gmk,gkn->gmn" if stacked else "gmk,kn->gmn"
    with falcon.use(cfg):
        pw = falcon.plan_weight(w, keep_weight=False, grouped=stacked)
        assert pw.precombined and pw.w is None

        def loss(p):
            return jnp.sum(falcon.grouped_matmul(a3, p) ** 2)

        val, g = jax.value_and_grad(loss)(pw)
    ref_val = jnp.sum(jnp.einsum(sub, a3, w) ** 2)
    np.testing.assert_allclose(float(val), float(ref_val), rtol=1e-4)
    # exact check: SGD on B̃ must reduce the loss (the cotangent is real)
    assert float(jnp.max(jnp.abs(g.bt))) > 0.0
    with falcon.use(cfg):
        pw2 = dataclasses.replace(pw, bt=pw.bt - 1e-4 * g.bt)
        val2 = loss(pw2)
    assert float(val2) < float(val)


def test_batched_einsum_attention_matches_reference(rng):
    """Attention einsums (batched both sides) through the grouped routing."""
    cfg = FalconConfig(mode="strassen")
    q = jnp.asarray(rng.standard_normal((2, 16, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, 4, 8)), jnp.float32)
    s = falcon.einsum("bqhd,bkhd->bhqk", q, k, cfg=cfg)
    np.testing.assert_allclose(np.asarray(s),
                               np.einsum("bqhd,bkhd->bhqk", np.asarray(q),
                                         np.asarray(k)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE through the grouped path
# ---------------------------------------------------------------------------

def _tiny_moe(rng, dtype=jnp.float32):
    key = jax.random.PRNGKey(3)
    d, ff, E = 16, 32, 4
    p = moe.moe_init(key, d, ff, E, dtype)
    x = jnp.asarray(rng.standard_normal((2, 8, d)), dtype)
    return p, x


def _eager_moe_ffn(p, xb):
    """Reference per-expert SwiGLU: plain jnp, no falcon anywhere."""
    def one(x, wg, wu, wd):
        g = x @ wg
        u = x @ wu
        return (jax.nn.silu(g) * u) @ wd
    return jax.vmap(one)(xb, p["moe_gate"], p["moe_up"], p["moe_down"])


def test_moe_dense_grouped_matches_eager(rng):
    p, x = _tiny_moe(rng)
    with falcon.use(FalconConfig(mode="strassen", backend="jnp")):
        y, aux = moe.moe_apply(p, x, top_k=2, capacity_factor=1.5)
    with falcon.use(FalconConfig(enabled=False)):
        y_ref, aux_ref = moe.moe_apply(p, x, top_k=2, capacity_factor=1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


def test_moe_dense_planned_experts_match_eager(rng):
    """Acceptance: precombined stacked expert weights through moe_apply are
    allclose to the eager path, and the lift actually planned the experts."""
    p, x = _tiny_moe(rng)
    with falcon.use(FalconConfig(mode="strassen", backend="jnp")):
        planned, n = falcon.precombine_params(p, m_hint=64)
        assert n >= 3, "expert stacks should lift to PlannedWeights"
        assert isinstance(planned["moe_gate"], falcon.PlannedWeight)
        assert planned["moe_gate"].bt.ndim == 4       # stacked per-expert B̃
        y, aux = moe.moe_apply(planned, x, top_k=2, capacity_factor=1.5)
    with falcon.use(FalconConfig(enabled=False)):
        y_ref, aux_ref = moe.moe_apply(p, x, top_k=2, capacity_factor=1.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


def test_moe_expert_ffn_is_grouped_planned(rng):
    """The expert FFN hits the plan cache under grouped keys (gEx...), one
    per projection shape — not E per-expert keys."""
    p, x = _tiny_moe(rng)
    cfg = FalconConfig(hardware="tpu_v5e", use_plan_cache=True)
    with falcon.use(cfg):
        moe.moe_apply(p, x, top_k=2, capacity_factor=1.5)
    grouped_keys = [k for k in plan_cache.default_cache().keys() if "|g4x" in k]
    assert len(grouped_keys) == 2, grouped_keys   # (d, ff) and (ff, d)


def test_warm_buckets_covers_grouped_expert_shapes():
    class MoEArch:
        d_model = 64
        num_heads = 4
        num_kv_heads = 4
        resolved_head_dim = 16
        d_ff = 128
        num_experts = 4
        experts_per_token = 2
        capacity_factor = 1.25
        vocab_size = 0
        dtype = "bfloat16"

    cfg = FalconConfig(hardware="tpu_v5e")
    with pytest.warns(DeprecationWarning, match="grouped_moe_shapes"):
        shapes = engine.grouped_expert_shapes(MoEArch(), 64)
    assert shapes == [(4, 40, 64, 128), (4, 40, 128, 64)]
    n = engine.warm_buckets(cfg, MoEArch(), [64])
    cache = plan_cache.default_cache()
    assert any("g4x40x64x128" in k for k in cache.keys())
    assert any("g4x40x128x64" in k for k in cache.keys())
    # every plan() / plan_batched() call landed in the cache exactly once
    assert cache.stats.inserts == n
    # a second warm pass is pure hits — the serve-time guarantee
    engine.warm_buckets(cfg, MoEArch(), [64])
    assert cache.stats.inserts == n


def test_warm_buckets_covers_planned_weight_redecision_keys():
    """The PlannedWeight apply path re-decides with candidates restricted to
    the weight's scheme — a differently-keyed plan. warm_buckets must
    pre-plan those restricted variants so the serve trace is a pure hit."""
    class Arch:
        d_model = 8192
        num_heads = 64
        num_kv_heads = 64
        resolved_head_dim = 128
        d_ff = 28672
        vocab_size = 0
        dtype = "bfloat16"

    cfg = FalconConfig(hardware="tpu_v5e")
    M = 8192
    engine.warm_buckets(cfg, Arch(), [M])
    cache = plan_cache.default_cache()
    # at this scale some precombined projection decision picks an LCMA...
    d_pre = falcon.plan(M, 8192, 28672, cfg, "bfloat16", precombined_b=True)
    assert d_pre.use_lcma
    # ...and the exact restricted-candidates re-decision _apply_planned runs
    # at serve time is already cached (no new miss)
    misses = cache.stats.misses
    falcon.plan(M, 8192, 28672,
                dataclasses.replace(cfg, mode="auto",
                                    candidates=(d_pre.algo.name,)),
                "bfloat16", precombined_b=True)
    assert cache.stats.misses == misses


def test_precombine_params_gates_moe_stack_on_grouped_decision():
    """Stacked MoE expert weights are lifted iff the *grouped* decision
    (plan_batched) accepts — not the per-element 2-D decision at m_hint.

    The flip regime (per-element declines, grouped accepts) needs the
    batched baseline compute-bound; scaled-up beta keeps the shapes small
    enough that the precombined B̃ this test materializes stays tiny."""
    hw = dataclasses.replace(TPU_V5E, name="moe_gate_test",
                             lcma_gemm_efficiency=0.35, beta=819e9 * 8)
    register_profile(hw)
    cfg = FalconConfig(hardware="moe_gate_test")
    E, C, d, ff = 16, 256, 512, 1792
    w3 = jnp.zeros((E, d, ff), jnp.bfloat16)
    with falcon.use(cfg):
        # grouped=True (what precombine_params passes for moe_* leaves):
        # the grouped decision accepts at m_hint//E = C rows per expert
        pw = engine.plan_weight(w3, m_hint=E * C, grouped=True)
        assert pw.precombined and pw.bt.ndim == 4
        # per-element gating at the same m_hint declines (the old behavior)
        pw2 = engine.plan_weight(w3, m_hint=C)
        assert not pw2.precombined
