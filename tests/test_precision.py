"""Paper §IV-F: the fused pipeline (f32 H on-chip) beats downcast-H numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg, codegen


def _rel_err(c, ref):
    return float(np.linalg.norm(c - ref) / np.linalg.norm(ref))


@pytest.mark.parametrize("name", ["strassen", "s444"])
def test_fused_beats_downcast_h(name, rng):
    """Fused keeps H in f32 and combines on-chip; the AlphaTensor-style
    baseline downcasts H to bf16 before Combine H. Fused error must be lower
    (statistically — averaged over trials, per the paper's ~17% claim)."""
    l = alg.get(name)
    M = K = N = l.m * 32
    errs_f, errs_d = [], []
    fused = codegen.generate(l, codegen.CodegenOptions(fused=True))
    down = codegen.generate(l, codegen.CodegenOptions(
        fused=False, downcast_h=True, gemm_backend="loop"))
    for t in range(6):
        r = np.random.default_rng(t)
        A64 = r.standard_normal((M, K)) * 4
        B64 = r.standard_normal((K, N)) * 4
        ref = A64 @ B64
        A = jnp.asarray(A64, jnp.bfloat16)
        B = jnp.asarray(B64, jnp.bfloat16)
        errs_f.append(_rel_err(np.asarray(fused.fn(A, B), np.float64), ref))
        errs_d.append(_rel_err(np.asarray(down.fn(A, B), np.float64), ref))
    assert np.mean(errs_f) < np.mean(errs_d), (errs_f, errs_d)


def test_lcma_error_within_budget(rng):
    """LCMA bf16 error stays within a small factor of standard bf16 GEMM."""
    l = alg.get("laderman")
    M = K = N = 96
    A64 = rng.standard_normal((M, K))
    B64 = rng.standard_normal((K, N))
    ref = A64 @ B64
    A = jnp.asarray(A64, jnp.bfloat16)
    B = jnp.asarray(B64, jnp.bfloat16)
    gemm_err = _rel_err(np.asarray(
        jnp.dot(A, B, preferred_element_type=jnp.float32), np.float64), ref)
    fused = codegen.generate(l)
    lcma_err = _rel_err(np.asarray(fused.fn(A, B), np.float64), ref)
    assert lcma_err < 6 * gemm_err  # literature: small constant-factor growth
