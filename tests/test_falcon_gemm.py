"""falcon_matmul public API: dispatch, batching, AD, precombined weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core.falcon_gemm import (FalconConfig, falcon_dense, falcon_matmul,
                                    matmul_with_precombined, plan,
                                    precombine_weights)


CFG_FORCE = FalconConfig(mode="strassen", backend="jnp")


def test_batched_and_dense(rng):
    A = jnp.asarray(rng.standard_normal((3, 20, 34)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((34, 18)), jnp.float32)
    got = jax.jit(lambda a, b: falcon_matmul(a, b, CFG_FORCE))(A, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)
    got2 = falcon_dense(A, B, CFG_FORCE)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got), atol=1e-5)


def test_gradients_match_standard(rng):
    A = jnp.asarray(rng.standard_normal((12, 10)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((10, 8)), jnp.float32)
    f_lcma = lambda a, b: jnp.sum(jnp.sin(falcon_matmul(a, b, CFG_FORCE)))
    f_std = lambda a, b: jnp.sum(jnp.sin(a @ b))
    ga, gb = jax.grad(f_lcma, (0, 1))(A, B)
    ga0, gb0 = jax.grad(f_std, (0, 1))(A, B)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb0), atol=1e-4)


def test_auto_mode_small_fallback():
    d = plan(128, 256, 256, FalconConfig())
    assert not d.use_lcma  # memory-bound small shape => standard GEMM


def test_auto_mode_large_selects_lcma():
    d = plan(16384, 5376, 21504, FalconConfig())
    assert d.use_lcma and d.speedup > 1.0


def test_shards_scale_decision():
    """Per-device shapes decide: a profitable global matmul sharded 16-ways
    may stop being profitable (and vice versa)."""
    big = plan(16384, 5376, 21504, FalconConfig())
    sharded = plan(16384, 5376, 21504, FalconConfig(shards=(16, 1, 16)))
    assert big.use_lcma
    assert big.speedup != sharded.speedup


def test_mode_gemm_disables():
    d = plan(65536, 65536, 65536, FalconConfig(mode="gemm"))
    assert not d.use_lcma


def test_precombined_weights_roundtrip(rng):
    l = alg.get("s223")
    W = jnp.asarray(rng.standard_normal((30, 27)), jnp.float32)  # pads to 30x...
    A = jnp.asarray(rng.standard_normal((2, 8, 30)), jnp.float32)
    bt = precombine_weights(W, l)
    got = matmul_with_precombined(A, bt, l, n_logical=27)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(W),
                               rtol=2e-4, atol=2e-4)


def test_pallas_backend_agrees(rng):
    cfg = FalconConfig(mode="laderman", backend="pallas_interpret")
    A = jnp.asarray(rng.standard_normal((27, 21)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((21, 33)), jnp.float32)
    got = falcon_matmul(A, B, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)
