"""falcon_matmul public API: dispatch, batching, AD, precombined weights."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core.falcon_gemm import (FalconConfig, falcon_dense, falcon_matmul,
                                    matmul_with_precombined, plan,
                                    precombine_weights)


CFG_FORCE = FalconConfig(mode="strassen", backend="jnp")


def test_batched_and_dense(rng):
    A = jnp.asarray(rng.standard_normal((3, 20, 34)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((34, 18)), jnp.float32)
    got = jax.jit(lambda a, b: falcon_matmul(a, b, CFG_FORCE))(A, B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)
    got2 = falcon_dense(A, B, CFG_FORCE)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got), atol=1e-5)


def test_gradients_match_standard(rng):
    A = jnp.asarray(rng.standard_normal((12, 10)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((10, 8)), jnp.float32)
    f_lcma = lambda a, b: jnp.sum(jnp.sin(falcon_matmul(a, b, CFG_FORCE)))
    f_std = lambda a, b: jnp.sum(jnp.sin(a @ b))
    ga, gb = jax.grad(f_lcma, (0, 1))(A, B)
    ga0, gb0 = jax.grad(f_std, (0, 1))(A, B)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb0), atol=1e-4)


def test_auto_mode_small_fallback():
    d = plan(128, 256, 256, FalconConfig())
    assert not d.use_lcma  # memory-bound small shape => standard GEMM


def test_auto_mode_large_selects_lcma():
    d = plan(16384, 5376, 21504, FalconConfig())
    assert d.use_lcma and d.speedup > 1.0


def test_shards_scale_decision():
    """Per-device shapes decide: a profitable global matmul sharded 16-ways
    may stop being profitable (and vice versa)."""
    big = plan(16384, 5376, 21504, FalconConfig())
    sharded = plan(16384, 5376, 21504, FalconConfig(shards=(16, 1, 16)))
    assert big.use_lcma
    assert big.speedup != sharded.speedup


def test_mode_gemm_disables():
    d = plan(65536, 65536, 65536, FalconConfig(mode="gemm"))
    assert not d.use_lcma


def test_precombined_weights_roundtrip(rng):
    l = alg.get("s223")
    W = jnp.asarray(rng.standard_normal((30, 27)), jnp.float32)  # pads to 30x...
    A = jnp.asarray(rng.standard_normal((2, 8, 30)), jnp.float32)
    bt = precombine_weights(W, l)
    got = matmul_with_precombined(A, bt, l, n_logical=27)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(W),
                               rtol=2e-4, atol=2e-4)


def test_pallas_backend_agrees(rng):
    cfg = FalconConfig(mode="laderman", backend="pallas_interpret")
    A = jnp.asarray(rng.standard_normal((27, 21)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((21, 33)), jnp.float32)
    got = falcon_matmul(A, B, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)


def test_precombined_shape_mismatch_raises(rng):
    """Operand validation must survive ``python -O`` (was a bare assert)."""
    import pytest

    l = alg.get("strassen")
    W = jnp.asarray(rng.standard_normal((30, 27)), jnp.float32)
    bt = precombine_weights(W, l)
    A = jnp.asarray(rng.standard_normal((4, 40)), jnp.float32)  # wrong K
    with pytest.raises(ValueError, match="does not match precombined"):
        matmul_with_precombined(A, bt, l, n_logical=27)


def test_matmul_shape_mismatch_raises(rng):
    import pytest

    from repro.core import engine
    with pytest.raises(ValueError, match="contracting dims differ"):
        engine.matmul(jnp.ones((4, 8)), jnp.ones((9, 4)), CFG_FORCE)


def test_warned_shards_is_bounded():
    """The once-per-key warning dedup must not leak in long-running serve
    processes: one entry per distinct shape x shards, capped."""
    from repro.core import falcon_gemm as fg

    fg._warned_shards.clear()
    cfg = FalconConfig(mode="gemm", shards=(3, 1, 1))
    for i in range(fg._WARNED_SHARDS_MAX + 64):
        plan(3 * i + 1, 16, 16, cfg)     # never divisible by 3 => warns
    assert len(fg._warned_shards) <= fg._WARNED_SHARDS_MAX
    # most-recent keys are retained, oldest evicted
    assert (3 * (fg._WARNED_SHARDS_MAX + 63) + 1, 16, 16, (3, 1, 1)) \
        in fg._warned_shards
