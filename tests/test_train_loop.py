"""Fault-tolerant loop: restart-from-checkpoint, retries, preemption, stragglers."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.data import DataConfig, SyntheticLMData
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.train import (FaultInjector, TrainLoop, TrainLoopConfig,
                         make_train_step)


@pytest.fixture(scope="module")
def setup():
    cfg = registry.smoke_config("granite_3_2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(params, opt_cfg)
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4))
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=40, warmup=2))
    return cfg, params, opt_state, data, step


def test_recovers_from_injected_faults(tmp_path, setup):
    _, params, opt_state, data, step = setup
    loop = TrainLoop(
        TrainLoopConfig(total_steps=20, checkpoint_every=5,
                        checkpoint_dir=str(tmp_path), log_every=100),
        step, data, params, opt_state,
        fault_injector=FaultInjector({7: 1, 13: 2}))
    out = loop.run()
    assert out["final_step"] == 20
    assert out["restarts"] == 3
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]  # learning on Markov synthetic data


def test_aborts_after_max_retries(tmp_path, setup):
    _, params, opt_state, data, step = setup
    loop = TrainLoop(
        TrainLoopConfig(total_steps=10, checkpoint_every=100,
                        checkpoint_dir=str(tmp_path), max_retries_per_step=2,
                        log_every=100),
        step, data, params, opt_state,
        fault_injector=FaultInjector({3: 99}))
    with pytest.raises(RuntimeError, match="aborting"):
        loop.run()


def test_preemption_checkpoints_and_resumes(tmp_path, setup):
    _, params, opt_state, data, step = setup
    loop = TrainLoop(
        TrainLoopConfig(total_steps=50, checkpoint_every=1000,
                        checkpoint_dir=str(tmp_path), log_every=100),
        step, data, params, opt_state)
    orig = loop.train_step

    def step_and_preempt(p, o, b, s):
        if s == 6:
            loop.preempt()
        return orig(p, o, b, s)

    loop.train_step = step_and_preempt
    out = loop.run()
    assert out["final_step"] < 50  # exited early

    # resume: a fresh loop restores the preemption checkpoint and finishes
    loop2 = TrainLoop(
        TrainLoopConfig(total_steps=10, checkpoint_every=1000,
                        checkpoint_dir=str(tmp_path), log_every=100),
        orig, data, params, opt_state)
    start = loop2._restore()
    assert start == out["final_step"]
    out2 = loop2.run(start_step=start)
    assert out2["final_step"] == 10


def test_straggler_detection(tmp_path, setup):
    import time
    _, params, opt_state, data, step = setup
    loop = TrainLoop(
        TrainLoopConfig(total_steps=12, checkpoint_every=1000,
                        checkpoint_dir=str(tmp_path), straggler_factor=2.5,
                        log_every=100),
        step, data, params, opt_state)
    orig = loop.train_step

    def slow_step(p, o, b, s):
        if s == 8:
            time.sleep(1.0)  # simulated slow host
        return orig(p, o, b, s)

    loop.train_step = slow_step
    out = loop.run()
    assert out["stragglers"] >= 1


def test_data_pipeline_determinism():
    d1 = SyntheticLMData(DataConfig(vocab_size=100, seq_len=16, global_batch=2))
    d2 = SyntheticLMData(DataConfig(vocab_size=100, seq_len=16, global_batch=2))
    b1, b2 = d1.batch(11), d2.batch(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(12)["tokens"], b1["tokens"])
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
