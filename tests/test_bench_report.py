"""Benchmark driver + machine-readable report: failure isolation and the
regression gate (the CI bench-smoke contract)."""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:                       # benchmarks/ is a namespace pkg
    sys.path.insert(0, REPO)

from benchmarks import report as bench_report  # noqa: E402
from benchmarks import run as bench_run        # noqa: E402


def _report(metrics, failures=()):
    return {"version": 1, "failures": list(failures), "metrics": metrics}


def _m(value, higher=True, tolerance=None):
    d = {"value": value, "unit": "x", "higher_is_better": higher}
    if tolerance is not None:
        d["tolerance"] = tolerance
    return d


def test_check_regressions_green_and_red():
    base = _report({"a.speed": _m(100.0), "b.err": _m(0.01, higher=False)})
    ok = _report({"a.speed": _m(85.0), "b.err": _m(0.011, higher=False)})
    assert bench_report.check_regressions(ok, base) == []
    bad = _report({"a.speed": _m(70.0), "b.err": _m(0.02, higher=False)})
    problems = bench_report.check_regressions(bad, base)
    assert len(problems) == 2
    assert any("a.speed" in p for p in problems)
    assert any("b.err" in p for p in problems)


def test_check_regressions_per_metric_tolerance_and_missing():
    base = _report({"a.speed": _m(100.0, tolerance=0.5), "gone": _m(1.0)})
    new = _report({"a.speed": _m(55.0)})       # within the widened band
    problems = bench_report.check_regressions(new, base)
    assert problems == ["gone: missing from new report (baseline 1)"]


def test_check_regressions_flags_section_failures():
    base = _report({})
    new = _report({}, failures=["serve"])
    problems = bench_report.check_regressions(new, base)
    assert problems and "serve" in problems[0]


def test_committed_baseline_parses_against_schema():
    path = os.path.join(REPO, "benchmarks", "baseline_cpu.json")
    doc = json.load(open(path))
    assert doc["version"] == bench_report.REPORT_VERSION
    assert doc["metrics"], "baseline must gate at least one metric"
    for name, m in doc["metrics"].items():
        assert isinstance(m["value"], (int, float)), name
        assert isinstance(m["higher_is_better"], bool), name
    # a report identical to the baseline is green by construction
    assert bench_report.check_regressions(doc, doc) == []


def test_run_sections_isolate_failures(monkeypatch, tmp_path, capsys):
    """One exploding section must not kill the others — but must fail the
    process and be recorded in the JSON report (the old driver exited 0)."""
    calls = []

    def fake_sections(quick):
        return [
            ("boom", "exploding section", lambda: (_ for _ in ()).throw(
                RuntimeError("mid-benchmark crash"))),
            ("serve", "working section",
             lambda: calls.append("ran") or [
                 {"requests": 1, "finished": 1, "warm_plans": 0,
                  "warm_shapes": 0, "warm_s": 0.0, "prefill_steps": 1,
                  "decode_steps": 1, "tokens_per_s": 10.0,
                  "decode_tokens_per_s": 5.0, "bucket_hit_rate": 1.0,
                  "padding_waste": 0.1, "plan_cache_hit_rate": 0.9,
                  "plan_cache_entries": 3}]),
        ]

    monkeypatch.setattr(bench_run, "_sections", fake_sections)
    out = str(tmp_path / "bench.json")
    rc = bench_run.main(["--quick", "--json", out])
    assert rc == 1
    assert calls == ["ran"], "later sections must still run"
    doc = json.load(open(out))
    assert doc["failures"] == ["boom"]
    assert doc["metrics"]["serve.tokens_per_s"]["value"] == 10.0
    assert "FAILED section 'boom'" in capsys.readouterr().err


def test_run_exit_zero_when_clean(monkeypatch, tmp_path):
    monkeypatch.setattr(bench_run, "_sections",
                        lambda quick: [("noop", "noop", lambda: [])])
    rc = bench_run.main(["--json", str(tmp_path / "b.json")])
    assert rc == 0
