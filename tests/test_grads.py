"""Planned custom-VJP autodiff: falcon gradients vs ``lax`` baselines.

The dispatch core carries a ``jax.custom_vjp`` whose backward computes
``dA = g Bᵀ`` and ``dB = Aᵀ g`` as independently planned falcon contractions
(see ``core/engine.py``). These tests pin the contract:

  * ``jax.grad`` of a falcon-dispatched loss is allclose to the eager ``lax``
    baseline for every candidate scheme, across backends (jnp +
    pallas_interpret), dtypes, and batched/transposed ``dot_general`` forms;
  * gradients flow through ``PlannedWeight`` (raw-weight cotangent planned;
    B̃ cotangent exact via the rotated rank-R scheme when the weight was
    dropped);
  * one jitted train step in auto mode leaves plan-cache entries for both
    backward shapes of each planned layer;
  * a planned train step's loss trajectory matches eager training.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.core import algorithms as alg, plan_cache
from repro.core.falcon_gemm import FalconConfig, matmul_with_precombined
from repro.core.hardware import HardwareProfile, register_profile
from repro.optim import AdamWConfig, adamw_init, adamw_update

# Enormous bandwidth makes every probe shape compute-bound, so auto mode
# picks LCMAs at test-sized shapes (the Decision Module otherwise declines
# everything small via the Eq. 8 memory-bound guard).
LCMA_FRIENDLY = register_profile(HardwareProfile(
    name="lcma_friendly_test", flops_mul=1e12, flops_add=1e12, beta=1e15))

FORCE = FalconConfig(mode="strassen", backend="jnp")

TOL = {"float32": dict(rtol=3e-4, atol=3e-4),
       "bfloat16": dict(rtol=8e-2, atol=8e-2)}


def _assert_grads_match(f_falcon, f_ref, args, dtype="float32"):
    got = jax.grad(f_falcon, tuple(range(len(args))))(*args)
    want = jax.grad(f_ref, tuple(range(len(args))))(*args)
    for g, w in zip(got, want):
        g = np.asarray(g, np.float32)
        w = np.asarray(w, np.float32)
        if dtype == "bfloat16":
            # bf16 grads carry order-of-summation noise at the combine
            # stages; compare against the gradient's scale, not elementwise
            scale = max(float(np.abs(w).max()), 1.0)
            np.testing.assert_allclose(g, w, rtol=0.1, atol=0.05 * scale)
        else:
            np.testing.assert_allclose(g, w, **TOL[dtype])


# ---------------------------------------------------------------------------
# Every candidate scheme: grads allclose to the lax baseline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", [l.name for l in alg.candidates()])
def test_grads_match_lax_for_every_candidate_scheme(scheme, rng):
    cfg = FalconConfig(mode=scheme, backend="jnp")
    # deliberately grid-non-divisible shapes: the padding path must
    # differentiate correctly too
    A = jnp.asarray(rng.standard_normal((13, 11)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((11, 9)), jnp.float32)
    _assert_grads_match(
        lambda a, b: jnp.sum(jnp.sin(falcon.matmul(a, b, cfg=cfg))),
        lambda a, b: jnp.sum(jnp.sin(a @ b)),
        (A, B))


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_grads_across_backends_and_dtypes(backend, dtype, rng):
    """The Pallas pipeline has no autodiff transpose of its own — the planned
    VJP is what makes backend='pallas' trainable at all."""
    cfg = FalconConfig(mode="laderman", backend=backend)
    A = jnp.asarray(rng.standard_normal((27, 21)), dtype)
    B = jnp.asarray(rng.standard_normal((21, 24)), dtype)
    _assert_grads_match(
        lambda a, b: jnp.sum(falcon.matmul(a, b, cfg=cfg) ** 2),
        lambda a, b: jnp.sum((a @ b).astype(jnp.float32) ** 2).astype(
            jnp.float32),
        (A, B), dtype=dtype)


# ---------------------------------------------------------------------------
# dot_general forms: batched / transposed contractions
# ---------------------------------------------------------------------------

DN_CASES = [
    # (a_shape, b_shape, dimension_numbers)
    ((20, 16), (16, 12), (((1,), (0,)), ((), ()))),          # canonical dense
    ((16, 20), (16, 12), (((0,), (0,)), ((), ()))),          # transposed lhs
    ((20, 16), (12, 16), (((1,), (1,)), ((), ()))),          # transposed rhs
    ((2, 3, 16, 12), (2, 3, 12, 8),
     (((3,), (2,)), ((0, 1), (0, 1)))),                      # doubly batched
    ((3, 10, 16), (3, 16, 8), (((2,), (1,)), ((0,), (0,)))),  # single batch
]


@pytest.mark.parametrize("a_shape,b_shape,dn", DN_CASES)
def test_dot_general_grads_match_lax(a_shape, b_shape, dn, rng):
    a = jnp.asarray(rng.standard_normal(a_shape), jnp.float32)
    b = jnp.asarray(rng.standard_normal(b_shape), jnp.float32)
    _assert_grads_match(
        lambda x, y: jnp.sum(jnp.sin(falcon.dot_general(x, y, dn, cfg=FORCE))),
        lambda x, y: jnp.sum(jnp.sin(jax.lax.dot_general(x, y, dn))),
        (a, b))


def test_attention_einsum_grads_match(rng):
    """The attention-score einsum form layers.py actually dispatches."""
    q = jnp.asarray(rng.standard_normal((2, 8, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 8, 4, 16)), jnp.float32)
    _assert_grads_match(
        lambda x, y: jnp.sum(
            falcon.einsum("bqhd,bkhd->bhqk", x, y, cfg=FORCE) ** 2),
        lambda x, y: jnp.sum(jnp.einsum("bqhd,bkhd->bhqk", x, y) ** 2),
        (q, k))


def test_grads_under_jit_and_auto_mode(rng):
    cfg = FalconConfig(mode="auto", hardware="lcma_friendly_test",
                       backend="jnp")
    A = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((32, 48)), jnp.float32)
    f = jax.jit(jax.grad(lambda a: jnp.sum(falcon.matmul(a, B, cfg=cfg) ** 2)))
    want = jax.grad(lambda a: jnp.sum((a @ B) ** 2))(A)
    np.testing.assert_allclose(np.asarray(f(A)), np.asarray(want),
                               **TOL["float32"])


def test_planned_vjp_false_restores_differentiate_through(rng):
    """Escape hatch: planned_vjp=False differentiates through the combine
    graph (old semantics) and still matches the baseline."""
    cfg = dataclasses.replace(FORCE, planned_vjp=False)
    A = jnp.asarray(rng.standard_normal((12, 10)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((10, 8)), jnp.float32)
    _assert_grads_match(
        lambda a, b: jnp.sum(jnp.sin(falcon.matmul(a, b, cfg=cfg))),
        lambda a, b: jnp.sum(jnp.sin(a @ b)),
        (A, B))


# ---------------------------------------------------------------------------
# PlannedWeight training
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
def test_planned_weight_raw_grad_matches_eager(backend, rng):
    cfg = dataclasses.replace(FORCE, backend=backend)
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=cfg, m_hint=256)
    assert pw.precombined
    gpw = jax.jit(jax.grad(
        lambda p: jnp.sum(falcon.dense(x, p, cfg=cfg) ** 2)))(pw)
    want = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(W)
    np.testing.assert_allclose(np.asarray(gpw.w), np.asarray(want),
                               **TOL["float32"])
    # the B̃ leaf carries a zero cotangent: the optimizer trains w, and
    # refresh_planned_params re-derives B̃
    assert float(jnp.max(jnp.abs(gpw.bt))) == 0.0


def test_planned_weight_input_grad_matches_eager(rng):
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE, m_hint=256)
    _assert_grads_match(
        lambda xx: jnp.sum(falcon.dense(xx, pw, cfg=FORCE) ** 2),
        lambda xx: jnp.sum((xx @ W) ** 2),
        (x,))


def test_planned_weight_dropped_raw_trains_bt_directly(rng):
    """keep_weight=False: B̃ is the parameter; its cotangent comes from the
    rotated rank-R scheme and must equal autodiff of the generated path."""
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE, keep_weight=False)
    assert pw.w is None and pw.precombined
    gbt = jax.grad(
        lambda p: jnp.sum(falcon.dense(x, p, cfg=FORCE) ** 2))(pw).bt
    ref_cfg = dataclasses.replace(FORCE, planned_vjp=False)
    want = jax.grad(lambda bt: jnp.sum(matmul_with_precombined(
        x, bt, pw.lcma, pw.n, ref_cfg) ** 2))(pw.bt)
    np.testing.assert_allclose(np.asarray(gbt), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_refresh_planned_params_recombines(rng):
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    pw = falcon.plan_weight(W, cfg=FORCE, m_hint=256)
    moved = dataclasses.replace(pw, w=W * 2.0)      # optimizer moved w; B̃ stale
    fresh = falcon.refresh_planned_params({"w_q": moved})["w_q"]
    np.testing.assert_allclose(np.asarray(fresh.bt), np.asarray(pw.bt) * 2.0,
                               rtol=1e-6, atol=1e-6)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    got = falcon.dense(x, fresh, cfg=FORCE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ (W * 2.0)),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Train steps: backward plans in the cache + trajectory vs eager
# ---------------------------------------------------------------------------

def test_jitted_train_step_populates_backward_plans(rng):
    """Acceptance: after one jitted train step in auto mode, the plan cache
    holds entries for BOTH backward shapes of each planned layer."""
    plan_cache.reset()
    try:
        cfg = FalconConfig(mode="auto", hardware="lcma_friendly_test",
                           backend="jnp")
        x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
        params = {"w1": jnp.asarray(rng.standard_normal((32, 48)), jnp.float32),
                  "w2": jnp.asarray(rng.standard_normal((48, 16)), jnp.float32)}

        def loss(p):
            h = jax.nn.tanh(falcon.dense(x, p["w1"], cfg=cfg))
            out = falcon.dense(h, p["w2"], cfg=cfg)
            return jnp.mean((out - y) ** 2)

        @jax.jit
        def train_step(p):
            val, g = jax.value_and_grad(loss)(p)
            return jax.tree.map(lambda w, gw: w - 0.01 * gw, p, g), val

        params, val = train_step(params)
        assert np.isfinite(float(val))
        cache = plan_cache.default_cache()
        for (M, K, N) in [(64, 32, 48), (64, 48, 16)]:     # layer fwd shapes
            assert cache.has_shape(M, K, N), (M, K, N)
            for (Mb, Kb, Nb) in falcon.backward_shapes(M, K, N):
                assert cache.has_shape(Mb, Kb, Nb), (M, K, N, "bwd", Mb, Kb, Nb)
    finally:
        plan_cache.reset()


def test_warm_train_covers_the_whole_step():
    """steps.warm_train pre-plans every fwd+bwd triple a train step traces."""
    from repro.configs import registry
    from repro.train.steps import warm_train

    plan_cache.reset()
    try:
        cfg = registry.smoke_config("granite_3_2b")
        with falcon.use(FalconConfig(mode="auto",
                                     hardware="lcma_friendly_test")):
            n = warm_train(cfg, batch=2, seq=16)
        assert n > 0
        cache = plan_cache.default_cache()
        M = 2 * 16
        for (K, N) in falcon.dense_projection_shapes(cfg):
            for (Mb, Kb, Nb) in falcon.backward_shapes(M, K, N):
                assert cache.has_shape(Mb, Kb, Nb), (K, N)
    finally:
        plan_cache.reset()


def _sgd_trajectory(make_loss, params0, steps=5, lr=0.05, refresh=False):
    params = params0
    losses = []
    for _ in range(steps):
        val, g = jax.value_and_grad(make_loss)(params)
        params = jax.tree.map(lambda w, gw: w - lr * gw, params, g)
        if refresh:
            params = falcon.refresh_planned_params(params)
        losses.append(float(val))
    return losses, params


def test_planned_weight_training_trajectory_matches_eager(rng):
    """Loss trajectory of SGD through a PlannedWeight (planned VJP + B̃
    refresh each step) matches raw-weight eager training."""
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((32, 48)), jnp.float32)

    eager_losses, eager_p = _sgd_trajectory(
        lambda p: jnp.mean((x @ p["w"] - y) ** 2), {"w": W})

    pw = falcon.plan_weight(W, cfg=FORCE, m_hint=64)
    assert pw.precombined
    planned_losses, planned_p = _sgd_trajectory(
        lambda p: jnp.mean((falcon.dense(x, p["w"], cfg=FORCE) - y) ** 2),
        {"w": pw}, refresh=True)

    np.testing.assert_allclose(planned_losses, eager_losses,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(planned_p["w"].w),
                               np.asarray(eager_p["w"]),
                               rtol=1e-4, atol=1e-5)


def test_model_train_step_trajectory_matches_eager(rng):
    """Full train step (model fwd + planned custom-VJP bwd + AdamW) tracks
    the eager (falcon-disabled) loss trajectory."""
    from repro.configs import registry
    from repro.models import model as M
    from repro.train.steps import make_train_step

    cfg_falcon = dataclasses.replace(registry.smoke_config("granite_3_2b"),
                                     falcon_mode="strassen")
    cfg_eager = dataclasses.replace(cfg_falcon, use_falcon=False)
    params = M.init_params(cfg_falcon, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    tokens = jnp.asarray(rng.integers(0, cfg_falcon.vocab_size, (2, 16)),
                         jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    def run(cfg):
        step = jax.jit(make_train_step(cfg, opt_cfg))
        p, o = params, adamw_init(params, opt_cfg)
        losses = []
        for i in range(3):
            p, o, m = step(p, o, batch, jnp.asarray(i))
            losses.append(float(m["loss"]))
        return losses

    np.testing.assert_allclose(run(cfg_falcon), run(cfg_eager),
                               rtol=5e-3, atol=5e-4)


def test_adamw_steps_planned_weight_params(rng):
    """PlannedWeight leaves ride through adamw_update + refresh: the planned
    layer's weight actually moves and the loss decreases."""
    W = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    y = np.asarray(x) @ np.asarray(rng.standard_normal((64, 48)))
    y = jnp.asarray(y, jnp.float32)
    params = {"w_q": falcon.plan_weight(W, cfg=FORCE, m_hint=64)}
    opt_cfg = AdamWConfig(lr=3e-2, weight_decay=0.0)
    state = adamw_init(params, opt_cfg)

    def loss(p):
        return jnp.mean((falcon.dense(x, p["w_q"], cfg=FORCE) - y) ** 2)

    first = None
    for _ in range(15):
        val, g = jax.value_and_grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, opt_cfg)
        params = falcon.refresh_planned_params(params)
        first = val if first is None else first
    assert isinstance(params["w_q"], falcon.PlannedWeight)
    assert float(loss(params)) < 0.6 * float(first)
