"""End-to-end system tests: train loop e2e, serve e2e, dry-run integration."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, SRC
from repro.configs import registry
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill_step


def test_end_to_end_training_learns():
    """~60 steps on synthetic Markov data must reduce loss materially."""
    from repro.data import DataConfig, SyntheticLMData
    from repro.optim import AdamWConfig, adamw_init
    from repro.train import make_train_step
    cfg = registry.smoke_config("granite_3_2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    oc = AdamWConfig(lr=2e-3)
    ost = adamw_init(params, oc)
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8))
    step = jax.jit(make_train_step(cfg, oc, total_steps=60, warmup=5))
    first = last = None
    for s in range(60):
        params, ost, m = step(params, ost, data.batch(s), s)
        if s == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.1, (first, last)


@pytest.mark.parametrize("arch", ["granite_3_2b", "mamba2_370m", "hymba_1_5b",
                                  "dbrx_132b", "musicgen_large"])
def test_generation_pipeline(arch, rng):
    """prefill -> N decode steps runs and produces finite logits."""
    cfg = registry.smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, gen = 2, 8, 4
    shape = (B, S, cfg.num_codebooks) if cfg.frontend == "audio_codebooks" else (B, S)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, max_len=S + gen))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, tokens)
    for i in range(gen):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if cfg.frontend == "audio_codebooks":
            tok = nxt[:, None]
            if tok.ndim == 2:
                tok = jnp.tile(tok[..., None], (1, 1, cfg.num_codebooks))
        else:
            tok = nxt[:, None]
        logits, cache = decode(params, cache, tok, S + i)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """The real dry-run entrypoint works for one (arch x shape x mesh) cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "granite_3_2b",
         "--shape", "decode_32k", "--mesh", "single", "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "granite_3_2b__decode_32k__single.json"))
    assert rec["status"] == "ok"
    assert rec["roofline"]["hlo_flops"] > 0
    assert rec["analytic"]["t_compute"] > 0


def test_launch_train_cli_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2_370m",
         "--steps", "3", "--batch", "2", "--seq", "32",
         "--checkpoint-dir", "/tmp/repro_cli_test"],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "done: 3 steps" in out.stdout
