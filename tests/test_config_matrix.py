"""Per-config smoke matrix: every registry arch vs the workload registry.

"Works on granite" must not stand in for "works": every architecture in
``configs/registry.py`` (mamba2/SSD, MoE, pixtral, musicgen, kimi_k2, ...)
contributes its own projection shapes — attention/MLP/SSM/vocab, plus the
grouped MoE expert shapes — and each is pushed through the planned
``falcon.dot_general`` forward AND backward at a tiny M, with the scheme
forced so the LCMA path (not the GEMM fallback) is what gets exercised.

The registry-coverage test is the contract the warm surfaces rely on:
``contraction_set`` must enumerate every plan-cache key a full fwd+bwd
trace of the model actually creates — an unwarmable contraction escaping
the registry is a bug here before it is a serve-time cold miss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.configs import registry
from repro.core import plan_cache, workloads
from repro.models import model as M
from repro.models import ssd as SSD

# Forced strassen + jnp backend: tiny shapes would otherwise always take the
# plain-GEMM fallback and the matrix would prove nothing about the combines.
FCFG = falcon.FalconConfig(mode="strassen", backend="jnp", use_plan_cache=False)
DN = (((1,), (0,)), ((), ()))          # (M, K) @ (K, N)


def _shapes_for(cfg, cap: int = 256):
    """A few representative (K, N) projections, dims capped for CPU speed."""
    shapes = falcon.dense_projection_shapes(cfg)
    return [(min(k, cap), min(n, cap)) for (k, n) in shapes[:4]]


@pytest.mark.parametrize("arch", registry.list_archs())
def test_dot_general_fwd_bwd_per_config(arch, rng):
    cfg = registry.smoke_config(arch)
    with falcon.use(FCFG):
        for (K, N) in _shapes_for(cfg):
            x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
            w = jnp.asarray(rng.standard_normal((K, N)) * 0.1, jnp.float32)
            y = falcon.dot_general(x, w, DN)
            ref = np.asarray(x) @ np.asarray(w)
            np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)

            gx, gw = jax.grad(
                lambda a, b: jnp.sum(falcon.dot_general(a, b, DN) ** 2),
                argnums=(0, 1))(x, w)
            gx0, gw0 = jax.grad(
                lambda a, b: jnp.sum((a @ b) ** 2), argnums=(0, 1))(x, w)
            np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                                       atol=5e-2, rtol=1e-3)
            np.testing.assert_allclose(np.asarray(gw), np.asarray(gw0),
                                       atol=5e-2, rtol=1e-3)


@pytest.mark.parametrize(
    "arch", [a for a in registry.list_archs()
             if getattr(registry.smoke_config(a), "num_experts", 0)])
def test_grouped_expert_matmul_per_moe_config(arch, rng):
    """MoE archs additionally smoke their grouped E x (C, K) @ (K, N) path."""
    cfg = registry.smoke_config(arch)
    (E, C, K, N) = falcon.grouped_moe_shapes(cfg, 16)[0]
    E, C, K, N = min(E, 4), min(C, 16), min(K, 128), min(N, 128)
    x = jnp.asarray(rng.standard_normal((E, C, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)) * 0.1, jnp.float32)
    with falcon.use(FCFG):
        y = falcon.grouped_matmul(x, w)
        g = jax.grad(lambda a: jnp.sum(falcon.grouped_matmul(a, w) ** 2))(x)
    ref = np.einsum("eck,ekn->ecn", np.asarray(x), np.asarray(w))
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)
    g0 = jax.grad(lambda a: jnp.sum(jnp.einsum("eck,ekn->ecn", a, w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               atol=5e-2, rtol=1e-3)


# ---------------------------------------------------------------------------
# Workload-registry coverage: no plan-cache key escapes contraction_set
# ---------------------------------------------------------------------------

def _smoke_batch(cfg, rng, B=2, S=16):
    if cfg.frontend == "audio_codebooks":
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S, cfg.num_codebooks)),
            jnp.int32)
        return {"tokens": toks, "labels": toks}
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.list_archs())
def test_registry_covers_traced_plan_keys(arch, rng):
    """contraction_set covers every plan-cache key a fwd+bwd trace creates.

    This is the registry's core contract: every shape the Decision Module is
    asked to plan during a real train trace must be enumerable from the
    config alone — otherwise warm surfaces (warm_buckets / warm_train /
    ServeEngine.warm / tools.tune) could never guarantee a hot cache.
    """
    cfg = registry.smoke_config(arch)
    B, S = 2, 16
    plan_cache.reset()
    try:
        batch = _smoke_batch(cfg, rng, B, S)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        with falcon.use(falcon.FalconConfig(hardware="tpu_v5e",
                                            use_plan_cache=True)):
            jax.grad(lambda p: M.lm_loss(p, cfg, batch)[0])(params)
        traced = {workloads.shape_token(k)
                  for k in plan_cache.default_cache().keys()}
        allowed = {c.key_shape()
                   for c in falcon.resolve_contractions(cfg, B, S, train=True)}
        assert traced, f"{arch}: trace created no plan-cache keys"
        extra = traced - allowed
        assert not extra, (
            f"{arch}: traced contractions missing from the registry: "
            f"{sorted(extra)}")
        if cfg.family in ("ssm", "hybrid"):
            # the SSD chunk contractions are Decision-routed: grouped keys
            # (gGxMxKxN) from the scan must show up in the trace
            assert any(t.startswith("g") for t in traced), (
                f"{arch}: no grouped SSD contraction was planned")
    finally:
        plan_cache.reset()


# ---------------------------------------------------------------------------
# SSD: falcon-routed chunk contractions vs the plain-einsum reference
# ---------------------------------------------------------------------------

def _ssd_scan_einsum_reference(x, dt, A, B_, C_, chunk, init_state=None):
    """The original 3-operand jnp.einsum SSD formulation (pre falcon routing)."""
    Bb, L, H, Pd = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Lp = -(-L // chunk) * chunk
    nc = Lp // chunk
    xdt = x * dt[..., None]
    a = (dt * (-jnp.exp(A))[None, None, :]).astype(jnp.float32)

    def r(t):
        return t.reshape((Bb, nc, chunk) + t.shape[2:])

    xc, ac = r(xdt).astype(jnp.float32), r(a)
    Bh = jnp.repeat(r(B_), rep, axis=3).astype(jnp.float32)
    Ch = jnp.repeat(r(C_), rep, axis=3).astype(jnp.float32)
    ac_t = ac.transpose(0, 1, 3, 2)
    Lmat = jnp.exp(SSD._segsum(ac_t))
    scores = jnp.einsum("bnihs,bnjhs->bnhij", Ch, Bh)
    y_diag = jnp.einsum("bnhij,bnhij,bnjhp->bnihp", scores, Lmat, xc)
    decay_to_end = jnp.exp(jnp.sum(ac_t, -1, keepdims=True)
                           - jnp.cumsum(ac_t, -1))
    states = jnp.einsum("bnhj,bnjhs,bnjhp->bnhsp", decay_to_end, Bh, xc)
    chunk_decay = jnp.exp(jnp.sum(ac_t, axis=-1))
    s0 = (jnp.zeros((Bb, H, N, Pd), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(s, inp):
        st, dk = inp
        return s * dk[..., None, None] + st, s

    s_final, prev = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)
    dfs = jnp.exp(jnp.cumsum(ac_t, -1))
    y_off = jnp.einsum("bnihs,bnhsp,bnhi->bnihp", Ch, prev, dfs)
    y = (y_diag + y_off).reshape(Bb, Lp, H, Pd)[:, :L].astype(x.dtype)
    return y, s_final.astype(x.dtype)


def _ssd_inputs(rng, B=2, L=24, H=4, P=16, G=2, N=16):
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, L, H))) * 0.1, jnp.float32)
    A = jnp.asarray(np.abs(rng.standard_normal((H,))) * 0.5, jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, N, P)), jnp.float32)
    return x, dt, A, B_, C_, s0


def test_ssd_scan_falcon_routed_matches_einsum_reference(rng):
    """The decomposed 2-operand falcon.einsum scan == the 3-operand original,
    with the LCMA scheme FORCED so the combines (not a GEMM fallback) run."""
    x, dt, A, B_, C_, s0 = _ssd_inputs(rng)
    with falcon.use(FCFG):
        y, sf = SSD.ssd_scan(x, dt, A, B_, C_, chunk=8, init_state=s0)
    y_ref, s_ref = _ssd_scan_einsum_reference(x, dt, A, B_, C_, 8,
                                              init_state=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s_ref),
                               atol=2e-3, rtol=1e-3)


def test_ssd_decode_step_falcon_routed_matches_reference(rng):
    """Decode recurrence (outer-product state update + readout) through
    falcon.einsum == the plain jnp formulation."""
    x, dt, A, B_, C_, s0 = _ssd_inputs(rng)
    xd, dtd, Bd, Cd = x[:, :1], dt[:, :1], B_[:, :1], C_[:, :1]
    with falcon.use(FCFG):
        y, ns = SSD.ssd_decode_step(xd, dtd, A, Bd, Cd, s0)
    H, G = x.shape[2], B_.shape[2]
    a = jnp.exp(dtd[:, 0] * (-jnp.exp(A))[None, :])
    Bh = jnp.repeat(Bd[:, 0], H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cd[:, 0], H // G, axis=1).astype(jnp.float32)
    xdt = (xd[:, 0] * dtd[:, 0, :, None]).astype(jnp.float32)
    ns_ref = (s0.astype(jnp.float32) * a[..., None, None]
              + jnp.einsum("bhs,bhp->bhsp", Bh, xdt))
    y_ref = jnp.einsum("bhs,bhsp->bhp", Ch, ns_ref)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(y_ref),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(ns), np.asarray(ns_ref),
                               atol=2e-3, rtol=1e-3)
