"""Per-config smoke matrix: falcon.dot_general fwd+bwd for every registry arch.

"Works on granite" must not stand in for "works": every architecture in
``configs/registry.py`` (mamba2/SSD, MoE, pixtral, musicgen, kimi_k2, ...)
contributes its own projection shapes — attention/MLP/SSM/vocab, plus the
grouped MoE expert shapes — and each is pushed through the planned
``falcon.dot_general`` forward AND backward at a tiny M, with the scheme
forced so the LCMA path (not the GEMM fallback) is what gets exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as falcon
from repro.configs import registry
from repro.core import engine as core_engine

# Forced strassen + jnp backend: tiny shapes would otherwise always take the
# plain-GEMM fallback and the matrix would prove nothing about the combines.
FCFG = falcon.FalconConfig(mode="strassen", backend="jnp", use_plan_cache=False)
DN = (((1,), (0,)), ((), ()))          # (M, K) @ (K, N)


def _shapes_for(cfg, cap: int = 256):
    """A few representative (K, N) projections, dims capped for CPU speed."""
    shapes = core_engine.projection_shapes(cfg)
    return [(min(k, cap), min(n, cap)) for (k, n) in shapes[:4]]


@pytest.mark.parametrize("arch", registry.list_archs())
def test_dot_general_fwd_bwd_per_config(arch, rng):
    cfg = registry.smoke_config(arch)
    with falcon.use(FCFG):
        for (K, N) in _shapes_for(cfg):
            x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
            w = jnp.asarray(rng.standard_normal((K, N)) * 0.1, jnp.float32)
            y = falcon.dot_general(x, w, DN)
            ref = np.asarray(x) @ np.asarray(w)
            np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)

            gx, gw = jax.grad(
                lambda a, b: jnp.sum(falcon.dot_general(a, b, DN) ** 2),
                argnums=(0, 1))(x, w)
            gx0, gw0 = jax.grad(
                lambda a, b: jnp.sum((a @ b) ** 2), argnums=(0, 1))(x, w)
            np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                                       atol=5e-2, rtol=1e-3)
            np.testing.assert_allclose(np.asarray(gw), np.asarray(gw0),
                                       atol=5e-2, rtol=1e-3)


@pytest.mark.parametrize(
    "arch", [a for a in registry.list_archs()
             if getattr(registry.smoke_config(a), "num_experts", 0)])
def test_grouped_expert_matmul_per_moe_config(arch, rng):
    """MoE archs additionally smoke their grouped E x (C, K) @ (K, N) path."""
    cfg = registry.smoke_config(arch)
    (E, C, K, N) = core_engine.grouped_expert_shapes(cfg, m_tokens=16)[0]
    E, C, K, N = min(E, 4), min(C, 16), min(K, 128), min(N, 128)
    x = jnp.asarray(rng.standard_normal((E, C, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)) * 0.1, jnp.float32)
    with falcon.use(FCFG):
        y = falcon.grouped_matmul(x, w)
        g = jax.grad(lambda a: jnp.sum(falcon.grouped_matmul(a, w) ** 2))(x)
    ref = np.einsum("eck,ekn->ecn", np.asarray(x), np.asarray(w))
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-3)
    g0 = jax.grad(lambda a: jnp.sum(jnp.einsum("eck,ekn->ecn", a, w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               atol=5e-2, rtol=1e-3)
