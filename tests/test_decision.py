"""Decision Module: Table II closed forms, Eq. 8/10, selection behavior."""
import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import algorithms as alg, decision as dec
from repro.core.hardware import TPU_V5E


def test_table2_combine_a_intensity():
    """Arithmetic intensity of Combine A == (|U|0 - R)/(mk + R)  [Table II]."""
    l = alg.get("strassen")
    M = N = K = 4096
    est = dec.estimate(l, M, N, K, TPU_V5E, "bfloat16")
    ca = est.stages[0]
    by = 2
    expect_ai = (l.nnz_u - l.R) / (l.m * l.k + l.R) / by  # per-BYTE intensity
    assert ca.name == "combine_a"
    np.testing.assert_allclose(ca.flops / ca.bytes, expect_ai, rtol=1e-6)


def test_fused_drops_h_traffic():
    l = alg.get("strassen")
    M = N = K = 8192
    fused = dec.estimate(l, M, N, K, TPU_V5E, fused=True)
    unfused = dec.estimate(l, M, N, K, TPU_V5E, fused=False)
    bytes_f = sum(s.bytes for s in fused.stages)
    bytes_u = sum(s.bytes for s in unfused.stages)
    # Eq.9 -> Eq.10: H is written once by the GEMM stage and read once by
    # Combine H in the unfused flow => fused saves 2*R*(M/m)(N/n) elements.
    saved = 2 * l.R * (M // l.m) * (N // l.n) * 2  # x2 bytes (bf16)
    assert bytes_u - bytes_f == pytest.approx(saved, rel=1e-6)
    assert fused.time < unfused.time


def test_eq8_memory_bound_guard():
    # tiny K => memory bound => no LCMA
    assert dec.eq8_is_memory_bound(4096, 4096, 32, TPU_V5E)
    d = dec.decide(4096, 4096, 32, TPU_V5E)
    assert not d.use_lcma and d.estimates == ()


def test_eq10_consistency_with_estimate():
    """Closed-form Eq.10 must agree with the staged model in the memory-bound-
    combines + compute-bound-GEMM regime it assumes."""
    l = alg.get("strassen")
    hw = TPU_V5E
    for M, N, K in [(16384, 16384, 16384), (32768, 32768, 8192),
                    (8192, 8192, 8192), (2048, 2048, 2048)]:
        est = dec.estimate(l, M, N, K, hw)
        # verify regime assumptions hold, then check agreement
        s = {x.name: x for x in est.stages}
        if (s["combine_a"].bound == "memory" and s["combine_b"].bound == "memory"
                and s["gemm+combine_h"].bound == "compute"):
            profitable_model = est.time < dec.gemm_time(M, N, K, hw)
            assert dec.eq10_profitable(l, M, N, K, hw) == profitable_model


def test_selection_prefers_bigger_savings_at_scale():
    hw = TPU_V5E
    d = dec.decide(32768, 32768, 32768, hw, "bfloat16")
    assert d.use_lcma
    assert d.algo.mult_saving >= alg.get("strassen").mult_saving
    assert d.speedup > 1.0


def test_effective_tflops_exceeds_peak():
    """The paper's headline: effective TFLOPS above the hardware peak."""
    hw = TPU_V5E
    d = dec.decide(65536, 65536, 65536, hw, "bfloat16")
    assert d.use_lcma
    eff = dec.effective_tflops(65536, 65536, 65536, d.seconds)
    assert eff > hw.flops_for("bfloat16") / 1e12


def test_padding_priced_in():
    l = alg.get("s444")
    hw = TPU_V5E
    t_exact = dec.lcma_time(l, 16384, 16384, 16384, hw)
    t_padded = dec.lcma_time(l, 16383, 16383, 16383, hw)  # pads up to x4
    assert t_padded >= t_exact


def test_precombined_b_removes_stage():
    l = alg.get("strassen")
    est = dec.estimate(l, 8192, 8192, 8192, TPU_V5E, precombined_b=True)
    assert [s.name for s in est.stages] == ["combine_a", "gemm+combine_h"]


@given(st.integers(8, 64), st.integers(8, 64), st.integers(8, 64))
@settings(max_examples=25, deadline=None)
def test_decision_never_slower_than_gemm_model(m_, n_, k_):
    """Property: the Decision Module's pick is never predicted slower than
    standard GEMM (it falls back when LCMA can't win)."""
    M, N, K = m_ * 256, n_ * 256, k_ * 256
    d = dec.decide(M, N, K, TPU_V5E)
    assert d.seconds <= dec.gemm_time(M, N, K, TPU_V5E) * (1 + 1e-9)


def test_cutoff_moves_with_bandwidth():
    """More bandwidth (H20-like beta/flops ratio) => LCMA wins at smaller sizes."""
    import dataclasses
    fat = dataclasses.replace(TPU_V5E, beta=4000e9, flops_mul=148e12,
                              dtype_flops=None)
    thin = TPU_V5E
    M = N = K = 4096
    d_fat = dec.decide(M, N, K, fat, "bfloat16")
    d_thin = dec.decide(M, N, K, thin, "bfloat16")
    assert d_fat.use_lcma and not d_thin.use_lcma
