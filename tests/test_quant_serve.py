"""int8-quantized serving vs fp32: logit error under the accuracy budget.

Serves the same ragged request set through two ServeEngines built from the
same seed — one fp32, one with the quantized decision tier on — and compares
recorded per-step logits. Comparison is *prefix-matched*: step ``t`` of a
request is comparable only while both engines generated identical tokens up
to ``t`` (greedy decode diverging on a near-tie changes every downstream
context, so naive all-steps error is meaningless). Step 0 depends only on the
prompt and is always comparable.

The smoke arch is widened (d_model 256) so the Decision Module actually
selects the quantized LCMA tier for the serving buckets: at the registry
smoke dims (d_model 64) no tier beats cuBLAS-style GEMM and both engines
would run the identical dense path.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import plan_cache
from repro.core.engine import PlannedWeight
from repro.serve import ServeEngine, StepLoop

# Widened smoke config: big enough for the quant tier to engage, small
# enough for interpret-mode Pallas in CI (shared with benchmarks/quant_serve
# via the registry).
CFG = registry.lcma_smoke_config("granite_3_2b")

N_REQUESTS = 5
# Relative logit-error ceiling for blockwise int8 weights at these dims;
# measured headroom is ~3x (see benchmarks/quant_serve.py).
REL_BUDGET = 0.15


def _quantized_weights(engine) -> int:
    leaves = jax.tree_util.tree_leaves(
        engine.params, is_leaf=lambda x: isinstance(x, PlannedWeight))
    return sum(1 for x in leaves
               if isinstance(x, PlannedWeight) and x.quantized)


def _serve(cfg, *, quantize: bool):
    plan_cache.reset()
    engine = ServeEngine(cfg, max_slots=4, max_prompt_len=32,
                         max_new_tokens=8, record_logits=True, seed=0,
                         quantize=quantize)
    rng = np.random.default_rng(11)
    for _ in range(N_REQUESTS):
        plen = int(rng.integers(4, 33))
        engine.submit(rng.integers(0, cfg.vocab_size, plen),
                      max_new_tokens=int(rng.integers(2, 9)))
    done = StepLoop(engine).run_until_idle()
    return engine, sorted(done, key=lambda r: r.rid)


@pytest.fixture(scope="module", params=["jnp", "pallas_interpret"])
def served_pair(request):
    cfg = dataclasses.replace(CFG, falcon_backend=request.param)
    fp_engine, fp_done = _serve(cfg, quantize=False)
    q_engine, q_done = _serve(cfg, quantize=True)
    return fp_engine, fp_done, q_engine, q_done


def test_quant_engine_serves_everything(served_pair):
    fp_engine, fp_done, q_engine, q_done = served_pair
    assert len(fp_done) == len(q_done) == N_REQUESTS
    assert q_engine.summary()["quantize"] is True
    assert fp_engine.summary()["quantize"] is False


def test_quant_tier_actually_engaged(served_pair):
    """The quant engine must hold offline-quantized PlannedWeights."""
    _, _, q_engine, _ = served_pair
    assert q_engine.n_precombined >= 1
    assert _quantized_weights(q_engine) >= 1


def test_prefix_matched_logit_error_under_budget(served_pair):
    _, fp_done, _, q_done = served_pair
    compared = 0
    worst = 0.0
    for rf, rq in zip(fp_done, q_done):
        assert rf.prompt == rq.prompt
        scale = max(float(np.max(np.abs(np.asarray(l))))
                    for l in rf.logits)
        for t, (lf, lq) in enumerate(zip(rf.logits, rq.logits)):
            if rf.generated[:t] != rq.generated[:t]:
                break
            err = float(np.max(np.abs(np.asarray(lf) - np.asarray(lq))))
            worst = max(worst, err / max(scale, 1e-30))
            compared += 1
    # step 0 (prompt-only context) is always comparable for every request
    assert compared >= N_REQUESTS
    assert worst <= REL_BUDGET, \
        f"max prefix-matched relative logit error {worst:.3f} > {REL_BUDGET}"
