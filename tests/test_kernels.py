"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import algorithms as alg
from repro.kernels import ops, ref
from repro.kernels.fused_gemm import fused_gemm_combine_h, tiled_matmul
from repro.kernels.group_combine import group_combine
from repro.kernels.tuning import (combine_vmem, fused_gemm_vmem,
                                  plan_combine_blocks, plan_fused_gemm_blocks)


@pytest.mark.parametrize("name", ["strassen", "laderman", "s223"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_group_combine_matches_oracle(name, dtype, rng):
    l = alg.get(name)
    X, Y = 32, 16
    x = jnp.asarray(rng.standard_normal((l.m * X, l.k * Y)), dtype)
    got = group_combine(x, l.U, block=(16, 8), interpret=True)
    parts = x.reshape(l.m, X, l.k, Y).transpose(0, 2, 1, 3)
    want = ref.group_combine_ref(parts, l.U)
    # bf16: kernel adds sequentially in bf16; oracle einsum may accumulate
    # differently => order-of-summation differences of a few ulp
    atol, rtol = (1e-5, 1e-6) if dtype == "float32" else (6e-2, 2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol, rtol=rtol)


@pytest.mark.parametrize("name", ["strassen", "s223"])
@pytest.mark.parametrize("blocks", [(16, 16, 16), (32, 8, 16), (8, 8, 8)])
def test_fused_gemm_blocks(name, blocks, rng):
    l = alg.get(name)
    R = l.R
    at = jnp.asarray(rng.standard_normal((R, 32, 32)), jnp.float32)
    bt = jnp.asarray(rng.standard_normal((R, 32, 32)), jnp.float32)
    got = fused_gemm_combine_h(at, bt, l.W, block=blocks, interpret=True)
    want = ref.fused_gemm_combine_h_ref(at, bt, l.W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


from _schemes import mag2_scheme as _mag2_scheme  # noqa: E402 - shared fixture


def test_group_combine_honors_coefficient_magnitude(rng):
    l = _mag2_scheme()
    X, Y = 16, 16
    x = jnp.asarray(rng.integers(-4, 4, (l.m * X, l.k * Y)), jnp.float32)
    got = group_combine(x, l.U, block=(8, 8), interpret=True)
    parts = x.reshape(l.m, X, l.k, Y).transpose(0, 2, 1, 3)
    want = ref.group_combine_ref(parts, l.U)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_pipeline_honors_coefficient_magnitude(rng):
    """Full kernel pipeline (Combine A/B + fused GEMM/Combine H) stays exact
    on integer inputs for a |c|=2 scheme — exercises the magnitude paths in
    both group_combine and the fused Combine-H kernel."""
    l = _mag2_scheme()
    A = jnp.asarray(rng.integers(-3, 3, (24, 20)), jnp.float32)
    B = jnp.asarray(rng.integers(-3, 3, (20, 28)), jnp.float32)
    got = ops.falcon_matmul_pallas(A, B, l, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(A, np.float64) @ np.asarray(B, np.float64))


@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5),
       st.sampled_from(["strassen", "laderman"]))
@settings(max_examples=10, deadline=None)
def test_e2e_pallas_odd_shapes(mm, kk, nn, name):
    """Padding path: arbitrary (possibly non-divisible) shapes stay correct."""
    rng = np.random.default_rng(mm * 100 + kk * 10 + nn)
    l = alg.get(name)
    M, K, N = 13 * mm, 9 * kk, 11 * nn
    A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    got = ops.falcon_matmul_pallas(A, B, l, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)


def test_tiled_matmul_baseline(rng):
    A = jnp.asarray(rng.standard_normal((48, 64)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    got = tiled_matmul(A, B, block=(16, 16, 16), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A) @ np.asarray(B),
                               rtol=1e-5, atol=1e-5)


def test_resource_planner_respects_budget():
    """On-chip Resource Planning (§III-A): high-rank schemes get smaller tiles."""
    bx, bz, by = plan_fused_gemm_blocks(4096, 4096, 4096, R=49, m=4, n=4,
                                        dtype=jnp.bfloat16)
    assert fused_gemm_vmem(bx, bz, by, 49, 4, 4, 2) <= (12 << 20)
    bx7, bz7, by7 = plan_fused_gemm_blocks(4096, 4096, 4096, R=7, m=2, n=2,
                                           dtype=jnp.bfloat16)
    assert fused_gemm_vmem(bx7, bz7, by7, 7, 2, 2, 2) <= (12 << 20)
    # lower rank => at least as large a working tile
    assert bx7 * bz7 * by7 >= bx * bz * by


def test_combine_planner():
    bx, by = plan_combine_blocks(2048, 2048, R=23, nparts=9, dtype=jnp.bfloat16)
    assert 2048 % bx == 0 and 2048 % by == 0
    assert combine_vmem(bx, by, 23, 9, 2) <= (12 << 20)


def test_fused_kernel_keeps_h_in_f32(rng):
    """§IV-F mechanism: the fused kernel's C comes from f32 accumulators."""
    l = alg.get("strassen")
    at = jnp.asarray(rng.standard_normal((7, 16, 128)) * 30, jnp.bfloat16)
    bt = jnp.asarray(rng.standard_normal((7, 128, 16)) * 30, jnp.bfloat16)
    got = fused_gemm_combine_h(at, bt, l.W, block=(16, 16, 64),
                               out_dtype=jnp.float32, interpret=True)
    want = ref.fused_gemm_combine_h_ref(at, bt, l.W, out_dtype=jnp.float32)
    # identical f32 accumulation up to summation order (values ~1e3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=0.5)
