"""falcon-check static-analysis subsystem: pass APIs + CLI acceptance.

Every scenario here is static — no kernel is compiled or launched. The four
acceptance scenarios (corrupted scheme, undersized accumulator, over-VMEM
plan, dangling cache ref) each drive the CLI end-to-end and assert both the
non-zero exit AND that the report names the responsible pass.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import analysis
from repro.core import algorithms as alg
from repro.core import decision as dec
from repro.core import discovery, hardware, plan_cache
from repro.core.lcma import LCMA, apply_reference, validate
from repro.kernels import tuning
from repro.tools import check as check_cli

from _schemes import mag2_111, mag2_scheme


# ---------------------------------------------------------------------------
# pass 1: exact Brent verification
# ---------------------------------------------------------------------------

def _corrupt(l: LCMA, name="corrupt") -> LCMA:
    W = l.W.copy()
    W[0, 0, 0] += 1
    return LCMA(name, l.m, l.k, l.n, l.R, l.U, l.V, W)


def test_brent_clean_on_library():
    findings = analysis.check_library()
    assert not analysis.has_errors(findings)


def test_brent_flags_corrupted_scheme():
    findings = analysis.check_scheme(_corrupt(alg.strassen()))
    assert len(findings) == 1
    f = findings[0]
    assert f.pass_name == "brent" and f.is_error
    assert "Brent equations violated" in f.message


def test_brent_residual_is_exact_integer():
    res = analysis.brent_residual(alg.strassen())
    assert res.dtype == np.int64
    assert not res.any()
    bad = analysis.brent_residual(_corrupt(alg.strassen()))
    assert bad.any()


def test_verify_or_raise_names_context():
    with pytest.raises(ValueError, match="promotion"):
        analysis.verify_or_raise(_corrupt(alg.strassen()), context="promotion")


def test_register_rejects_invalid_scheme():
    bad = _corrupt(alg.strassen(), name="bad-register")
    with pytest.raises(ValueError, match="Brent"):
        alg.register(bad)
    assert "bad-register" not in alg.library()


def test_validate_exact_integer_path_is_default():
    assert validate(alg.strassen())
    assert not validate(_corrupt(alg.strassen()))
    # float path survives for prospective non-integer decompositions
    assert validate(alg.strassen(), atol=1e-9)


def test_discovery_output_is_exactly_verified():
    found = discovery.discover(2, 2, 2, 7, restarts=8, als_iters=40, seed=0,
                               init=alg.strassen())
    assert found is not None
    assert not analysis.check_scheme(found)


# ---------------------------------------------------------------------------
# pass 2: stability analysis + Decision Module budget
# ---------------------------------------------------------------------------

def test_stability_standard_growth_is_k():
    s = alg.standard(2, 3, 2).stability
    # standard <m,k,n>: alpha_u = alpha_v = 1 per term, alpha_w = k
    assert s.growth == 3
    assert s.max_abs_coeff == 1


def test_stability_orders_strassen_below_mag2():
    st = alg.strassen().stability
    m2 = mag2_scheme().stability
    assert st.error_bound("bfloat16") < m2.error_bound("bfloat16")
    assert m2.max_abs_coeff > 1


def test_stability_warns_on_magnitude_regression_scheme():
    findings = analysis.check_scheme_stability(mag2_111())
    warn = [f for f in findings if f.severity == "warning"]
    assert warn and "magnitude" in warn[0].message


def test_decide_respects_accuracy_budget():
    hw = hardware.TPU_V5E
    strassen = alg.strassen()
    budget = strassen.stability.error_bound("bfloat16")  # admits strassen only
    cands = [strassen, mag2_scheme()]
    d = dec.decide(4096, 4096, 4096, hw, "bfloat16", candidates=cands,
                   accuracy_budget=budget)
    assert all(e.lcma.name != "mag2-222" for e in d.estimates)
    # a budget below every candidate's bound forces standard GEMM
    d0 = dec.decide(4096, 4096, 4096, hw, "bfloat16", candidates=cands,
                    accuracy_budget=budget / 1e6)
    assert d0.algo is None and d0.estimates == ()


def test_plan_key_accuracy_budget_token():
    hw = hardware.TPU_V5E
    k0 = plan_cache.plan_key(64, 64, 64, hw, "bfloat16")
    kb = plan_cache.plan_key(64, 64, 64, hw, "bfloat16", accuracy_budget=0.25)
    assert k0 != kb and "ab=0.25" in kb and "ab=" not in k0


def test_quant_accumulator_bounds():
    assert analysis.max_safe_accum_depth(32) == (2**31 - 1) // 127**2
    ok = analysis.check_quant_accumulator(128, 32)
    assert not analysis.has_errors(ok)
    bad = analysis.check_quant_accumulator(128, 16)
    assert analysis.has_errors(bad)
    assert bad[0].pass_name == "stability"


def test_quant_kernel_guards_accumulator_depth():
    from repro.kernels import quant_combine
    depth = analysis.max_safe_accum_depth(32) + 1
    R = 2
    aq = np.zeros((R, 1, depth), np.int8)
    a_scales = np.ones((R, 1, 1), np.float32)
    bq = np.zeros((R, depth, 1), np.int8)
    b_scales = np.ones((R, 1, 1), np.float32)
    w = np.ones((R, 1, 1), np.int8)
    with pytest.raises(ValueError, match="overflow"):
        quant_combine.fused_gemm_combine_h_quant(
            aq, a_scales, bq, b_scales, w, interpret=True)


# ---------------------------------------------------------------------------
# pass 3: plan + codegen lint
# ---------------------------------------------------------------------------

def _tiny_vmem(name="tiny_vmem_test") -> hardware.HardwareProfile:
    return hardware.register_profile(dataclasses.replace(
        hardware.TPU_V5E, name=name, vmem_bytes=1 << 10))


def test_plan_lint_clean_on_default_profile():
    findings = analysis.lint_scheme_plans(
        alg.strassen(), [(1024, 1024, 1024)], hardware.TPU_V5E)
    assert not analysis.has_errors(findings)


def test_plan_lint_flags_overbudget_plan():
    plan = tuning.block_plans(alg.strassen(), 1024, 1024, 1024)
    findings = analysis.lint_block_plan(plan, _tiny_vmem())
    errs = [f for f in findings if f.is_error]
    assert errs and all(f.pass_name == "plan-lint" for f in errs)
    assert any("VMEM footprint" in f.message for f in errs)


def test_plan_lint_flags_tampered_report():
    plan = tuning.block_plans(alg.strassen(), 1024, 1024, 1024)
    plan["fused_gemm_vmem_bytes"] += 1
    findings = analysis.lint_block_plan(plan, hardware.TPU_V5E)
    assert any("stale or hand-edited" in f.message for f in findings
               if f.is_error)


def test_plan_lint_flags_illegal_dtype():
    plan = tuning.block_plans(alg.strassen(), 1024, 1024, 1024,
                              dtype="float64")
    findings = analysis.lint_block_plan(plan, hardware.TPU_V5E,
                                        dtype="float64", backend="pallas")
    assert any("not executable on backend" in f.message for f in findings
               if f.is_error)


def test_planner_degrades_high_rank_schemes_into_budget():
    # <4,4,4>;49: the (R, bx, bz) accumulator bursts the MXU-aligned tiles;
    # the planner must degrade block sizes, not emit an over-budget plan.
    l = alg.get("s444")
    plan = tuning.block_plans(l, 1024, 1024, 1024, hw=hardware.TPU_V5E)
    assert not analysis.has_errors(
        analysis.lint_block_plan(plan, hardware.TPU_V5E))


def test_block_plans_hw_clamps_budget():
    hw = dataclasses.replace(hardware.TPU_V5E, name="clamp", vmem_bytes=1 << 20)
    plan = tuning.block_plans(alg.strassen(), 1024, 1024, 1024, hw=hw)
    assert plan["vmem_budget_bytes"] == 1 << 20
    assert not analysis.has_errors(analysis.lint_block_plan(plan, hw))


def test_codegen_lint_clean_on_candidates():
    for l in alg.candidates():
        assert analysis.lint_codegen(l) == [], l.name


def test_codegen_lint_clean_on_magnitude_scheme():
    # the PR 4 regression class: |c|>1 coefficients must round-trip the AST
    assert analysis.lint_codegen(mag2_scheme()) == []


def test_codegen_lint_catches_magnitude_drop(monkeypatch):
    """The PR 4 class of generator bug: emitted source drops |c|>1 magnitudes.

    Simulated by emitting source for a magnitude-stripped clone of mag2-111
    while linting the real scheme — the lint must notice the emitted
    coefficient maps disagree with the true tensors.
    """
    from repro.core import codegen

    l = mag2_111()
    stripped = LCMA("mag2-dropped", 1, 1, 1, 2,
                    np.sign(l.U), np.sign(l.V), np.sign(l.W))
    orig = codegen._emit_source
    monkeypatch.setattr(codegen, "_emit_source",
                        lambda scheme, o: orig(stripped, o))
    findings = analysis.lint_codegen(l)
    errs = [f for f in findings if f.is_error]
    assert errs and all(f.pass_name == "codegen-lint" for f in errs)


# ---------------------------------------------------------------------------
# pass 4: cache audit
# ---------------------------------------------------------------------------

def _saved_cache(tmp_path, hw=hardware.TPU_V5E):
    cache = plan_cache.PlanCache(capacity=8)
    d = dec.decide(1024, 1024, 1024, hw, "bfloat16")
    cache.insert(plan_cache.plan_key(1024, 1024, 1024, hw, "bfloat16"), d)
    return cache.save(str(tmp_path / "cache.json"))


def test_cache_audit_clean_roundtrip(tmp_path):
    path = _saved_cache(tmp_path)
    findings = analysis.audit_cache_file(path, hw=hardware.TPU_V5E)
    assert not analysis.has_errors(findings)


def test_cache_audit_flags_dangling_scheme(tmp_path):
    path = _saved_cache(tmp_path)
    doc = json.loads(open(path).read())
    doc["entries"][0][1]["algo"] = "ghost-scheme"
    doc["entries"][0][1]["algo_fp"] = "0" * 12
    doc["entries"][0][1]["lcma_seconds"] = 1e-5
    json.dump(doc, open(path, "w"))
    findings = analysis.audit_cache_file(path)
    errs = [f for f in findings if f.is_error]
    assert errs and all(f.pass_name == "cache-audit" for f in errs)
    assert any("ghost-scheme" in f.message for f in errs)


def test_cache_audit_flags_definition_drift(tmp_path):
    path = _saved_cache(tmp_path)
    doc = json.loads(open(path).read())
    entry = doc["entries"][0][1]
    if entry["algo"] is None:   # force an LCMA-bearing entry
        entry["algo"] = "strassen"
        entry["lcma_seconds"] = 1e-5
    entry["algo_fp"] = "f" * 12  # not any real fingerprint
    json.dump(doc, open(path, "w"))
    findings = analysis.audit_cache_file(path)
    assert any("definition changed" in f.message for f in findings
               if f.is_error)


def test_cache_load_drops_fingerprint_drift(tmp_path):
    path = _saved_cache(tmp_path)
    doc = json.loads(open(path).read())
    entry = doc["entries"][0][1]
    entry["algo"] = "strassen"
    entry["lcma_seconds"] = 1e-5
    entry["algo_fp"] = "f" * 12
    json.dump(doc, open(path, "w"))
    cache = plan_cache.PlanCache(path=path)   # permissive loader
    assert len(cache) == 0                    # stale entry dropped, not served


def test_cache_audit_flags_shape_mismatch(tmp_path):
    path = _saved_cache(tmp_path)
    doc = json.loads(open(path).read())
    doc["entries"][0][1]["M"] = 999
    json.dump(doc, open(path, "w"))
    findings = analysis.audit_cache_file(path)
    assert any("shape token" in f.message for f in findings if f.is_error)


def test_fingerprint_tracks_definition_not_name():
    a = alg.strassen()
    b = LCMA("renamed", a.m, a.k, a.n, a.R, a.U, a.V, a.W)
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != _corrupt(a).fingerprint


# ---------------------------------------------------------------------------
# satellite: ValueErrors with shapes instead of bare asserts
# ---------------------------------------------------------------------------

def test_concat_mismatch_raises_with_shapes():
    with pytest.raises(ValueError, match=r"<2,2,2>.*<3,3,3>"):
        alg.concat_n(alg.strassen(), alg.laderman())
    with pytest.raises(ValueError, match="concat_m"):
        alg.concat_m(alg.strassen(), alg.laderman())
    with pytest.raises(ValueError, match="concat_k"):
        alg.concat_k(alg.strassen(), alg.laderman())


def test_apply_reference_raises_with_shapes():
    l = alg.strassen()
    with pytest.raises(ValueError, match="contraction"):
        apply_reference(l, np.ones((4, 4)), np.ones((6, 4)))
    with pytest.raises(ValueError, match="divisible"):
        apply_reference(l, np.ones((3, 4)), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# CLI acceptance scenarios
# ---------------------------------------------------------------------------

def test_cli_all_clean_on_shipped_library(capsys):
    assert check_cli.main(["--all"]) == 0
    out = capsys.readouterr().out
    assert "0 error(s)" in out


def test_cli_flags_corrupted_strassen(tmp_path, capsys):
    l = _corrupt(alg.strassen(), name="strassen-corrupt")
    doc = dict(name=l.name, m=l.m, k=l.k, n=l.n, R=l.R,
               U=l.U.tolist(), V=l.V.tolist(), W=l.W.tolist())
    p = tmp_path / "bad_scheme.json"
    p.write_text(json.dumps(doc))
    assert check_cli.main(["--scheme-file", str(p)]) == 1
    out = capsys.readouterr().out
    assert "brent" in out and "Brent equations violated" in out


def test_cli_flags_undersized_accumulator(capsys):
    assert check_cli.main(["--quant-accum", "128,16"]) == 1
    out = capsys.readouterr().out
    assert "stability" in out and "overflow" in out


def test_cli_flags_overbudget_plan(tmp_path, capsys):
    _tiny_vmem("tiny_vmem_cli")
    plan = tuning.block_plans(alg.strassen(), 1024, 1024, 1024)
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan))
    assert check_cli.main(["--plan-file", str(p),
                           "--hardware", "tiny_vmem_cli"]) == 1
    out = capsys.readouterr().out
    assert "plan-lint" in out and "VMEM footprint" in out


def test_cli_flags_dangling_cache_entry(tmp_path, capsys):
    path = _saved_cache(tmp_path)
    doc = json.loads(open(path).read())
    doc["entries"][0][1]["algo"] = "ghost-scheme"
    doc["entries"][0][1]["lcma_seconds"] = 1e-5
    json.dump(doc, open(path, "w"))
    assert check_cli.main(["--cache", path]) == 1
    out = capsys.readouterr().out
    assert "cache-audit" in out and "ghost-scheme" in out


def test_cli_budget_makes_mag2_an_error(tmp_path, capsys):
    l = mag2_scheme()
    doc = dict(name=l.name, m=l.m, k=l.k, n=l.n, R=l.R,
               U=l.U.tolist(), V=l.V.tolist(), W=l.W.tolist())
    p = tmp_path / "mag2.json"
    p.write_text(json.dumps(doc))
    # strassen's bf16 bound as budget: mag2 exceeds it
    budget = alg.strassen().stability.error_bound("bfloat16")
    assert check_cli.main(["--scheme-file", str(p),
                           "--budget", f"{budget:g}"]) == 1
    out = capsys.readouterr().out
    assert "stability" in out and "exceeds the accuracy budget" in out


def test_cli_single_scheme_pass(capsys):
    assert check_cli.main(["--scheme", "strassen"]) == 0
    assert check_cli.main(["--scheme", "no-such-scheme"]) == 2
