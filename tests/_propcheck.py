"""Property-testing shim: real ``hypothesis`` when installed, else a fallback.

``hypothesis`` is a declared test dependency (``pip install -e ".[test]"``),
but the suite must also run in minimal containers that only have jax/numpy/
pytest. Importing this module instead of ``hypothesis`` directly keeps
collection working either way:

  * with hypothesis installed, ``given``/``settings``/``st`` are the real
    thing — full shrinking, example databases, the works;
  * without it, ``given`` degrades to a deterministic sampler: boundary
    points first, then seeded-random draws up to ``max_examples``. No
    shrinking, but the property still gets exercised on every run.

Only the tiny subset this repo uses is shimmed (``st.integers``,
``settings(max_examples=, deadline=)``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import itertools
    import random

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        @property
        def corners(self) -> list[int]:
            return sorted({self.lo, self.hi, (self.lo + self.hi) // 2})

        def draw(self, rnd: random.Random) -> int:
            return rnd.randint(self.lo, self.hi)

    class _Floats:
        def __init__(self, lo: float, hi: float):
            self.lo, self.hi = float(lo), float(hi)

        @property
        def corners(self) -> list[float]:
            return sorted({self.lo, self.hi, (self.lo + self.hi) / 2.0})

        def draw(self, rnd: random.Random) -> float:
            return rnd.uniform(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        @property
        def corners(self) -> list:
            return self.elements

        def draw(self, rnd: random.Random):
            return rnd.choice(self.elements)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Integers:
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Floats:
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements) -> _SampledFrom:
            return _SampledFrom(elements)

    st = _Strategies()

    def settings(max_examples: int = 100, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    def given(*strategies):
        def deco(f):
            n_default = getattr(f, "_max_examples", 25)

            def wrapper():
                seen = 0
                # all-corner combinations first (capped), then seeded draws
                for combo in itertools.islice(
                        itertools.product(*(s.corners for s in strategies)),
                        n_default):
                    f(*combo)
                    seen += 1
                rnd = random.Random(0)
                while seen < n_default:
                    f(*(s.draw(rnd) for s in strategies))
                    seen += 1

            # NOT functools.wraps: pytest must see a zero-arg signature, or it
            # would try to resolve the property's parameters as fixtures.
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco
