"""Plan cache: round-trip, key separation, hit short-circuit, LRU bounds."""
import dataclasses

import pytest

from repro.core import decision as dec, plan_cache
from repro.core.falcon_gemm import FalconConfig, plan
from repro.core.hardware import CPU_HOST, TPU_V5E

SHAPE = (16384, 5376, 21504)      # M, K, N — profitable on v5e => algo cached


@pytest.fixture(autouse=True)
def fresh_cache():
    """Isolate every test from the process-default cache."""
    plan_cache.reset()
    yield
    plan_cache.reset()


def _key(M, K, N, hw=TPU_V5E, dtype="bfloat16", **kw):
    kw.setdefault("min_speedup", FalconConfig.min_speedup)
    kw.setdefault("max_grid", FalconConfig.max_grid)
    return plan_cache.plan_key(M, K, N, hw, dtype, **kw)


def test_roundtrip_save_load(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = plan_cache.configure(path=path, autoload=False)
    d = plan(*SHAPE, FalconConfig())
    assert d.use_lcma and len(cache) == 1
    cache.save()

    loaded = plan_cache.PlanCache(path=path)
    assert len(loaded) == 1
    hit = loaded.lookup(_key(*SHAPE))
    assert hit is not None
    assert hit.algo.name == d.algo.name
    assert hit.gemm_seconds == pytest.approx(d.gemm_seconds)
    assert hit.lcma_seconds == pytest.approx(d.lcma_seconds)
    assert hit.estimates == ()   # breakdown dropped on disk, decision intact
    assert hit.speedup == pytest.approx(d.speedup)


def test_no_key_collisions_across_dtype_and_hardware():
    keys = {
        _key(*SHAPE),
        _key(*SHAPE, dtype="float32"),
        _key(*SHAPE, hw=CPU_HOST),
        _key(*SHAPE, fused=False),
        _key(*SHAPE, precombined_b=True),
        _key(*SHAPE, candidates=("strassen",)),
        _key(4096, 5376, 21504),
    }
    assert len(keys) == 7


def test_recalibration_invalidates_fingerprint():
    """Same profile *name*, different numbers => different key."""
    recal = dataclasses.replace(TPU_V5E, beta=TPU_V5E.beta * 0.5)
    assert _key(*SHAPE) != _key(*SHAPE, hw=recal)


def test_cache_hit_short_circuits_enumeration(monkeypatch):
    plan_cache.configure(path=None)
    calls = {"n": 0}
    real_decide = dec.decide

    def counting_decide(*a, **kw):
        calls["n"] += 1
        return real_decide(*a, **kw)

    monkeypatch.setattr(dec, "decide", counting_decide)
    cfg = FalconConfig()
    d1 = plan(*SHAPE, cfg)
    d2 = plan(*SHAPE, cfg)
    assert calls["n"] == 1                    # second call never enumerates
    assert d2 is d1                           # in-memory hit: same object
    st = plan_cache.stats()
    assert st.hits == 1 and st.misses == 1 and st.inserts == 1
    # opting out disables memoization
    plan(*SHAPE, dataclasses.replace(cfg, use_plan_cache=False))
    assert calls["n"] == 2


def test_non_auto_modes_bypass_cache():
    plan_cache.configure(path=None)
    plan(*SHAPE, FalconConfig(mode="gemm"))
    plan(*SHAPE, FalconConfig(mode="strassen"))
    st = plan_cache.stats()
    assert st.lookups == 0 and len(plan_cache.default_cache()) == 0


def test_lru_eviction_is_bounded():
    cache = plan_cache.PlanCache(capacity=2)
    cfg = FalconConfig()
    for M in (1024, 2048, 4096):
        d = plan(M, 5376, 21504, dataclasses.replace(cfg, use_plan_cache=False))
        cache.insert(_key(M, 5376, 21504), d)
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    assert cache.lookup(_key(1024, 5376, 21504)) is None   # oldest evicted
    assert cache.lookup(_key(4096, 5376, 21504)) is not None


def test_load_skips_unknown_schemes(tmp_path):
    path = str(tmp_path / "plans.json")
    cache = plan_cache.PlanCache(path=path, autoload=False)
    d = plan(*SHAPE, FalconConfig(use_plan_cache=False))
    cache.insert("good", d)
    cache.save()

    import json
    doc = json.load(open(path))
    bad = dict(doc["entries"][0][1], algo="no_such_scheme_xyz")
    doc["entries"].append(["bad", bad])
    json.dump(doc, open(path, "w"))

    loaded = plan_cache.PlanCache(path=path)
    assert loaded.lookup("good") is not None
    assert loaded.lookup("bad") is None       # dropped, not crashed


def test_shards_produce_distinct_cached_plans():
    plan_cache.configure(path=None)
    big = plan(*SHAPE, FalconConfig())
    sharded = plan(*SHAPE, FalconConfig(shards=(16, 1, 16)))
    assert len(plan_cache.default_cache()) == 2
    assert big.speedup != sharded.speedup
