"""Sharding rules, divisibility filtering, shard_map MoE, compressed psum."""
import numpy as np

from conftest import run_multidevice


def test_spec_divisibility_filtering():
    from repro.parallel.sharding import ShardingRules
    rules = ShardingRules(
        rules=((r"w_q", (None, "model")), (r"embed", ("model", None))),
        batch=("data",), axis_sizes=(("data", 16), ("model", 16)))
    # divisible: kept
    assert str(rules.spec_for("layers/attn/w_q", (2048, 1600))) == \
        str(rules.spec_for("layers/attn/w_q", (2048, 1600)))
    s = rules.spec_for("layers/attn/w_q", (2048, 1600))
    assert s[1] == "model"
    # not divisible (hymba 25 heads -> 25*hd=... use odd dim): dropped
    s2 = rules.spec_for("layers/attn/w_q", (2048, 1601))
    assert s2[1] is None
    # leading stacked-layer dim is padded with None
    s3 = rules.spec_for("embed", (4, 49152, 64))
    assert s3[0] is None and s3[1] == "model"


def test_shard_act_identity_without_mesh():
    import jax.numpy as jnp
    from repro.parallel.sharding import shard_act
    x = jnp.ones((4, 4))
    y = shard_act(x, ("pod", "data"), "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_moe_shardmap_equals_dense():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.api as falcon
        from repro import compat
        from repro.models import moe as MOE
        p = MOE.moe_init(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
        with falcon.use(falcon.FalconConfig(enabled=False)):
            y0, _ = MOE._moe_dense(p, x, 2, 256)
            mesh = compat.make_mesh((4, 2), ("data", "model"))
            with compat.set_mesh(mesh):
                y1, _ = jax.jit(lambda p_, x_: MOE.moe_apply(
                    p_, x_, 2, 1.25, deterministic_capacity=256))(p, x)
        err = float(jnp.max(jnp.abs(y0 - y1)))
        assert err < 1e-5, err
        print("MOE_OK", err)
    """)
    assert "MOE_OK" in out


def test_moe_shardmap_precombined_without_raw_weight():
    """keep_weight=False expert PlannedWeights must shard over the mesh.

    The B̃-only precombine drops the raw (E, K, N) arrays to halve expert
    HBM; the shard_map path used to raise on it, forcing keep_weight=True
    under any TP mesh. Now the stacked B̃ crosses the boundary (sharded on
    the expert dim) and is re-wrapped per device.
    """
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.api as falcon
        from repro import compat
        from repro.core import engine
        from repro.models import moe as MOE
        p = MOE.moe_init(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
        cfg = falcon.FalconConfig(mode="strassen", backend="jnp",
                                  use_plan_cache=False)
        with falcon.use(cfg):
            for k in ("moe_gate", "moe_up", "moe_down"):
                p[k] = engine.plan_weight(p[k], keep_weight=False, grouped=True)
                assert p[k].w is None and p[k].bt is not None, k
            y0, _ = MOE._moe_dense(p, x, 2, 256)
            mesh = compat.make_mesh((4, 2), ("data", "model"))
            with compat.set_mesh(mesh):
                y1, _ = jax.jit(lambda p_, x_: MOE.moe_apply(
                    p_, x_, 2, 1.25, deterministic_capacity=256))(p, x)
        err = float(jnp.max(jnp.abs(y0 - y1)))
        assert err < 1e-4, err
        print("MOE_PRE_OK", err)
    """)
    assert "MOE_PRE_OK" in out


def test_compressed_psum_accuracy_and_train_step():
    out = run_multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.parallel.compression import compressed_psum_mean, psum_mean
        mesh = compat.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.01

        def body(gl):
            exact = psum_mean({"g": gl}, ("data",))["g"]
            comp = compressed_psum_mean({"g": gl}, ("data",))["g"]
            return exact, comp
        with compat.set_mesh(mesh):
            exact, comp = jax.jit(compat.shard_map(
                body, in_specs=P("data", None),
                out_specs=(P(None, None), P(None, None)), check_vma=False))(g)
        rel = float(jnp.linalg.norm(exact - comp) / jnp.linalg.norm(exact))
        assert rel < 2e-2, rel
        print("COMP_OK", rel)

        # end-to-end: compressed-DP train step decreases loss
        from repro.configs import registry
        from repro.models import model as M
        from repro.optim import AdamWConfig, adamw_init
        from repro.data import DataConfig, SyntheticLMData
        from repro.train.steps import make_compressed_dp_train_step
        cfg = registry.smoke_config("granite_3_2b")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        oc = AdamWConfig(lr=1e-3)
        ost = adamw_init(params, oc)
        data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                          global_batch=8))
        # warmup=1: the default 100-step warmup leaves lr_scale ~0 over a
        # short smoke run, reducing the "learns" assertion to batch noise.
        step = jax.jit(make_compressed_dp_train_step(cfg, oc, mesh, warmup=1))
        batch = data.batch(0)  # fixed batch: loss must drop deterministically
        with compat.set_mesh(mesh):
            losses = []
            for s in range(8):
                params, ost, m = step(params, ost, batch, s)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.05, losses
        print("CDP_OK", round(losses[0], 3), round(losses[-1], 3))
    """, timeout=420)
    assert "COMP_OK" in out and "CDP_OK" in out


def test_param_sharding_rules_on_mesh():
    out = run_multidevice("""
        import jax, numpy as np
        from repro import compat
        from repro.configs import registry
        from repro.models import model as M
        from repro.parallel import sharding as SH
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        cfg = registry.smoke_config("dbrx_132b")
        sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        rules = SH.make_rules(mesh, fsdp=True)
        sh = SH.param_sharding(sds, mesh, rules)
        flat, _ = jax.tree_util.tree_flatten_with_path(sh)
        specs = {"/".join(str(getattr(p, "key", p)) for p in path): s.spec
                 for path, s in flat}
        moe_gate = [v for k, v in specs.items() if "moe_gate" in k][0]
        assert moe_gate[1] == "model", moe_gate   # experts over model (after L dim)
        wq = [v for k, v in specs.items() if "w_q" in k][0]
        assert "model" in str(wq)
        print("RULES_OK")
    """)
    assert "RULES_OK" in out
