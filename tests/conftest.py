import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 300) -> str:
    """Run python code in a subprocess with N virtual host devices.

    Tests in this process must see the real single device (per the dry-run
    isolation rule), so multi-device behavior is exercised out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
