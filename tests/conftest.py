import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Modules whose tests exercise mesh/shard_map behavior. They are auto-marked
# ``mesh`` so CI can run them as a dedicated simulated-mesh tier
# (``pytest -m mesh`` under the distributed job); they also run in tier-1.
MESH_TEST_MODULES = {"test_sharding", "test_shardmap_local", "test_distributed"}


def pytest_collection_modifyitems(config, items):
    for item in items:
        mod = getattr(item, "module", None)
        if mod is not None and mod.__name__ in MESH_TEST_MODULES:
            item.add_marker(pytest.mark.mesh)


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 300) -> str:
    """Run python code in a subprocess with N virtual host devices.

    Tests in this process must see the real single device (per the dry-run
    isolation rule), so multi-device behavior is exercised out-of-process.
    The subprocess asserts it actually sees ``n_devices`` before running the
    test body — an unset/ignored XLA flag must fail loudly, not let a mesh
    test silently pass on 1 device.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    guard = textwrap.dedent(f"""\
        import jax as _jax_guard
        assert len(_jax_guard.devices()) == {n_devices}, (
            "simulated mesh not in effect: expected {n_devices} devices, got "
            f"{{len(_jax_guard.devices())}} — XLA_FLAGS was not honored")
        """)
    out = subprocess.run(
        [sys.executable, "-c", guard + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
