"""Shared synthetic LCMA schemes for the test suite.

One definition of the |c|>1 regression scheme — previously copy-pasted into
four test files, which could silently drift apart.
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms as alg
from repro.core.lcma import LCMA, validate


def mag2_111() -> LCMA:
    """Valid <1,1,1>;2 scheme with |c| in {1, 2, 3}: C = (2A)(2B) - 3(AB)."""
    return LCMA("mag2-111", 1, 1, 1, 2,
                np.array([[[2]], [[1]]], np.int8),
                np.array([[[2]], [[1]]], np.int8),
                np.array([[[1]], [[-3]]], np.int8))


def mag2_scheme() -> LCMA:
    """<2,2,2>;14 with |c| in {1,2,3}: tensor product of the magnitude-2
    <1,1,1>;2 scheme with Strassen. Regression scheme for the bug where the
    combine emitters/kernels dropped coefficient magnitude (|c|>1 computed
    wrong results for AlphaTensor standard-arithmetic / Smirnov listings)."""
    l = alg.tensor_product(mag2_111(), alg.strassen(), "mag2-222")
    assert validate(l)
    return l
