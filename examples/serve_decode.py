"""Serving example: batched prefill + decode with offline Combine-B weights.

Shows the paper's §IV-C inference integration: for layers where the Decision
Module picks an LCMA, the static weight matrix is pre-combined ONCE
(offline Combine B) so serving pays only Combine A + fused GEMM/Combine H.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import algorithms as alg
from repro.core.falcon_gemm import (FalconConfig, matmul_with_precombined,
                                    precombine_weights)
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill_step

# --- offline Combine B on a static weight ----------------------------------
rng = np.random.default_rng(0)
l = alg.get("strassen")
W = jnp.asarray(rng.standard_normal((512, 2048)), jnp.float32)
Wt = precombine_weights(W, l)          # (R, K/2, N/2) — done once at load
x = jnp.asarray(rng.standard_normal((4, 64, 512)), jnp.float32)
y = matmul_with_precombined(x, Wt, l, n_logical=2048)
print(f"offline Combine B: weight (512,2048) -> B~ {tuple(Wt.shape)}; "
      f"serve err={float(jnp.max(jnp.abs(y - x @ W))):.2e}")

# --- batched generation with the reduced model -----------------------------
cfg = registry.smoke_config("granite_3_2b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
B, S, GEN = 4, 32, 16
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
prefill = jax.jit(make_prefill_step(cfg, max_len=S + GEN))
decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

logits, cache = prefill(params, tokens)
jax.block_until_ready(logits)
t0 = time.perf_counter()
outs = []
for i in range(GEN):
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    outs.append(np.asarray(nxt))
    logits, cache = decode(params, cache, nxt[:, None], S + i)
jax.block_until_ready(logits)
dt = time.perf_counter() - t0
print(f"generated {GEN} tokens x batch {B}: {B*GEN/dt:.1f} tok/s "
      f"({dt/GEN*1e3:.1f} ms/step)")
print("sequences:", np.stack(outs, 1)[:2].tolist())
