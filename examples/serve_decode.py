"""Serving example: batched prefill + decode on PlannedWeight params.

Shows the paper's §IV-C inference integration through the unified API: the
model's static weights are lifted to ``PlannedWeight``s (``precombine_params``)
so every projection where the Decision Module picks an LCMA pays only
Combine A + the fused GEMM/Combine H at serve time — Combine B ran ONCE at
load. The planned generation is checked allclose against the eager
(non-precombined) path.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro.configs import registry
from repro.models import model as M
from repro.train.steps import make_decode_step, make_prefill_step

# --- offline Combine B on a single static weight ---------------------------
rng = np.random.default_rng(0)
cfg_force = falcon.FalconConfig(mode="strassen")
W = jnp.asarray(rng.standard_normal((512, 2048)), jnp.float32)
pw = falcon.plan_weight(W, cfg=cfg_force)        # B~ combined once at load
x = jnp.asarray(rng.standard_normal((4, 64, 512)), jnp.float32)
with falcon.use(cfg_force):
    y = falcon.dense(x, pw)
print(f"offline Combine B: weight (512,2048) -> B~ {tuple(pw.bt.shape)} "
      f"[{pw.algo}]; serve err={float(jnp.max(jnp.abs(y - x @ W))):.2e}")

# --- batched generation with the reduced model -----------------------------
cfg = registry.smoke_config("granite_3_2b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
B, S, GEN = 4, 32, 16
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
prefill = jax.jit(make_prefill_step(cfg, max_len=S + GEN))
decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))


def generate(p):
    logits, cache = prefill(p, tokens)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    outs, logit_trace = [], [logits[:, -1]]
    for i in range(GEN):
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        outs.append(np.asarray(nxt))
        logits, cache = decode(p, cache, nxt[:, None], S + i)
        logit_trace.append(logits[:, -1])
    jax.block_until_ready(logits)
    return np.stack(outs, 1), jnp.stack(logit_trace, 1), time.perf_counter() - t0


with falcon.use(cfg_force):
    # eager path: every projection runs Combine A + Combine B + GEMM + H
    eager_tokens, eager_logits, dt_eager = generate(params)

    # planned path: Combine B is offline — params become PlannedWeights
    planned_params, n_planned = falcon.precombine_params(params, m_hint=B * S)
    planned_tokens, planned_logits, dt_planned = generate(planned_params)

err = float(jnp.max(jnp.abs(planned_logits - eager_logits)))
match = float(np.mean(planned_tokens == eager_tokens))
print(f"precombined {n_planned} weight tensor(s) into PlannedWeights")
print(f"planned-vs-eager: logits max |err| = {err:.2e}, "
      f"token agreement = {match:.0%}")
assert np.allclose(np.asarray(planned_logits), np.asarray(eager_logits),
                   rtol=1e-2, atol=1e-2), "planned serving diverged from eager"
print(f"generated {GEN} tokens x batch {B}: "
      f"{B*GEN/dt_planned:.1f} tok/s planned ({dt_planned/GEN*1e3:.1f} ms/step) "
      f"vs {B*GEN/dt_eager:.1f} tok/s eager")
print("sequences:", planned_tokens[:2].tolist())
