"""FalconGEMM quickstart: the three modules in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg, codegen, decision as dec
from repro.core.falcon_gemm import FalconConfig, falcon_matmul
from repro.core.hardware import TPU_V5E

# --- 1. The LCMA library (validated schemes) -------------------------------
print("candidate LCMAs (Decision Module's S_LCMA):")
for l in alg.candidates(max_grid=4)[:6]:
    print(f"  {l.name:12s} {l.key:16s} mult.saving={l.mult_saving:.1%}")

# --- 2. Deployment Module: code generation ---------------------------------
gen = codegen.generate(alg.get("strassen"))
print("\ngenerated source (first lines) — coefficients are compile-time +/-:")
print("\n".join("  " + ln for ln in gen.source.splitlines()[:12]))

# --- 3. Decision Module: analytical selection on TPU v5e -------------------
print("\nDecision Module on TPU v5e (bf16):")
for M, K, N in [(512, 512, 512), (8192, 8192, 8192), (32768, 32768, 32768),
                (16384, 5376, 21504)]:
    d = dec.decide(M, N, K, TPU_V5E, "bfloat16")
    eff = dec.effective_tflops(M, N, K, d.seconds)
    pick = d.algo.name if d.use_lcma else "standard GEMM"
    print(f"  M={M:6d} K={K:6d} N={N:6d} -> {pick:14s} "
          f"predicted {eff:6.1f} eff-TF/s ({eff/197:.0%} of peak)")

# --- 4. The drop-in matmul ---------------------------------------------------
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((300, 200)), jnp.float32)
B = jnp.asarray(rng.standard_normal((200, 100)), jnp.float32)
C = falcon_matmul(A, B, FalconConfig(mode="strassen"))
err = float(jnp.max(jnp.abs(C - A @ B)))
print(f"\nfalcon_matmul vs A@B: max |err| = {err:.2e}  (arbitrary shapes pad)")

# --- 5. Pallas kernel path (TPU target; interpret-validated here) -----------
C2 = falcon_matmul(A, B, FalconConfig(mode="strassen", backend="pallas_interpret"))
print(f"pallas pipeline      max |err| = {float(jnp.max(jnp.abs(C2 - A @ B))):.2e}")
print("\nOK")
