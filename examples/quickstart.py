"""FalconGEMM quickstart: the unified API in ~80 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

import repro.api as falcon
from repro.core import algorithms as alg, codegen, decision as dec
from repro.core.hardware import TPU_V5E

# --- 1. The LCMA library (Decision Module's S_LCMA) -------------------------
print("candidate LCMAs (Decision Module's S_LCMA):")
for l in alg.candidates(max_grid=4)[:6]:
    print(f"  {l.name:12s} {l.key:16s} mult.saving={l.mult_saving:.1%}")

# --- 2. Deployment Module: code generation ---------------------------------
gen = codegen.generate(alg.get("strassen"))
print("\ngenerated source (first lines) — coefficients are compile-time +/-:")
print("\n".join("  " + ln for ln in gen.source.splitlines()[:12]))

# --- 3. Decision Module: analytical selection on TPU v5e -------------------
print("\nDecision Module on TPU v5e (bf16):")
for M, K, N in [(512, 512, 512), (8192, 8192, 8192), (32768, 32768, 32768),
                (16384, 5376, 21504)]:
    d = dec.decide(M, N, K, TPU_V5E, "bfloat16")
    eff = dec.effective_tflops(M, N, K, d.seconds)
    pick = d.algo.name if d.use_lcma else "standard GEMM"
    print(f"  M={M:6d} K={K:6d} N={N:6d} -> {pick:14s} "
          f"predicted {eff:6.1f} eff-TF/s ({eff/197:.0%} of peak)")

# --- 4. Context-scoped dispatch: falcon.use + dense/dot_general/einsum -----
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((300, 200)), jnp.float32)
B = jnp.asarray(rng.standard_normal((200, 100)), jnp.float32)
with falcon.use(falcon.FalconConfig(mode="strassen")):
    C = falcon.matmul(A, B)                       # drop-in a @ b
    err = float(jnp.max(jnp.abs(C - A @ B)))
    print(f"\nfalcon.matmul vs A@B: max |err| = {err:.2e}  (arbitrary shapes pad)")

    # batched/transposed contractions normalize down to the same 2-D core:
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 80, 4, 32)), jnp.float32)
    S = falcon.einsum("bqhd,bkhd->bhqk", q, k)    # attention scores
    err = float(jnp.max(jnp.abs(S - jnp.einsum("bqhd,bkhd->bhqk", q, k))))
    print(f"falcon.einsum (attention) max |err| = {err:.2e}")

# --- 5. First-class precombined weights (offline Combine B, §IV-C) ---------
W = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
pw = falcon.plan_weight(W, cfg=falcon.FalconConfig(mode="strassen"))
x = jnp.asarray(rng.standard_normal((4, 64, 256)), jnp.float32)
y = falcon.dense(x, pw, cfg=falcon.FalconConfig(mode="strassen"))
print(f"PlannedWeight[{pw.algo}] B~{tuple(pw.bt.shape)}: "
      f"max |err| = {float(jnp.max(jnp.abs(y - x @ W))):.2e}")

# --- 6. Backend registry: execution strategies are pluggable ---------------
calls = []

def traced_jnp(a2, b2, l, cfg):
    calls.append((a2.shape, b2.shape, l.name))
    return falcon.get_backend("jnp").apply(a2, b2, l, cfg)

falcon.register_backend("traced", traced_jnp)
C2 = falcon.matmul(A, B, cfg=falcon.FalconConfig(mode="strassen", backend="traced"))
print(f"registered backend 'traced' handled {calls}; "
      f"available: {falcon.available_backends()}")

# --- 7. Pallas kernel path (TPU target; interpret-validated here) ----------
C3 = falcon.matmul(A, B, cfg=falcon.FalconConfig(mode="strassen",
                                                 backend="pallas_interpret"))
print(f"pallas pipeline      max |err| = {float(jnp.max(jnp.abs(C3 - A @ B))):.2e}")
print("\nOK")
