"""Inspect the production multi-pod distribution config for any arch x cell.

Prints the mesh, the parameter sharding decisions (first N rules applied),
the input specs, and the analytic roofline terms — without compiling.

Run:  PYTHONPATH=src python examples/multipod_config.py --arch kimi_k2_1t --shape train_4k
(abstract only — safe on CPU; the full compile lives in repro.launch.dryrun)
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_27b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    # 512 virtual devices BEFORE jax init (same contract as the dry-run)
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.configs import SHAPE_CELLS, get_config
    from repro.core.hardware import TPU_V5E
    from repro.launch import specs as SP
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analytic import analytic_costs

    cfg = get_config(args.arch)
    cell = SHAPE_CELLS[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)} = {len(mesh.devices.reshape(-1))} chips")
    ok, why = SP.cell_applicable(cfg, cell)
    if not ok:
        print(f"cell skipped: {why}")
        return
    cs = SP.input_specs(cfg, cell, mesh)
    print(f"params: {cs.n_params/1e9:.2f}B total, {cs.n_active_params/1e9:.2f}B active")
    flat, _ = jax.tree_util.tree_flatten_with_path(cs.params)
    print("parameter shardings (sample):")
    for path, leaf in flat[:8]:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        print(f"  {name:42s} {str(leaf.shape):28s} {leaf.sharding.spec}")
    ac = analytic_costs(cfg, cell, dict(mesh.shape), cs.n_params, cs.n_active_params)
    tc, tm, tl = ac.terms(TPU_V5E, cfg.dtype)
    print(f"\nanalytic roofline/device: compute={tc:.4f}s memory={tm:.4f}s "
          f"collective={tl:.4f}s -> bottleneck: "
          f"{max(zip((tc, tm, tl), ('compute', 'memory', 'collective')))[1]}")


if __name__ == "__main__":
    main()
