"""End-to-end driver: train a ~100M-param LM with the full stack.

Exercises: model zoo (granite family), synthetic data pipeline, AdamW,
fault-tolerant TrainLoop with async checkpointing, FalconGEMM-backed
projections, restart-from-checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--quick]
"""
import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLMData
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init
from repro.train import TrainLoop, TrainLoopConfig, make_train_step
from repro.train.steps import warm_train


def config_100m(quick: bool) -> ModelConfig:
    if quick:
        return ModelConfig(name="lm_quick", family="dense", num_layers=2,
                           d_model=128, num_heads=4, num_kv_heads=2,
                           d_ff=256, vocab_size=512, dtype="float32",
                           remat=False, fsdp=False)
    # ~103M params: 12L x d768 (GPT-2-small-class), GQA 12/4, SwiGLU 2048
    return ModelConfig(name="lm_100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32768, dtype="float32",
                       remat=False, fsdp=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/falcon_train_lm")
    args = ap.parse_args()

    cfg = config_100m(args.quick)
    if args.quick:
        args.steps, args.seq, args.batch = min(args.steps, 20), 64, 2
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=6e-4)
    opt_state = adamw_init(params, opt_cfg)
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps,
                                   warmup=20), donate_argnums=(0, 1))
    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=100,
                        checkpoint_dir=args.ckpt, log_every=10),
        step, data, params, opt_state,
        # pre-plan every fwd+dA+dB shape triple so the first step's trace
        # (which compiles the planned custom-VJP backward) is plan-cache-hot
        warm_fn=lambda: warm_train(cfg, args.batch, args.seq))
    import logging
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    out = loop.run()
    h = out["history"]
    print(f"\ntrained {out['final_step']} steps: "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"({np.mean([r['time'] for r in h[5:]]):.2f}s/step)")
    print(f"checkpoints in {args.ckpt}: restart me and I resume automatically")


if __name__ == "__main__":
    main()
